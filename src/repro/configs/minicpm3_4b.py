"""minicpm3-4b [hf:openbmb/MiniCPM3-4B].

62L, d_model 2560, 40 heads, Multi-head Latent Attention (MLA):
q_lora 768, kv_lora 256, qk_nope 64 + qk_rope 32, v_head 64.
d_ff 6400, vocab 73448.  Decode caches the shared latent (288/token).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,               # MLA: per-head K/V derived from shared latent
    head_dim=64,
    d_ff=6_400,
    vocab_size=73_448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=10_000.0,
)
