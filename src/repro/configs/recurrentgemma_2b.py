"""recurrentgemma-2b [arXiv:2402.19427, Griffin].

26L, d_model 2560, 10 Q heads (head_dim 256), MQA kv=1, d_ff 7680
(GeGLU), vocab 256000.  Block pattern (rec, rec, attn): RG-LRU temporal
mixing 2-of-3 layers, local (windowed, 2048) attention 1-of-3.
Sub-quadratic -> runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7_680,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "attn"),
    window=2_048,
    lru_width=2_560,
    conv_width=4,
    rope_theta=10_000.0,
)
