"""xlstm-350m [arXiv:2405.04517].

24L, d_model 1024, 4 heads, vocab 50304, d_ff 0 (the xLSTM blocks carry
their own up/down projections: mLSTM proj factor 2, sLSTM 4/3).
Every 8th layer is sLSTM (xLSTM[7:1] ratio); the rest are mLSTM.
Constant-size recurrent state -> runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=8,
    proj_factor_mlstm=2.0,
    proj_factor_slstm=4.0 / 3.0,
    conv_width=4,
)
