"""seamless-m4t-medium [arXiv:2308.11596].

Encoder-decoder, 12L each side, d_model 1024, 16 heads (MHA), d_ff 4096,
vocab 256206.  The speech frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, frames, d).
Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4_096,
    vocab_size=256_206,
    frontend="audio",
    rope_theta=10_000.0,
)
