"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 family].

32L, d_model 1536, 24 Q heads, GQA kv=8, MoE 40 experts top-8 with
per-expert d_ff 512, vocab 49155.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                    # per-expert intermediate size
    vocab_size=49_155,
    n_experts=40,
    experts_per_token=8,
    rope_theta=10_000.0,
)
