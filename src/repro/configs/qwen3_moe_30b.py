"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B].

48L, d_model 2048, 32 Q heads (head_dim 128), GQA kv=4, MoE 128 experts
top-8 with per-expert d_ff 768, vocab 151936.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                    # per-expert intermediate size
    vocab_size=151_936,
    n_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
)
