"""phi3-mini-3.8b [arXiv:2404.14219].

32L, d_model 3072, 32 heads, MHA (kv=32), d_ff 8192, vocab 32064,
RoPE + SwiGLU.  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8_192,
    vocab_size=32_064,
    rope_theta=10_000.0,
)
