"""h2o-danube-3-4b [arXiv:2401.16818].

24L, d_model 3840, 32 Q heads (head_dim 120), GQA kv=8, d_ff 10240,
vocab 32000.  Llama+Mistral mix with sliding-window attention (window 4096)
-> sub-quadratic context handling; runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10_240,
    vocab_size=32_000,
    attn_kind="swa",
    window=4_096,
    rope_theta=10_000.0,
)
