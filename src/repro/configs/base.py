"""Config system: architecture + input-shape descriptions.

Every assigned architecture gets one ``ModelConfig`` (exact public numbers)
in its own ``configs/<id>.py``; the four assigned input shapes live here.
TP-divisibility derivations (head padding / KV expansion, DESIGN §5.5) are
computed by ``resolve_for_tp`` so the raw configs stay faithful to the
published numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention flavour
    attn_kind: str = "full"      # full | swa | mla
    window: int = 0              # swa / local-attention window
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_router_dtype: str = "float32"
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # hybrid (recurrentgemma): repeating block pattern, e.g. ("rec","rec","attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    conv_width: int = 4
    # xlstm: layer i is sLSTM iff (i % slstm_every == slstm_every - 1)
    slstm_every: int = 0
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    # enc-dec (seamless)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stub
    frontend: str = "none"       # none | audio | vision
    n_frontend_tokens: int = 256  # patch/frame embeddings prepended (vlm/audio)
    # misc
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # --- derived / TP-resolution fields (filled by resolve_for_tp) ---
    n_heads_padded: int = 0
    n_kv_heads_eff: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/logits tables padded for TP divisibility (Megatron
        convention); padded logit slots are masked to -inf in the loss."""
        return -(-self.vocab_size // 128) * 128

    @property
    def heads(self) -> int:
        return self.n_heads_padded or self.n_heads

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads_eff or self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        if self.block_pattern:
            reps = math.ceil(self.n_layers / len(self.block_pattern))
            return (self.block_pattern * reps)[: self.n_layers]
        if self.slstm_every:
            return tuple("slstm" if (i % self.slstm_every == self.slstm_every - 1)
                         else "mlstm" for i in range(self.n_layers))
        return ("attn",) * self.n_layers


def resolve_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad Q heads to a multiple of tp; expand KV heads so the cache shards.

    Numerics are unchanged: padded Q heads carry zero output-projection rows,
    expanded KV heads are exact repeats (GQA semantics).  DESIGN §5.5.
    """
    if tp <= 1:
        return dataclasses.replace(cfg, n_heads_padded=cfg.n_heads,
                                   n_kv_heads_eff=cfg.n_kv_heads)
    pad = math.ceil(cfg.n_heads / tp) * tp
    kv = cfg.n_kv_heads
    if kv % tp == 0:
        kv_eff = kv
    elif tp % kv == 0:
        kv_eff = tp
    else:                        # fall back to replication (no expansion)
        kv_eff = kv
    # GQA grouping must stay aligned: q-per-kv must divide evenly
    if kv_eff and pad % kv_eff:
        kv_eff = kv
    return dataclasses.replace(cfg, n_heads_padded=pad, n_kv_heads_eff=kv_eff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs able to run long_500k (sub-quadratic context handling); see DESIGN §7
SUBQUADRATIC = {"h2o-danube-3-4b", "recurrentgemma-2b", "xlstm-350m"}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.name in SUBQUADRATIC
    return True


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.block_pattern) or
                                           (cfg.slstm_every or 1))),
        d_model=64, n_heads=4, head_dim=16,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_frontend_tokens=8,
        n_heads_padded=0, n_kv_heads_eff=0,
    )
    if cfg.is_moe:
        # capacity_factor = n_experts makes the reduced config drop-free so
        # forward/prefill/decode agree exactly (full configs keep 1.25)
        kw.update(n_experts=4, experts_per_token=2, d_ff=64,
                  capacity_factor=4.0)
    if cfg.attn_kind == "mla":
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
                  qk_rope_dim=8, v_head_dim=16)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, n_dec_layers=2, n_layers=2)
    if cfg.slstm_every:
        kw.update(n_layers=4, slstm_every=2)
    return dataclasses.replace(cfg, **kw)
