"""internvl2-26b [arXiv:2404.16821].

InternViT-6B vision frontend (STUB: ``input_specs()`` provides precomputed
patch embeddings) + InternLM2-20B language backbone: 48L, d_model 6144,
48 Q heads (head_dim 128), GQA kv=8, d_ff 16384, vocab 92553.
Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6_144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=92_553,
    frontend="vision",
    n_frontend_tokens=256,
    rope_theta=1_000_000.0,
)
