"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``smoke_config`` (base.py) derives the reduced CPU-test variant.
"""
from __future__ import annotations

from .base import (SHAPES, SUBQUADRATIC, ModelConfig, ShapeConfig,
                   resolve_for_tp, shape_applicable, smoke_config)
from .granite_moe_3b import CONFIG as granite_moe_3b
from .h2o_danube3_4b import CONFIG as h2o_danube3_4b
from .internvl2_26b import CONFIG as internvl2_26b
from .minicpm3_4b import CONFIG as minicpm3_4b
from .phi3_mini import CONFIG as phi3_mini
from .phi4_mini import CONFIG as phi4_mini
from .qwen3_moe_30b import CONFIG as qwen3_moe_30b
from .recurrentgemma_2b import CONFIG as recurrentgemma_2b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .xlstm_350m import CONFIG as xlstm_350m

ARCHS = {
    c.name: c for c in [
        qwen3_moe_30b, granite_moe_3b, h2o_danube3_4b, minicpm3_4b,
        phi3_mini, phi4_mini, recurrentgemma_2b, seamless_m4t_medium,
        xlstm_350m, internvl2_26b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_configs() -> list[str]:
    return sorted(ARCHS)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ARCHS", "SHAPES", "SUBQUADRATIC", "ModelConfig", "ShapeConfig",
           "get_config", "get_shape", "list_configs", "resolve_for_tp",
           "shape_applicable", "smoke_config"]
