"""phi4-mini-3.8b [arXiv:2412.08905].

32L, d_model 3072, 24 Q heads (head_dim 128), GQA kv=8, d_ff 8192,
vocab 200064, RoPE + SwiGLU.  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8_192,
    vocab_size=200_064,
    rope_theta=10_000.0,
)
