"""Jit'd wrapper + custom VJP for the linear-scan kernel.

Backward of h_t = a_t h_{t-1} + b_t:
    db_t = g_t + a_{t+1} db_{t+1}      (reverse linear scan)
    da_t = db_t * h_{t-1}
so the backward reuses the SAME kernel on reversed inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import linear_scan as _kernel_scan
from .ref import linear_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def linear_scan(a, b, interpret: bool = False):
    return _kernel_scan(a, b, interpret=interpret)


def _fwd(a, b, interpret):
    h = _kernel_scan(a, b, interpret=interpret)
    return h, (a, h)


def _bwd(interpret, res, g):
    a, h = res
    # reverse-scan: db_t = g_t + a_{t+1} db_{t+1}
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    db = _kernel_scan(a_next[:, ::-1], g[:, ::-1],
                      interpret=interpret)[:, ::-1]
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = db * h_prev
    return da, db


linear_scan.defvjp(_fwd, _bwd)
