"""Pure-jnp oracle for the chunked linear recurrence h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                    h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """a, b: (B, S, D) fp32. Returns h: (B, S, D)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    B, S, D = a.shape
    h0 = jnp.zeros((B, D), a.dtype) if h0 is None else h0
    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2),
                                    b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
