"""Pallas TPU chunked linear-recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Covers the RG-LRU (recurrentgemma) and diagonal-state updates.  Grid =
(B, S/chunk) with the chunk axis innermost-sequential; the carry h lives
in VMEM scratch and persists across chunks, so HBM traffic is exactly one
read of (a, b) and one write of h -- the memory-roofline optimum for this
memory-bound op.  Within a chunk the recurrence runs as a fori_loop over
time steps on (D,)-vectors (VPU lanes); D blocks map to the lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256


def _scan_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        h = a_ref[t, :] * h + b_ref[t, :]
        o_ref[t, :] = h
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def linear_scan(a: jnp.ndarray, b: jnp.ndarray,
                chunk: int = DEFAULT_CHUNK,
                interpret: bool = False) -> jnp.ndarray:
    """a, b: (B, S, D). Returns h with h_t = a_t h_{t-1} + b_t, h_0 = b_0."""
    B, S, D = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to the chunk size"
    nc = S // chunk
    kernel = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, D), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, chunk, D), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, D), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
        interpret=interpret,
    )(a, b)
