"""Dispatch layer for the ``we_rounds`` kernel package.

``we_rounds_grid`` is what the ``pallas`` sampler backend calls: it pads
the batch to a tile multiple, picks an execution mode, and returns numpy
arrays.  Modes (``REPRO_WE_ROUNDS_MODE`` or the ``mode=`` kwarg):

``auto``
    Compiled Pallas kernel when a Pallas-lowering backend (TPU) is
    attached, otherwise the jitted jnp reference -- the path CPU CI runs.
``kernel`` / ``interpret``
    Force the Pallas kernel, compiled / in interpreter mode.  Interpret
    mode executes the *actual kernel code* on CPU (slowly), which is what
    the ``pallas-interpret`` CI job exercises.
``reference``
    Force the jitted jnp oracle.

All modes are bit-identical on real rows (counter-based draws -- see
``ref.py``), so mode selection is a pure performance choice.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import numpy as np

from .kernel import DEFAULT_BLOCK_B, we_rounds_pallas
from .ref import (gamma_rows_reference, we_rounds_reference,
                  we_rounds_reference_panel)

ENV_MODE = "REPRO_WE_ROUNDS_MODE"
MODES = ("auto", "kernel", "interpret", "reference")


def lowering_available() -> bool:
    """True when the attached jax backend can compile Pallas TPU kernels."""
    try:
        import jax
        return jax.default_backend() in ("tpu",)
    except Exception:
        return False


def resolve_mode(mode: Optional[str] = None) -> str:
    name = mode or os.environ.get(ENV_MODE) or "auto"
    if name not in MODES:
        raise KeyError(f"unknown we_rounds mode {name!r}; have {MODES}")
    if name == "auto":
        return "kernel" if lowering_available() else "reference"
    return name


@functools.lru_cache(maxsize=None)
def _jit_reference(n0: float, threshold: float, cap: float, known: bool,
                   max_iter: int):
    import jax
    return jax.jit(functools.partial(we_rounds_reference, n0=n0,
                                     threshold=threshold, cap=cap,
                                     known=known, max_iter=max_iter))


@functools.lru_cache(maxsize=None)
def _jit_kernel(n0: float, threshold: float, cap: float, known: bool,
                max_iter: int, block_b: int, interpret: bool):
    import jax
    return jax.jit(functools.partial(we_rounds_pallas, n0=n0,
                                     threshold=threshold, cap=cap,
                                     known=known, max_iter=max_iter,
                                     block_b=block_b, interpret=interpret))


@functools.lru_cache(maxsize=None)
def _jit_reference_panel(n0: float, threshold: float, cap: float,
                         max_iter: int):
    import jax
    return jax.jit(functools.partial(we_rounds_reference_panel, n0=n0,
                                     threshold=threshold, cap=cap,
                                     max_iter=max_iter))


@functools.lru_cache(maxsize=None)
def _jit_kernel_panel(n0: float, threshold: float, cap: float,
                      max_iter: int, block_b: int, interpret: bool):
    import jax

    def fn(lam_rows, seed, flags, sched=None):
        return we_rounds_pallas(lam_rows, seed, sched, flags, n0=n0,
                                threshold=threshold, cap=cap, known=False,
                                max_iter=max_iter, block_b=block_b,
                                interpret=interpret)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_sharded(mesh, n0: float, threshold: float, cap: float, known: bool,
                 max_iter: int, block_b: int, mode: str,
                 drift: bool = False, panel: bool = False):
    """shard_map wrapper over the per-mode fn, cached per (mesh, config).

    Each device runs the whole pipeline on its block of rows with its own
    seed pair (one ``(D, 2)`` seed matrix, one row per device), so shards
    never synchronize; ``check_rep=False`` because jax<=0.4 has no
    replication rule for ``while``.  ``drift`` adds the per-round rate
    schedule as a batch-sharded input; ``panel`` is the fused mixed-mode
    launch, which adds the per-row known flags (row-sharded like the
    rates -- a flag travels with its row).
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    if panel:
        if mode == "reference":
            fn = _jit_reference_panel(n0, threshold, cap, max_iter)

            def block(seeds_b, lam_b, flags_b):
                return fn(lam_b, seeds_b[0], flags_b)

            def block_drift(seeds_b, lam_b, flags_b, sched_b):
                return fn(lam_b, seeds_b[0], flags_b, sched_b)
        else:
            fn = _jit_kernel_panel(n0, threshold, cap, max_iter, block_b,
                                   mode == "interpret")

            def block(seeds_b, lam_b, flags_b):
                out = fn(lam_b, seeds_b, flags_b)
                return out[:, 0], out[:, 1], out[:, 2]

            def block_drift(seeds_b, lam_b, flags_b, sched_b):
                out = fn(lam_b, seeds_b, flags_b, sched_b)
                return out[:, 0], out[:, 1], out[:, 2]
    elif mode == "reference":
        fn = _jit_reference(n0, threshold, cap, known, max_iter)

        def block(seeds_b, lam_b):
            return fn(lam_b, seeds_b[0])

        def block_drift(seeds_b, lam_b, sched_b):
            return fn(lam_b, seeds_b[0], sched_b)
    else:
        fn = _jit_kernel(n0, threshold, cap, known, max_iter, block_b,
                         mode == "interpret")

        def block(seeds_b, lam_b):
            out = fn(lam_b, seeds_b)
            return out[:, 0], out[:, 1], out[:, 2]

        def block_drift(seeds_b, lam_b, sched_b):
            out = fn(lam_b, seeds_b, sched_b)
            return out[:, 0], out[:, 1], out[:, 2]

    spec = PartitionSpec(mesh.axis_names[0])
    n_in = 2 + (1 if panel else 0)
    if drift:
        return jax.jit(shard_map(block_drift, mesh=mesh,
                                 in_specs=(spec,) * (n_in + 1),
                                 out_specs=spec, check_rep=False))
    return jax.jit(shard_map(block, mesh=mesh, in_specs=(spec,) * n_in,
                             out_specs=spec, check_rep=False))


def _pad_rows(rows: Optional[np.ndarray], pad: int) -> Optional[np.ndarray]:
    if rows is None or pad == 0:
        return rows
    return np.concatenate([rows, np.repeat(rows[:1], pad, axis=0)])


def we_rounds_grid(lam_rows: np.ndarray, seed, *, n0: float,
                   threshold: float, cap: float, known,
                   max_iter: int, mode: Optional[str] = None,
                   block_b: int = DEFAULT_BLOCK_B, mesh=None,
                   rate_schedule: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fused round pipeline over ``(B, K)`` rate rows -> per-row
    ``(t_comp, iterations, n_comm)`` float64 numpy arrays.

    ``seed`` is a pair of uint32 (any sequence of two ints).  ``B`` is
    padded to a multiple of ``block_b`` with copies of row 0 (counters are
    per global row, so padding never alters real rows).

    ``known`` is a bool (the single-scheme path) or a ``(B,)`` per-row
    flag array -- the fused-panel mixed mode, where known and unknown
    work-exchange rows of a whole figure run in ONE launch (``cap``
    applies to the unknown rows; known rows are uncapped).

    ``mesh`` (a 1-D jax Mesh, e.g. from ``grid_sharding``) shards the row
    axis across its devices via ``shard_map``; ``seed`` must then be a
    ``(mesh.size, 2)`` matrix, one independent seed pair per device.
    Sharded runs are NOT bit-identical to single-device runs (different
    counter keying), but every mode agrees bitwise at a fixed layout.

    ``rate_schedule`` (optional ``(B, R, K)``, row-aligned with
    ``lam_rows``) is the drifting-scenario per-round schedule; every mode
    (kernel / interpret / reference) consumes it identically, so drift
    runs keep the cross-mode bit-identity.
    """
    import jax.numpy as jnp

    lam_rows = np.asarray(lam_rows, dtype=np.float32)
    if lam_rows.ndim != 2:
        raise ValueError(f"lam_rows must be (B, K); got {lam_rows.shape}")
    B = lam_rows.shape[0]
    sched = None
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float32)
        if sched.ndim != 3 or sched.shape[0] != B:
            raise ValueError(f"rate_schedule must be (B={B}, R, K); "
                             f"got {sched.shape}")
    flags = None
    if not isinstance(known, (bool, np.bool_)):
        flags = np.asarray(known, dtype=np.float32).reshape(-1, 1)
        if flags.shape[0] != B:
            raise ValueError(f"per-row known flags must have one entry per "
                             f"row (B={B}); got {flags.shape[0]}")
        known = False
    mode = resolve_mode(mode)
    if mesh is not None and mesh.size > 1:
        D = int(mesh.size)
        seed_arr = np.asarray(seed, dtype=np.uint32).reshape(D, 2)
        # every device block must be a whole number of kernel tiles
        quantum = D if mode == "reference" else D * block_b
        pad = (-B) % quantum
        lam_rows = _pad_rows(lam_rows, pad)
        sched = _pad_rows(sched, pad)
        flags = _pad_rows(flags, pad)
        fn = _jit_sharded(mesh, float(n0), float(threshold), float(cap),
                          bool(known), int(max_iter), int(block_b), mode,
                          drift=sched is not None, panel=flags is not None)
        args = (jnp.asarray(seed_arr), jnp.asarray(lam_rows))
        if flags is not None:
            args += (jnp.asarray(flags),)
        if sched is not None:
            args += (jnp.asarray(sched),)
        t, it, cm = fn(*args)
        return (np.asarray(t, dtype=np.float64)[:B],
                np.asarray(it, dtype=np.float64)[:B],
                np.asarray(cm, dtype=np.float64)[:B])
    seed_arr = np.asarray(seed, dtype=np.uint32).reshape(2)

    pad = (-B) % block_b
    if pad and mode != "reference":
        lam_rows = _pad_rows(lam_rows, pad)
        sched = _pad_rows(sched, pad)
        flags = _pad_rows(flags, pad)

    if mode == "reference":
        if flags is not None:
            fn = _jit_reference_panel(float(n0), float(threshold),
                                      float(cap), int(max_iter))
            args = (jnp.asarray(lam_rows), jnp.asarray(seed_arr),
                    jnp.asarray(flags))
            t, it, cm = fn(*args) if sched is None else fn(
                *args, jnp.asarray(sched))
        else:
            fn = _jit_reference(float(n0), float(threshold), float(cap),
                                bool(known), int(max_iter))
            if sched is None:
                t, it, cm = fn(jnp.asarray(lam_rows), jnp.asarray(seed_arr))
            else:
                t, it, cm = fn(jnp.asarray(lam_rows), jnp.asarray(seed_arr),
                               jnp.asarray(sched))
    elif flags is not None:
        fn = _jit_kernel_panel(float(n0), float(threshold), float(cap),
                               int(max_iter), int(block_b),
                               mode == "interpret")
        sched_arg = None if sched is None else jnp.asarray(sched)
        out = fn(jnp.asarray(lam_rows), jnp.asarray(seed_arr[None, :]),
                 jnp.asarray(flags), sched_arg)
        t, it, cm = out[:, 0], out[:, 1], out[:, 2]
    else:
        fn = _jit_kernel(float(n0), float(threshold), float(cap),
                         bool(known), int(max_iter), int(block_b),
                         mode == "interpret")
        if sched is None:
            out = fn(jnp.asarray(lam_rows), jnp.asarray(seed_arr[None, :]))
        else:
            out = fn(jnp.asarray(lam_rows), jnp.asarray(seed_arr[None, :]),
                     jnp.asarray(sched))
        t, it, cm = out[:, 0], out[:, 1], out[:, 2]
    return (np.asarray(t, dtype=np.float64)[:B],
            np.asarray(it, dtype=np.float64)[:B],
            np.asarray(cm, dtype=np.float64)[:B])


@functools.lru_cache(maxsize=4)
def _jit_gamma_rows(boost: bool):
    import jax
    return jax.jit(functools.partial(gamma_rows_reference, boost=boost))


def gamma_rows_grid(shape_rows: np.ndarray, scale_rows: np.ndarray,
                    seed) -> np.ndarray:
    """Counter-based ``Gamma(shape) * scale`` over ``(R, K)`` rows in one
    jitted dispatch (the MDS L-sweep primitive of the pallas backend;
    shape/scale broadcast against each other).  The boost chain -- and
    its two extra Threefry calls per element -- is compiled in only when
    some shape is below 3.  Output stays float32 (the pipeline dtype)."""
    import jax.numpy as jnp

    shape_rows = np.asarray(shape_rows, dtype=np.float32)
    scale_rows = np.asarray(scale_rows, dtype=np.float32)
    seed_arr = np.asarray(seed, dtype=np.uint32).reshape(2)
    boost = bool((shape_rows < 3.0).any())
    out = _jit_gamma_rows(boost)(jnp.asarray(shape_rows),
                                 jnp.asarray(scale_rows),
                                 jnp.asarray(seed_arr))
    return np.asarray(out)
