"""Fused work-exchange round-pipeline kernel (the ``pallas`` sampler
backend): counter-based Threefry bits + Marsaglia-Tsang Gammas + argmin
straggler selection + normal-limit Binomials in one tiled pass."""
from .kernel import DEFAULT_BLOCK_B, we_rounds_pallas
from .ops import (ENV_MODE, MODES, gamma_rows_grid, lowering_available,
                  resolve_mode, we_rounds_grid)
from .ref import gamma_rows_reference, we_rounds_reference

__all__ = [
    "DEFAULT_BLOCK_B", "ENV_MODE", "MODES", "gamma_rows_grid",
    "gamma_rows_reference", "lowering_available", "resolve_mode",
    "we_rounds_grid", "we_rounds_pallas", "we_rounds_reference",
]
