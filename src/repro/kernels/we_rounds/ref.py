"""Counter-based work-exchange round pipeline: shared math + jnp oracle.

Everything the Pallas kernel computes lives here as pure ``jnp`` functions
on ``(rows, K)`` tiles, so the kernel (``kernel.py``) and the reference
engine (``we_rounds_reference``) share one implementation of

* **bit generation** -- Threefry-2x32 (20 rounds: add / xor / rotate on
  ``uint32`` only, the reason JAX itself uses Threefry on TPU), keyed per
  ``(trial, worker, round, slot)``.  Counter-based draws make the pipeline
  embarrassingly parallel AND tiling-invariant: a row's random stream
  depends only on its global row id, never on tile size, loop trip count,
  or padding rows, so kernel and reference are *bit-identical* and padded
  rows cannot perturb real ones.
* **Gamma service draws** -- the mean-exact Marsaglia-Tsang transform
  ``d * (1 + z / (3 sqrt(d)))^3`` with the exact boost
  ``Gamma(a) = Gamma(a+1) * U^(1/a)`` chained three times below shape 3
  (the same relaxation as the ``jax`` sampler backend).
* **straggler selection** -- per-trial argmin over the K workers.
* **Binomial done-counts** -- the mean/variance-exact normal limit.

``we_rounds_reference`` runs the full batch through one
``lax.while_loop``; it is both the CPU-CI execution path of the ``pallas``
sampler backend (jitted, no Pallas lowering required) and the oracle the
kernel is validated against.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# slot layout per (trial, worker, round): 4 Threefry calls x 2 words
#   pair 0 -> Box-Muller pair for the Gamma normal
#   pair 1 -> boost uniforms u0, u1
#   pair 2 -> boost uniform u2 (word 1 spare)
#   pair 3 -> Box-Muller pair for the Binomial normal
N_PAIRS = 4
_U32 = jnp.uint32


def _rotl(x: jnp.ndarray, d: int) -> jnp.ndarray:
    return (x << _U32(d)) | (x >> _U32(32 - d))


def threefry2x32(k0, k1, c0, c1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Threefry-2x32, 20 rounds (the full-strength variant)."""
    k0, k1 = _U32(k0) + _U32(0), _U32(k1) + _U32(0)
    ks2 = k0 ^ k1 ^ _U32(0x1BD11BDA)
    x0 = c0.astype(jnp.uint32) + k0
    x1 = c1.astype(jnp.uint32) + k1
    rot_a = (13, 15, 26, 6)
    rot_b = (17, 29, 16, 24)
    inject = ((k1, ks2), (ks2, k0), (k0, k1), (k1, ks2), (ks2, k0))
    for block in range(5):
        for d in (rot_a if block % 2 == 0 else rot_b):
            x0 = x0 + x1
            x1 = _rotl(x1, d) ^ x0
        x0 = x0 + inject[block][0]
        x1 = x1 + inject[block][1] + _U32(block + 1)
    return x0, x1


def uniform01(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 -> float32 uniform in (0, 1): top 24 bits, zero-excluded
    so ``log(u)`` stays finite."""
    u = (bits >> _U32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return jnp.maximum(u, jnp.float32(1e-12))


def _box_muller(u1: jnp.ndarray, u2: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(
        jnp.float32(2.0 * jnp.pi) * u2)


def round_uniforms(k0, k1, c0: jnp.ndarray, c1_base: jnp.ndarray):
    """The 7 variates one exchange round needs per ``(row, worker)`` cell.

    ``c0`` carries the global row (trial) id, ``c1_base`` the
    ``(round * K + worker) * N_PAIRS`` namespace; both broadcast over the
    tile.  Returns ``(z_gamma, u0, u1, u2, z_binom)`` float32 arrays.
    """
    c0 = c0.astype(jnp.uint32)
    c1_base = c1_base.astype(jnp.uint32)
    a0, a1 = threefry2x32(k0, k1, c0, c1_base)
    b0, b1 = threefry2x32(k0, k1, c0, c1_base + _U32(1))
    c0_, _ = threefry2x32(k0, k1, c0, c1_base + _U32(2))
    d0, d1 = threefry2x32(k0, k1, c0, c1_base + _U32(3))
    z_gamma = _box_muller(uniform01(a0), uniform01(a1))
    z_binom = _box_muller(uniform01(d0), uniform01(d1))
    return (z_gamma, uniform01(b0), uniform01(b1), uniform01(c0_), z_binom)


def gamma_mt(z: jnp.ndarray, u0: jnp.ndarray, u1: jnp.ndarray,
             u2: jnp.ndarray, alpha: jnp.ndarray,
             inv_rate: jnp.ndarray) -> jnp.ndarray:
    """Mean-exact MT transform for any ``alpha > 0``: raw transform at
    shape ``alpha + 3`` below 3, pulled back through the exact identity
    ``Gamma(a) = Gamma(a+1) U^{1/a}`` chained three times (the chained
    mean telescopes exactly, as in the jax sampler backend)."""
    boost = alpha < 3.0
    a = jnp.where(boost, alpha + 3.0, alpha)
    d = a - jnp.float32(1.0 / 3.0)
    c = jnp.maximum(1.0 + z / (3.0 * jnp.sqrt(d)), 0.0)
    raw = d * c ** 3 * inv_rate
    log_pow = (jnp.log(u0) / jnp.maximum(alpha, 1e-12)
               + jnp.log(u1) / jnp.maximum(alpha + 1.0, 1e-12)
               + jnp.log(u2) / jnp.maximum(alpha + 2.0, 1e-12))
    return raw * jnp.where(boost, jnp.exp(log_pow), 1.0)


def binomial_normal(z: jnp.ndarray, n: jnp.ndarray,
                    p: jnp.ndarray) -> jnp.ndarray:
    """Binomial(n, p) in its mean/variance-exact normal limit."""
    mean = n * p
    std = jnp.sqrt(jnp.maximum(n * p * (1.0 - p), 0.0))
    return jnp.clip(mean + z * std, 0.0, n)


# ---------------------------------------------------------------------------
# the round pipeline on a (rows, K) tile
# ---------------------------------------------------------------------------

def estimator_prior(lam: jnp.ndarray) -> jnp.ndarray:
    """Initial / no-observation rate estimate per worker column.

    The paper's prior is ``lambda_hat = 1`` everywhere; zero-rate columns
    (masked padding from the K-axis shape buckets) must hold a zero
    estimate instead so the estimator never assigns them work.  Without
    padding this is exactly ``jnp.ones_like(lam)``, bit-for-bit.
    """
    return jnp.where(lam > 0.0, jnp.float32(1.0), jnp.float32(0.0))


def init_state(rows: int, K: int, n0: float, threshold: float,
               known: bool, lam: jnp.ndarray = None,
               with_round: bool = False) -> Dict[str, jnp.ndarray]:
    st = {
        "n_rem": jnp.full((rows, 1), jnp.float32(n0)),
        "n_left": jnp.zeros((rows, K), jnp.float32),
        "t_comp": jnp.zeros((rows, 1), jnp.float32),
        "n_comm": jnp.zeros((rows, 1), jnp.float32),
        "iters": jnp.zeros((rows, 1), jnp.int32),
        "active": jnp.full((rows, 1), n0 > threshold),
    }
    if with_round:
        # scalar trip counter: every *active* row has proceeded on every
        # prior trip, so its ``iters`` equals this counter -- which is why
        # the in-loop drift read can be one dynamic slice instead of a
        # per-row gather
        st["round"] = jnp.int32(0)
    if not known:
        prior = (jnp.ones((rows, K), jnp.float32) if lam is None
                 else jnp.broadcast_to(estimator_prior(lam), (rows, K))
                 .astype(jnp.float32))
        st.update(est_done=jnp.zeros((rows, K), jnp.float32),
                  est_time=jnp.zeros((rows, 1), jnp.float32),
                  lam_hat=prior)
    return st


def sched_inv_rates(sched: jnp.ndarray, iters: jnp.ndarray) -> jnp.ndarray:
    """1/rate in effect at each row's current round, from a
    ``(rows, R, K)`` per-round schedule (round >= R holds the last row).

    One-hot masked sum -- O(rows * R * K) per call, so it is reserved for
    the run-once final phase where ``iters`` genuinely differs per row;
    the in-loop read uses the scalar round counter and a dynamic slice
    (``sched_row`` / the kernel's ``pl.ds`` tile read) instead.
    """
    R = sched.shape[1]
    r_idx = jnp.minimum(iters, R - 1)                       # (rows, 1)
    rounds = jax.lax.broadcasted_iota(jnp.int32, (1, R), 1)
    sel = (r_idx == rounds).astype(sched.dtype)             # (rows, R)
    return 1.0 / (sched * sel[:, :, None]).sum(1)           # (rows, K)


def sched_row(sched: jnp.ndarray, rnd: jnp.ndarray) -> jnp.ndarray:
    """Rates row of a ``(rows, R, K)`` schedule at scalar round ``rnd``
    (clamped to the last row), as a direct round-indexed load."""
    r = jnp.minimum(rnd, sched.shape[1] - 1)
    return jax.lax.dynamic_slice_in_dim(sched, r, 1, axis=1)[:, 0, :]


def sched_inv_rates_gather(sched: jnp.ndarray,
                           iters: jnp.ndarray) -> jnp.ndarray:
    """``sched_inv_rates`` as a per-row gather: same selected values
    bit-for-bit, O(rows * K) instead of O(rows * R * K).  XLA-only (the
    full-batch reference); the kernel keeps the one-hot form, which
    lowers in Pallas and is cheap on a single tile."""
    r_idx = jnp.minimum(iters, sched.shape[1] - 1)          # (rows, 1)
    cur = jnp.take_along_axis(sched, r_idx[:, :, None], axis=1)[:, 0, :]
    return 1.0 / cur


def round_body(st: Dict[str, jnp.ndarray], lam: jnp.ndarray,
               inv_lam: jnp.ndarray, row_ids: jnp.ndarray, k0, k1, *,
               K: int, cap: float, threshold: float, known: bool,
               max_iter: int, sched_at=None,
               known_col: jnp.ndarray = None) -> Dict[str, jnp.ndarray]:
    """One fluid exchange round on a tile (shared by kernel and oracle).

    The RNG round index is the row's own ``iters`` (== the global loop
    count while a row is active), so frozen rows recompute already-spent
    counters into fully-masked lanes and the result is independent of how
    many extra trips the surrounding ``while_loop`` makes.

    ``sched_at`` (optional callable ``round -> (rows, K)`` rates) supplies
    each round's true service rates (drifting scenarios): the Gamma draws
    use them, the assignment shares keep using ``lam`` / the online
    estimate.  It is indexed by the scalar ``st["round"]`` trip counter --
    active rows always have ``iters == round`` (a row that fails to
    proceed goes inactive for good), and frozen rows' stale reads are
    fully masked -- so one row load per trip replaces the old
    O(rows * R * K) one-hot masked sum.

    ``known_col`` (optional ``(rows, 1)`` bool) is the fused-panel mixed
    mode: each row carries its own known-heterogeneity flag (known rows
    assign by ``lam`` with no storage cap, unknown rows by the online
    estimate under ``cap``).  Callers pass ``known=False`` alongside it so
    the estimator state exists for every row; known rows simply never
    read it.
    """
    worker = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    c1 = ((st["iters"] * K + worker) * N_PAIRS).astype(jnp.uint32)
    z_g, u0, u1, u2, z_b = round_uniforms(k0, k1, row_ids, c1)

    if sched_at is not None:
        inv_lam = 1.0 / sched_at(st["round"])
    if known_col is not None:
        rates = jnp.where(known_col, lam, st["lam_hat"])
        cap_eff = jnp.where(known_col, jnp.inf, jnp.float32(cap))
    else:
        rates = lam if known else st["lam_hat"]
        cap_eff = jnp.float32(cap)
    share = rates * (st["n_rem"] / rates.sum(1, keepdims=True))
    assign = jnp.minimum(share, cap_eff)
    busy = assign > 0.5        # sub-half slivers carry over as leftover
    t_raw = gamma_mt(z_g, u0, u1, u2, jnp.maximum(assign, 0.5), inv_lam)
    t_k = jnp.where(busy, t_raw, jnp.inf)
    t_star = t_k.min(1, keepdims=True)
    proceed = st["active"] & jnp.isfinite(t_star)
    fin = t_k == t_star                     # finisher clears its queue
    p = jnp.clip(t_star / t_k, 0.0, 1.0)
    done = binomial_normal(z_b, jnp.maximum(assign - 1.0, 0.0), p)
    done = jnp.where(fin, assign, jnp.where(busy, done, 0.0))
    n_rem = st["n_rem"] - done.sum(1, keepdims=True)

    started = st["iters"] > 0
    comm = jnp.maximum(assign - st["n_left"], 0.0).sum(1, keepdims=True)
    upd = lambda new, old: jnp.where(proceed, new, old)  # noqa: E731
    iters = st["iters"] + proceed
    n_rem_m = upd(n_rem, st["n_rem"])
    out = {
        "n_rem": n_rem_m,
        "n_left": upd(assign - done, st["n_left"]),
        "t_comp": upd(st["t_comp"] + t_star, st["t_comp"]),
        "n_comm": upd(st["n_comm"] + jnp.where(started, comm, 0.0),
                      st["n_comm"]),
        "iters": iters,
        "active": proceed & (n_rem_m > threshold) & (iters < max_iter),
    }
    if "round" in st:
        out["round"] = st["round"] + jnp.int32(1)
    if not known:
        # accumulators go unmasked; frozen rows only read them through
        # lam_hat, which IS masked
        ed = st["est_done"] + done
        et = st["est_time"] + t_star
        out["est_done"] = ed
        out["est_time"] = et
        out["lam_hat"] = upd(jnp.where(ed > 0.0, ed / jnp.maximum(et, 1e-30),
                                       estimator_prior(lam)),
                             st["lam_hat"])
    return out


def final_phase(st: Dict[str, jnp.ndarray], lam: jnp.ndarray,
                inv_lam: jnp.ndarray, row_ids: jnp.ndarray, k0, k1, *,
                K: int, known: bool, max_iter: int,
                sched: jnp.ndarray = None, sched_gather: bool = False,
                known_col: jnp.ndarray = None):
    """Below the threshold: assign the remainder, wait for all workers.
    Uses the reserved round index ``max_iter`` (the loop never reaches it:
    in-loop draws happen at ``iters < max_iter``).  ``sched_gather``
    selects the XLA per-row gather for the drift read (the full-batch
    reference path); the default one-hot lowers inside the kernel.
    ``known_col`` is the fused-panel per-row flag (see ``round_body``)."""
    worker = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    c1 = ((jnp.int32(max_iter) * K + worker) * N_PAIRS).astype(jnp.uint32)
    z_g, u0, u1, u2, _ = round_uniforms(
        k0, k1, jnp.broadcast_to(row_ids, (row_ids.shape[0], 1)), c1)
    has_rem = st["n_rem"] > 1e-6
    if sched is not None:
        inv_lam = (sched_inv_rates_gather(sched, st["iters"])
                   if sched_gather else sched_inv_rates(sched, st["iters"]))
    if known_col is not None:
        rates = jnp.where(known_col, lam, st["lam_hat"])
    else:
        rates = lam if known else st["lam_hat"]
    share = rates * (st["n_rem"] / rates.sum(1, keepdims=True))
    comm = jnp.maximum(share - st["n_left"], 0.0).sum(1, keepdims=True)
    t_k = jnp.where(share > 1e-9,
                    gamma_mt(z_g, u0, u1, u2, jnp.maximum(share, 1e-9),
                             inv_lam), 0.0)
    t_comp = st["t_comp"] + jnp.where(has_rem, t_k.max(1, keepdims=True),
                                      0.0)
    n_comm = st["n_comm"] + jnp.where(has_rem & (st["iters"] > 0), comm,
                                      0.0)
    iters = st["iters"] + has_rem
    return t_comp[:, 0], iters[:, 0].astype(jnp.float32), n_comm[:, 0]


# ---------------------------------------------------------------------------
# full-batch jnp oracle (the pallas backend's CPU execution path)
# ---------------------------------------------------------------------------

def we_rounds_reference(lam_rows: jnp.ndarray, seed: jnp.ndarray,
                        sched: jnp.ndarray = None, *,
                        n0: float, threshold: float, cap: float,
                        known: bool, max_iter: int):
    """The whole ``(B, K)`` batch through one ``lax.while_loop``.

    Bit-identical to the Pallas kernel (interpret or compiled) on shared
    rows for any tiling, because every draw is a pure function of
    ``(seed, row, worker, round, slot)``.  ``sched`` (optional
    ``(B, R, K)``) is the per-round service-rate schedule of the
    drifting scenarios -- the RNG keying is unchanged, so kernel and
    reference stay bit-identical with or without drift.
    """
    B, K = lam_rows.shape
    lam = lam_rows.astype(jnp.float32)
    inv_lam = 1.0 / lam
    k0, k1 = seed[0], seed[1]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)
    sched_at = None if sched is None else (lambda r: sched_row(sched, r))

    def cond(st):
        return st["active"].any()

    def body(st):
        return round_body(st, lam, inv_lam, row_ids, k0, k1, K=K, cap=cap,
                          threshold=threshold, known=known,
                          max_iter=max_iter, sched_at=sched_at)

    st = jax.lax.while_loop(cond, body,
                            init_state(B, K, n0, threshold, known, lam=lam,
                                       with_round=sched is not None))
    return final_phase(st, lam, inv_lam, row_ids, k0, k1, K=K, known=known,
                       max_iter=max_iter, sched=sched, sched_gather=True)


def we_rounds_reference_panel(lam_rows: jnp.ndarray, seed: jnp.ndarray,
                              known_flags: jnp.ndarray,
                              sched: jnp.ndarray = None, *,
                              n0: float, threshold: float, cap: float,
                              max_iter: int):
    """``we_rounds_reference`` with a per-row known-heterogeneity flag.

    The fused-panel path: known and unknown work-exchange rows of a whole
    figure stack into ONE batch (one launch), each row reading its own
    ``known_flags`` entry (float32/bool ``(B,)`` or ``(B, 1)``; nonzero =
    known).  Counters are keyed by the global row id exactly as in the
    single-scheme path, so the panel keeps the kernel/interpret/reference
    bit-identity -- but it is a *different* (equally valid) bit stream
    than two separate launches, whose rows sit at different ids.
    """
    B, K = lam_rows.shape
    lam = lam_rows.astype(jnp.float32)
    inv_lam = 1.0 / lam
    k0, k1 = seed[0], seed[1]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)
    known_col = jnp.reshape(known_flags, (B, 1)) > 0
    sched_at = None if sched is None else (lambda r: sched_row(sched, r))

    def cond(st):
        return st["active"].any()

    def body(st):
        return round_body(st, lam, inv_lam, row_ids, k0, k1, K=K, cap=cap,
                          threshold=threshold, known=False,
                          max_iter=max_iter, sched_at=sched_at,
                          known_col=known_col)

    st = jax.lax.while_loop(cond, body,
                            init_state(B, K, n0, threshold, False, lam=lam,
                                       with_round=sched is not None))
    return final_phase(st, lam, inv_lam, row_ids, k0, k1, K=K, known=False,
                       max_iter=max_iter, sched=sched, sched_gather=True,
                       known_col=known_col)


# ---------------------------------------------------------------------------
# batched Gamma rows (the MDS L-sweep primitive)
# ---------------------------------------------------------------------------

def gamma_rows_reference(shape_rows: jnp.ndarray, scale_rows: jnp.ndarray,
                         seed: jnp.ndarray, *,
                         boost: bool = True) -> jnp.ndarray:
    """Counter-based ``Gamma(shape) * scale`` over an ``(R, K)`` matrix in
    one pass (round namespace 0 -- each call gets a fresh seed).
    ``shape_rows``/``scale_rows`` broadcast against each other.  With
    ``boost=False`` (every shape >= 3, the MDS regime) only the Box-Muller
    pair is generated -- one Threefry call per element instead of three.
    """
    R, K = jnp.broadcast_shapes(shape_rows.shape, scale_rows.shape)
    k0, k1 = seed[0], seed[1]
    c0 = jax.lax.broadcasted_iota(jnp.int32, (R, 1), 0).astype(jnp.uint32)
    worker = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    c1 = (worker * N_PAIRS).astype(jnp.uint32)
    a0, a1 = threefry2x32(k0, k1, c0, c1)
    z = _box_muller(uniform01(a0), uniform01(a1))
    alpha = jnp.broadcast_to(shape_rows, (R, K)).astype(jnp.float32)
    scale = scale_rows.astype(jnp.float32)
    if not boost:
        d = alpha - jnp.float32(1.0 / 3.0)
        c = jnp.maximum(1.0 + z / (3.0 * jnp.sqrt(d)), 0.0)
        return d * c ** 3 * scale
    b0, b1 = threefry2x32(k0, k1, c0, c1 + _U32(1))
    c0_, _ = threefry2x32(k0, k1, c0, c1 + _U32(2))
    return gamma_mt(z, uniform01(b0), uniform01(b1), uniform01(c0_),
                    alpha, scale)
