"""Pallas kernel for the work-exchange exchange-round pipeline.

One ``pallas_call`` fuses counter-based bit generation (Threefry-2x32,
keyed per ``(trial, worker, round)``), the Marsaglia-Tsang Gamma
transform, the per-trial argmin straggler selection, and the normal-limit
Binomial into a single tiled pass over the ``(trials x K)`` grid: grid =
``(B / block_b,)``, each program owns a ``(block_b, K)`` tile of trials
and runs the whole exchange-round ``while_loop`` to completion in VMEM --
state never round-trips to HBM between rounds, and the only HBM traffic
is one read of the rate tile and one write of the three per-trial stats.

Because every draw is a pure function of ``(seed, row, worker, round,
slot)`` (see ``ref.py``, which owns all the math), the kernel is
bit-identical to ``we_rounds_reference`` for any ``block_b``, and padding
rows cannot perturb real ones.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_B = 128


def _we_rounds_kernel(seed_ref, lam_ref, out_ref, *, K: int, block_b: int,
                      n0: float, threshold: float, cap: float, known: bool,
                      max_iter: int):
    _we_rounds_body(seed_ref, lam_ref, None, None, out_ref, K=K,
                    block_b=block_b, n0=n0, threshold=threshold, cap=cap,
                    known=known, max_iter=max_iter)


def _we_rounds_drift_kernel(seed_ref, lam_ref, sched_ref, out_ref, *,
                            K: int, block_b: int, n0: float,
                            threshold: float, cap: float, known: bool,
                            max_iter: int):
    _we_rounds_body(seed_ref, lam_ref, sched_ref, None, out_ref, K=K,
                    block_b=block_b, n0=n0, threshold=threshold, cap=cap,
                    known=known, max_iter=max_iter)


def _we_rounds_panel_kernel(seed_ref, lam_ref, flags_ref, out_ref, *,
                            K: int, block_b: int, n0: float,
                            threshold: float, cap: float, known: bool,
                            max_iter: int):
    _we_rounds_body(seed_ref, lam_ref, None, flags_ref, out_ref, K=K,
                    block_b=block_b, n0=n0, threshold=threshold, cap=cap,
                    known=known, max_iter=max_iter)


def _we_rounds_panel_drift_kernel(seed_ref, lam_ref, sched_ref, flags_ref,
                                  out_ref, *, K: int, block_b: int,
                                  n0: float, threshold: float, cap: float,
                                  known: bool, max_iter: int):
    _we_rounds_body(seed_ref, lam_ref, sched_ref, flags_ref, out_ref, K=K,
                    block_b=block_b, n0=n0, threshold=threshold, cap=cap,
                    known=known, max_iter=max_iter)


def _we_rounds_body(seed_ref, lam_ref, sched_ref, flags_ref, out_ref, *,
                    K: int, block_b: int, n0: float, threshold: float,
                    cap: float, known: bool, max_iter: int):
    k0 = seed_ref[0, 0]
    k1 = seed_ref[0, 1]
    lam = lam_ref[...]
    inv_lam = 1.0 / lam
    # fused-panel mixed mode: per-row known flag, estimator state for all
    known_col = None if flags_ref is None else flags_ref[...] > 0
    if sched_ref is None:
        sched_at = None
    else:
        R = sched_ref.shape[1]

        def sched_at(rnd):
            # direct round-indexed row load from the (block_b, R, K)
            # schedule tile: one dynamic slice per trip instead of the
            # old O(block_b * R * K) one-hot masked sum
            r = jnp.minimum(rnd, R - 1)
            return sched_ref[:, pl.ds(r, 1), :][:, 0, :]
    base = pl.program_id(0) * block_b
    row_ids = base + jax.lax.broadcasted_iota(jnp.int32, (block_b, 1), 0)

    def cond(st):
        return st["active"].any()

    def body(st):
        return ref.round_body(st, lam, inv_lam, row_ids, k0, k1, K=K,
                              cap=cap, threshold=threshold, known=known,
                              max_iter=max_iter, sched_at=sched_at,
                              known_col=known_col)

    st = jax.lax.while_loop(
        cond, body, ref.init_state(block_b, K, n0, threshold, known,
                                   lam=lam, with_round=sched_ref is not None))
    sched = None if sched_ref is None else sched_ref[...]
    t, it, cm = ref.final_phase(st, lam, inv_lam, row_ids, k0, k1, K=K,
                                known=known, max_iter=max_iter, sched=sched,
                                known_col=known_col)
    out_ref[...] = jnp.stack([t, it, cm], axis=1)


def we_rounds_pallas(lam_rows: jnp.ndarray, seed: jnp.ndarray,
                     sched_rows: jnp.ndarray = None,
                     known_flags: jnp.ndarray = None, *,
                     n0: float, threshold: float, cap: float, known: bool,
                     max_iter: int, block_b: int = DEFAULT_BLOCK_B,
                     interpret: bool = False) -> jnp.ndarray:
    """Run the fused round pipeline; returns ``(B, 3)``:
    ``[:, 0] = t_comp``, ``[:, 1] = iterations``, ``[:, 2] = n_comm``.

    ``B`` must be a multiple of ``block_b`` (callers pad -- see
    ``ops.we_rounds_grid``); ``seed`` is a ``(1, 2)`` uint32 array shared
    by every tile.  ``sched_rows`` (optional ``(B, R, K)``) adds the
    drifting-scenario per-round rate schedule as a third input: each
    program carries its tile's ``(block_b, R, K)`` schedule in VMEM and
    reads the current round's rates with one ``pl.ds`` dynamic slice on
    the trip counter (counters are untouched, so drift runs stay
    bit-identical to the reference).  ``known_flags`` (optional ``(B, 1)``
    float32, nonzero = known) is the fused-panel mixed mode: known and
    unknown rows of a whole figure share ONE launch, each row reading its
    own flag (``known`` is then ignored; pass ``known=False``).
    """
    B, K = lam_rows.shape
    assert B % block_b == 0, f"pad B={B} to a multiple of {block_b}"
    kern_fn = {
        (False, False): _we_rounds_kernel,
        (True, False): _we_rounds_drift_kernel,
        (False, True): _we_rounds_panel_kernel,
        (True, True): _we_rounds_panel_drift_kernel,
    }[(sched_rows is not None, known_flags is not None)]
    kernel = functools.partial(kern_fn, K=K, block_b=block_b, n0=n0,
                               threshold=threshold, cap=cap, known=known,
                               max_iter=max_iter)
    in_specs = [
        pl.BlockSpec((1, 2), lambda i: (0, 0)),
        pl.BlockSpec((block_b, K), lambda i: (i, 0)),
    ]
    args = (seed, lam_rows)
    if sched_rows is not None:
        R = sched_rows.shape[1]
        in_specs.append(pl.BlockSpec((block_b, R, K), lambda i: (i, 0, 0)))
        args += (sched_rows,)
    if known_flags is not None:
        in_specs.append(pl.BlockSpec((block_b, 1), lambda i: (i, 0)))
        args += (known_flags,)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 3), jnp.float32),
        interpret=interpret,
    )(*args)
