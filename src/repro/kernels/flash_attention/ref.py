"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """q: (B, Sq, Hq, d); k, v: (B, Sk, Hkv, d); Hq % Hkv == 0.

    Numerically-naive full-materialization reference in fp32.
    """
    B, Sq, Hq, d = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # right-aligned offsets
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)   # fully-masked rows
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return ctx.reshape(B, Sq, Hq, d).astype(q.dtype)
