"""Jit'd wrapper: pallas forward + reference-VJP backward.

``flash_attention`` is a drop-in for the model attention context op.  The
forward uses the Pallas kernel; the backward recomputes attention with the
chunked reference (flash-style memory) and differentiates it -- numerics
identical to ref.py, memory bounded, kernel speed on the fwd/serving path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    scale: float | None = None, interpret: bool = False):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               scale=scale, interpret=interpret)


def _fwd(q, k, v, causal, window, scale, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              scale=scale, interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.attention_ref(q, k, v, causal=causal,
                                          window=window, scale=scale),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
