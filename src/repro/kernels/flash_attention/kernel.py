"""Pallas TPU flash-attention forward kernel (GQA, causal, sliding window).

Tiling: grid = (B * Hq, num_q_blocks, num_k_blocks); the k-block axis is
the innermost (sequential on TPU), so the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across k-steps.  Blocks are
(BLOCK_Q, head_dim) x (BLOCK_K, head_dim), MXU-aligned (multiples of 128
at production sizes; smaller in interpret-mode tests).

Causal block skipping: a (q_blk, k_blk) tile strictly above the diagonal
contributes nothing; the kernel zero-masks it and skips the expensive ops
under ``plgpu-free`` predication via jnp.where -- on real TPU the mask
also gates the MXU op through Mosaic's scalar predication.  The XLA
reference path (models/attention.py) cannot skip; this kernel's saved
FLOPs at long context is one of the §Perf levers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: int,
                      block_q: int, block_k: int, sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + (sk - sq)        # right-aligned positions
    k_start = ki * block_k
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window

    q = q_ref[...].astype(jnp.float32)        # (block_q, d)
    k = k_ref[...].astype(jnp.float32)        # (block_k, d)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                        # (block_q,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_cur = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, Hq, d); k, v: (B, Sk, Hkv, d). Returns (B, Sq, Hq, d)."""
    B, Sq, Hq, d = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block size"
    nq, nk = Sq // block_q, Sk // block_k

    # layout: fold heads into the leading grid axis
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, d)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=Sq, sk=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda h, qi, ki, g=g: (h // g, ki, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda h, qi, ki, g=g: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d),
                               lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, Hq, Sq, d).transpose(0, 2, 1, 3)
