"""Jit'd wrapper for the expert matmul kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import expert_matmul
from .ref import expert_matmul_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def expert_matmul_op(buf, w, interpret: bool = False):
    return expert_matmul(buf, w, interpret=interpret)
