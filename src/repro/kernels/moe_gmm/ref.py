"""Pure-jnp oracle for the batched expert matmul (capacity-buffer MoE)."""
from __future__ import annotations

import jax.numpy as jnp


def expert_matmul_ref(buf: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """buf: (E, C, D); w: (E, D, F) -> (E, C, F), fp32 accumulation."""
    out = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                     w.astype(jnp.float32))
    return out.astype(buf.dtype)
