"""Pallas TPU batched expert matmul: (E, C, D) x (E, D, F) -> (E, C, F).

This is the compute hot spot of the capacity-buffer MoE path (models/moe):
each expert's token slab times its FFN weight.  Grid = (E, C/bc, F/bf,
D/bd) with the contraction axis innermost; a VMEM fp32 accumulator
persists across the D-steps.  Block shapes default to MXU-aligned 128s;
the expert axis maps to the outer grid so an expert's weight tile streams
HBM->VMEM once per (C-block, F-block) pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def expert_matmul(buf: jnp.ndarray, w: jnp.ndarray,
                  block_c: int = 128, block_f: int = 128,
                  block_d: int = 128, interpret: bool = False) -> jnp.ndarray:
    E, C, D = buf.shape
    F = w.shape[2]
    block_c, block_f, block_d = (min(block_c, C), min(block_f, F),
                                 min(block_d, D))
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    grid = (E, C // block_c, F // block_f, D // block_d)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((None, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((None, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(buf, w)
