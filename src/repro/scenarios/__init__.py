"""Pluggable scenario families: the scenario axis behind one registry.

Mirrors ``SCHEME_REGISTRY`` (policies) and ``SAMPLER_BACKENDS`` (draw
pipelines): string-keyed families, each a frozen value that materializes
into ``HetSpec`` rows (and, for non-stationary families, a per-exchange-
round rate schedule).

    from repro.scenarios import SCENARIO_REGISTRY, get_family

    get_family("uniform_random")(K=50, points=[(50.0, 50.0**2/6, 1)])
    get_family("drifting")(K=50, points=[(50.0, 0.0, 1)], kind="regime")
    get_family("trace_corpus")(corpus="default_64x48", K=16,
                               windows=[(0, 0), (16, 12)])
    get_family("hcmm_sweep")(K=50, mu=50.0, sigma2=50.0**2/6, seed=3)

Module map:
    base.py      -- ScenarioFamily protocol, SCENARIO_REGISTRY,
                    scenario_from_dict (incl. PR-4 legacy-shape shim)
    families.py  -- uniform_random / explicit (ported, hash-preserving)
    drifting.py  -- AR(1) / regime-switch rate evolution across rounds
    traces.py    -- measured-trace corpora (results/traces/) +
                    trace_corpus windows
    hcmm.py      -- HCMM-style load sweep with MC-optimized het_mds
                    redundancy per point
"""
from .base import (SCENARIO_REGISTRY, ScenarioFamily, get_family,
                   list_families, register_family, scenario_from_dict)
from .drifting import DriftingScenario
from .families import ExplicitScenario, ScenarioPoint, UniformRandomScenario
from .hcmm import HCMMSweepScenario
from .traces import (DEFAULT_CORPUS, TraceCorpus, TraceCorpusScenario,
                     corpus_path, load_corpus)

__all__ = [
    "SCENARIO_REGISTRY", "ScenarioFamily", "register_family", "get_family",
    "list_families", "scenario_from_dict",
    "ScenarioPoint", "UniformRandomScenario", "ExplicitScenario",
    "DriftingScenario",
    "DEFAULT_CORPUS", "TraceCorpus", "corpus_path", "load_corpus",
    "TraceCorpusScenario",
    "HCMMSweepScenario",
]
