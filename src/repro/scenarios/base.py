"""Scenario-family protocol + registry: the scenario axis as a plugin.

A *scenario family* answers "which heterogeneity situations does this
experiment sweep?"  Each family is a frozen dataclass -- a pure value
with pinned seeds -- that materializes into ``HetSpec`` rows, exactly
like ``SCHEME_REGISTRY`` keys policies and ``SAMPLER_BACKENDS`` keys
draw pipelines:

    from repro.scenarios import get_family, list_families

    fam = get_family("drifting")(K=50, points=[(50.0, 50.0**2 / 6, 1)])
    fam.specs()            # nominal HetSpec per grid point
    fam.rate_schedules()   # (G, R, K) per-round service rates, or None

Contract (enforced by ``tests/test_scenarios.py`` over every registered
family):

* ``specs()`` is deterministic -- every random choice is pinned by a
  seed field, so the family is a value, not a process;
* ``to_dict`` / ``from_dict`` round-trip losslessly, and every knob that
  changes ``specs()`` or ``rate_schedules()`` appears in ``to_dict()``
  (the dict is the family's ``spec_hash`` contribution);
* ``from_dict`` is strict: unknown keys raise ``KeyError`` naming the
  allowed knobs and the registered families (the ``validate_backend``
  behaviour -- typos fail loudly, never silently);
* ``rate_schedules()`` returns the optional ``(G, R, K)`` per-exchange-
  round service-rate schedule (drifting / trace-corpus families); the
  engines hold row ``R - 1`` for rounds beyond the schedule.

Serialization back-compat: the two PR-4 families serialize WITHOUT a
``family`` key (``uniform_random`` -> ``{"K", "points"}``, ``explicit``
-> ``{"explicit"}``) so every pre-existing spec hash and store address
survives the refactor; new families carry ``{"family": <name>, ...}``.
``scenario_from_dict`` dispatches both shapes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Type

import numpy as np

from repro.core.registry import Registry
from repro.core.types import HetSpec

SCENARIO_REGISTRY: Registry[Type["ScenarioFamily"]] = \
    Registry("scenario family")


def register_family(name: str):
    """Class decorator: key a ScenarioFamily subclass under ``name``."""
    def deco(cls: Type["ScenarioFamily"]) -> Type["ScenarioFamily"]:
        SCENARIO_REGISTRY.register(name, cls)
        cls.family = name
        return cls
    return deco


def list_families() -> List[str]:
    return SCENARIO_REGISTRY.names()


def get_family(name: str) -> Type["ScenarioFamily"]:
    return SCENARIO_REGISTRY.get(name)


class ScenarioFamily:
    """Common surface of every scenario family (see module docstring)."""

    family: str = "abstract"

    # -- materialization ----------------------------------------------------

    def specs(self) -> List[HetSpec]:
        """One nominal ``HetSpec`` per grid point, point order preserved."""
        raise NotImplementedError

    def rate_schedules(self) -> Optional[np.ndarray]:
        """Optional ``(G, R, K)`` per-exchange-round service rates.

        ``None`` (the default) means the scenario is stationary: the
        nominal rates hold for the whole run.  Families that drift
        return one ``(R, K)`` schedule per grid point; round ``r >= R``
        holds the last row.  Schedules are consumed by schemes with
        ``supports_rate_schedule`` (the work-exchange variants); single
        -shot schemes run at the nominal (round-0) rates.
        """
        return None

    def __len__(self) -> int:
        return len(self.specs())

    # subclasses also expose ``K`` (the shared worker count) -- as a
    # dataclass field or a property; the base deliberately defines no
    # default so dataclass subclasses don't inherit one

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        raise NotImplementedError

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScenarioFamily":
        raise NotImplementedError


def check_keys(d: Mapping[str, Any], required: frozenset,
               optional: frozenset, family: str) -> None:
    """Strict key validation for family ``from_dict``s: unknown keys
    raise ``KeyError`` listing the family's knobs AND the registered
    families, missing required keys raise ``KeyError`` as well."""
    keys = set(d)
    unknown = keys - required - optional - {"family"}
    if unknown:
        raise KeyError(
            f"unknown scenario key(s) {sorted(unknown)} for family "
            f"{family!r}; allowed {sorted(required | optional)} "
            f"(registered families: {list_families()})")
    missing = required - keys
    if missing:
        raise KeyError(f"scenario family {family!r} is missing required "
                       f"key(s) {sorted(missing)}")


def scenario_from_dict(d: Mapping[str, Any]) -> ScenarioFamily:
    """Deserialize any registered family (legacy PR-4 shapes included).

    Dispatch: an explicit ``family`` key wins; the key-less PR-4 shapes
    ``{"K", "points"}`` and ``{"explicit"}`` route to ``uniform_random``
    / ``explicit`` (the compatibility shim that keeps every pre-refactor
    spec hash addressable).  Anything else -- an unknown family name, or
    extra keys tacked onto a legacy shape -- raises ``KeyError`` listing
    the registered families.
    """
    if not isinstance(d, Mapping):
        raise KeyError(f"scenario grid must be a mapping; got "
                       f"{type(d).__name__} (registered families: "
                       f"{list_families()})")
    if "family" in d:
        return get_family(d["family"]).from_dict(d)
    if "explicit" in d:
        return get_family("explicit").from_dict(d)
    if "points" in d:
        return get_family("uniform_random").from_dict(d)
    raise KeyError(
        f"scenario grid dict has no 'family' key and no legacy "
        f"'points'/'explicit' shape (got keys {sorted(d)}); registered "
        f"families: {list_families()}")


__all__ = [
    "SCENARIO_REGISTRY", "ScenarioFamily", "register_family", "get_family",
    "list_families", "scenario_from_dict", "check_keys",
]
