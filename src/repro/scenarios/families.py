"""The two PR-4 scenario families, ported behind the registry.

``uniform_random`` is the paper's Section-7 family -- one K-worker
``HetSpec.uniform_random`` draw per ``(mu, sigma2, seed)`` point, the
heterogeneity draw pinned per point so the grid is a pure value.
``explicit`` carries literal rate vectors (measured clusters,
adversarial layouts).

Both serialize in the exact PR-4 ``ScenarioGrid`` shape (no ``family``
key), so every pre-refactor ``spec_hash`` and results-store address is
preserved, and the numpy engine consumes the same ``HetSpec`` rows in
the same order -- seed-for-seed bit-identity is structural.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Tuple

import numpy as np

from repro.core.types import HetSpec

from .base import ScenarioFamily, check_keys, register_family

ScenarioPoint = Tuple[float, float, int]        # (mu, sigma2, seed)


@register_family("uniform_random")
@dataclasses.dataclass(frozen=True)
class UniformRandomScenario(ScenarioFamily):
    """Paper Section-7 points: ``(mu, sigma2, seed)`` triples, each
    materializing as ``HetSpec.uniform_random(K, mu, sigma2,
    default_rng(seed))``."""

    K: int
    points: Tuple[ScenarioPoint, ...]

    def __post_init__(self):
        pts = tuple((float(mu), float(s2), int(seed))
                    for mu, s2, seed in self.points)
        if not pts:
            raise ValueError("uniform_random needs at least one point")
        if int(self.K) <= 0:
            raise ValueError("points grids need K > 0")
        object.__setattr__(self, "points", pts)
        object.__setattr__(self, "K", int(self.K))

    def __len__(self) -> int:
        return len(self.points)

    def specs(self) -> List[HetSpec]:
        return [HetSpec.uniform_random(self.K, mu, s2,
                                       np.random.default_rng(seed))
                for mu, s2, seed in self.points]

    def to_dict(self) -> Dict[str, Any]:
        # PR-4 ScenarioGrid shape, no "family" key: hash-preserving
        return {"K": self.K, "points": [list(p) for p in self.points]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "UniformRandomScenario":
        check_keys(d, frozenset({"K", "points"}), frozenset(),
                   "uniform_random")
        return cls(K=int(d["K"]),
                   points=tuple(tuple(p) for p in d["points"]))


@register_family("explicit")
@dataclasses.dataclass(frozen=True)
class ExplicitScenario(ScenarioFamily):
    """Literal ``HetSpec`` rate vectors; ``K`` is inferred and shared."""

    explicit: Tuple[HetSpec, ...]

    def __post_init__(self):
        exp = tuple(self.explicit)
        if not exp:
            raise ValueError("explicit needs at least one HetSpec")
        for h in exp:
            if not isinstance(h, HetSpec):
                raise TypeError(f"explicit entries must be HetSpec; "
                                f"got {type(h).__name__}")
        if any(h.K != exp[0].K for h in exp):
            raise ValueError("explicit HetSpecs must share K")
        object.__setattr__(self, "explicit", exp)

    @property
    def K(self) -> int:
        return self.explicit[0].K

    def __len__(self) -> int:
        return len(self.explicit)

    def specs(self) -> List[HetSpec]:
        return list(self.explicit)

    def to_dict(self) -> Dict[str, Any]:
        # PR-4 ScenarioGrid shape, no "family" key: hash-preserving
        return {"explicit": [h.to_dict() for h in self.explicit]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExplicitScenario":
        check_keys(d, frozenset({"explicit"}), frozenset(), "explicit")
        return cls(explicit=tuple(HetSpec.from_dict(h)
                                  for h in d["explicit"]))


__all__ = ["ScenarioPoint", "UniformRandomScenario", "ExplicitScenario"]
