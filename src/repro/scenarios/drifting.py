"""Drifting-heterogeneity scenarios: rates that evolve across rounds.

The paper evaluates every scheme on rates drawn once and held fixed; its
central claim -- work exchange tracks the work-conservation bound even
when heterogeneity is *unknown and estimated online* -- is only really
stressed when the rates move underneath the estimator.  This family
generates per-exchange-round service-rate schedules in two shapes:

``kind="ar1"``
    Log-rate AR(1): ``x_0 = 0``, ``x_{r+1} = rho x_r + sigma eps``,
    realized rates ``lambda_k exp(x_{r,k})`` -- smooth mean-reverting
    drift (thermal throttling, gradual co-tenancy pressure).

``kind="regime"``
    Two-state Markov switching per worker: a healthy worker drops to
    ``regime_scale`` of its nominal rate with probability
    ``regime_prob`` per round and recovers with probability
    ``recover_prob`` -- abrupt degradation (VM migration, noisy
    neighbours, power caps).

Round 0 always runs at the nominal rates (the base heterogeneity draw),
so the "known heterogeneity" variant genuinely knows the initial truth
and then watches it move; rounds beyond ``rounds`` hold the last row.
The schedule reaches the engines through the ``rate_schedule`` argument
of ``Scheme.mc_grid`` / the sampler backends: service draws follow the
schedule, assignment shares stay nominal (known) or online-estimated
(unknown).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Tuple

import numpy as np

from repro.core.types import HetSpec

from .base import ScenarioFamily, check_keys, register_family
from .families import ScenarioPoint

KINDS = ("ar1", "regime")
# namespace tag for the schedule's rng stream, so the drift draws are
# independent of the base heterogeneity draw pinned by the same seed
_SCHED_STREAM = 0xD81F7


@register_family("drifting")
@dataclasses.dataclass(frozen=True)
class DriftingScenario(ScenarioFamily):
    """AR(1) / regime-switch rate evolution over exchange rounds."""

    K: int
    points: Tuple[ScenarioPoint, ...]       # (mu, sigma2, seed) base draws
    kind: str = "ar1"
    rounds: int = 48
    rho: float = 0.9
    drift_sigma: float = 0.12
    regime_prob: float = 0.08
    regime_scale: float = 0.45
    recover_prob: float = 0.25

    def __post_init__(self):
        pts = tuple((float(mu), float(s2), int(seed))
                    for mu, s2, seed in self.points)
        if not pts:
            raise ValueError("drifting needs at least one point")
        if int(self.K) <= 0:
            raise ValueError("drifting grids need K > 0")
        if self.kind not in KINDS:
            raise ValueError(f"unknown drift kind {self.kind!r}; "
                             f"have {KINDS}")
        if int(self.rounds) < 1:
            raise ValueError("rounds must be >= 1")
        if not 0.0 <= float(self.rho) < 1.0:
            raise ValueError("rho must be in [0, 1)")
        if not 0.0 < float(self.regime_scale) <= 1.0:
            raise ValueError("regime_scale must be in (0, 1]")
        for name in ("drift_sigma", "regime_prob", "recover_prob"):
            if float(getattr(self, name)) < 0.0:
                raise ValueError(f"{name} must be >= 0")
        object.__setattr__(self, "points", pts)
        object.__setattr__(self, "K", int(self.K))
        object.__setattr__(self, "rounds", int(self.rounds))

    def __len__(self) -> int:
        return len(self.points)

    def specs(self) -> List[HetSpec]:
        """Nominal rates: the base draw == the schedule's round 0."""
        return [HetSpec.uniform_random(self.K, mu, s2,
                                       np.random.default_rng(seed))
                for mu, s2, seed in self.points]

    def rate_schedules(self) -> np.ndarray:
        """``(G, rounds, K)`` realized service rates, pinned per point."""
        out = np.empty((len(self.points), self.rounds, self.K))
        for g, ((mu, s2, seed), het) in enumerate(zip(self.points,
                                                      self.specs())):
            rng = np.random.default_rng([seed, _SCHED_STREAM])
            base = het.lambdas
            if self.kind == "ar1":
                x = np.zeros(self.K)
                for r in range(self.rounds):
                    out[g, r] = base * np.exp(x)
                    x = (self.rho * x
                         + self.drift_sigma * rng.standard_normal(self.K))
            else:                               # regime switching
                throttled = np.zeros(self.K, dtype=bool)
                for r in range(self.rounds):
                    out[g, r] = base * np.where(throttled,
                                                self.regime_scale, 1.0)
                    u = rng.uniform(size=self.K)
                    throttled = np.where(throttled,
                                         u >= self.recover_prob,
                                         u < self.regime_prob)
        return np.maximum(out, 1e-9)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "family": "drifting",
            "K": self.K,
            "points": [list(p) for p in self.points],
            "kind": self.kind,
            "rounds": self.rounds,
            "rho": float(self.rho),
            "drift_sigma": float(self.drift_sigma),
            "regime_prob": float(self.regime_prob),
            "regime_scale": float(self.regime_scale),
            "recover_prob": float(self.recover_prob),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "DriftingScenario":
        check_keys(d, frozenset({"K", "points"}),
                   frozenset({"kind", "rounds", "rho", "drift_sigma",
                              "regime_prob", "regime_scale",
                              "recover_prob"}), "drifting")
        kwargs = {k: d[k] for k in ("kind", "rounds", "rho", "drift_sigma",
                                    "regime_prob", "regime_scale",
                                    "recover_prob") if k in d}
        return cls(K=int(d["K"]),
                   points=tuple(tuple(p) for p in d["points"]), **kwargs)


__all__ = ["KINDS", "DriftingScenario"]
