"""Measured-trace corpora + the ``trace_corpus`` scenario family.

A corpus is one committed JSON file under ``results/traces/`` holding a
``(workers, epochs)`` matrix of observed per-epoch service rates
(units/sec) plus provenance metadata.  Corpora are **immutable**: the
name IS the version (a changed matrix must ship under a new name),
which is what lets the ``trace_corpus`` family contribute only its
corpus *name* to the experiment ``spec_hash`` and still promise
reproducibility.

``trace_corpus`` grid points are windows into the corpus -- a worker
offset and an epoch offset -- each materializing as

* a nominal ``HetSpec`` (the window's per-worker mean rates: what a
  scheduler that profiled the cluster beforehand would believe), and
* a per-round rate schedule (the window's actual epoch-by-epoch rates:
  what the cluster really does), consumed by the work-exchange engines
  through ``rate_schedule`` and replayable through the id-aware master
  protocol via ``scheme_spec("trace_replay", **family.trace_replay_
  params(g))``.

The committed ``default_64x48`` corpus is a synthetic *measured-trace
stand-in* (64 workers x 48 one-minute epochs, generated once from a
throttling + co-tenancy model -- see its ``provenance`` field); drop a
real cluster's JSON next to it and every family knob works unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.types import HetSpec

from .base import ScenarioFamily, check_keys, register_family

TRACES_ROOT = Path("results") / "traces"
DEFAULT_CORPUS = "default_64x48"


@dataclasses.dataclass(frozen=True)
class TraceCorpus:
    """One loaded corpus: rates (W, E) + metadata."""

    name: str
    rates: np.ndarray          # (workers, epochs), > 0
    meta: Dict[str, Any]

    @property
    def workers(self) -> int:
        return int(self.rates.shape[0])

    @property
    def epochs(self) -> int:
        return int(self.rates.shape[1])

    def window(self, K: int, worker_offset: int = 0, epoch_start: int = 0,
               epochs: Optional[int] = None) -> np.ndarray:
        """A ``(K, epochs)`` view: workers ``worker_offset ..`` and
        epochs ``epoch_start ..``, both wrapping -- every window is
        valid for any corpus size."""
        if K <= 0:
            raise ValueError("window needs K > 0")
        E = self.epochs if epochs is None else int(epochs)
        if E <= 0:
            raise ValueError("window needs epochs > 0")
        rows = (int(worker_offset) + np.arange(K)) % self.workers
        cols = (int(epoch_start) + np.arange(E)) % self.epochs
        return self.rates[np.ix_(rows, cols)]


def corpus_path(name: str) -> Path:
    """Resolve a corpus name (or literal path) to its JSON file.

    Lookup order: a literal / absolute path, ``results/traces`` under
    the current directory, then under the repo root (so tests and tools
    running from other directories still find committed corpora).
    """
    p = Path(name)
    if p.suffix == ".json" and p.is_file():
        return p
    repo_root = Path(__file__).resolve().parents[3]
    for root in (TRACES_ROOT, repo_root / TRACES_ROOT):
        cand = root / f"{name}.json"
        if cand.is_file():
            return cand
    raise FileNotFoundError(
        f"trace corpus {name!r} not found under {TRACES_ROOT} (cwd or "
        f"repo root); committed corpora live at results/traces/<name>.json")


@functools.lru_cache(maxsize=8)
def _load(path: str) -> TraceCorpus:
    d = json.loads(Path(path).read_text())
    rates = np.asarray(d["rates"], dtype=np.float64)
    if rates.ndim != 2 or rates.size == 0:
        raise ValueError(f"corpus rates must be a (workers, epochs) "
                         f"matrix; got shape {rates.shape}")
    if np.any(rates <= 0) or not np.all(np.isfinite(rates)):
        raise ValueError("corpus rates must be finite and positive")
    rates.setflags(write=False)
    meta = {k: v for k, v in d.items() if k != "rates"}
    return TraceCorpus(name=d.get("name", Path(path).stem), rates=rates,
                       meta=meta)


def load_corpus(name: str = DEFAULT_CORPUS) -> TraceCorpus:
    """Load (and cache) a corpus by name or path."""
    return _load(str(corpus_path(name)))


@register_family("trace_corpus")
@dataclasses.dataclass(frozen=True)
class TraceCorpusScenario(ScenarioFamily):
    """Windows into a measured-trace corpus as a scenario grid.

    ``windows`` is a tuple of ``(worker_offset, epoch_start)`` pairs --
    one grid point per window; ``epochs`` is the window length (and the
    length of the per-round schedule each point contributes).
    """

    corpus: str
    K: int
    windows: Tuple[Tuple[int, int], ...]
    epochs: int = 16

    def __post_init__(self):
        wins = tuple((int(w), int(e)) for w, e in self.windows)
        if not wins:
            raise ValueError("trace_corpus needs at least one window")
        if int(self.K) <= 0:
            raise ValueError("trace_corpus grids need K > 0")
        if int(self.epochs) <= 0:
            raise ValueError("epochs must be > 0")
        object.__setattr__(self, "windows", wins)
        object.__setattr__(self, "K", int(self.K))
        object.__setattr__(self, "epochs", int(self.epochs))

    def __len__(self) -> int:
        return len(self.windows)

    def _window(self, g: int) -> np.ndarray:
        w, e = self.windows[g]
        return load_corpus(self.corpus).window(self.K, w, e, self.epochs)

    def specs(self) -> List[HetSpec]:
        """Nominal rates: the window's per-worker mean (the profile a
        scheduler would have measured up front)."""
        return [HetSpec(self._window(g).mean(axis=1))
                for g in range(len(self.windows))]

    def rate_schedules(self) -> np.ndarray:
        """``(G, epochs, K)`` -- the measured epoch rates, epoch e
        driving exchange round e."""
        return np.stack([self._window(g).T
                         for g in range(len(self.windows))])

    def trace_replay_params(self, g: int) -> Dict[str, Any]:
        """Constructor params replaying point ``g``'s exact window
        through ``get_scheme("trace_replay", ...)``."""
        w, e = self.windows[g]
        return {"corpus": self.corpus, "worker_offset": w,
                "epoch_start": e, "epochs": self.epochs}

    def to_dict(self) -> Dict[str, Any]:
        return {
            "family": "trace_corpus",
            "corpus": self.corpus,
            "K": self.K,
            "windows": [list(w) for w in self.windows],
            "epochs": self.epochs,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TraceCorpusScenario":
        check_keys(d, frozenset({"corpus", "K", "windows"}),
                   frozenset({"epochs"}), "trace_corpus")
        kwargs = {"epochs": int(d["epochs"])} if "epochs" in d else {}
        return cls(corpus=str(d["corpus"]), K=int(d["K"]),
                   windows=tuple(tuple(w) for w in d["windows"]), **kwargs)


__all__ = ["TRACES_ROOT", "DEFAULT_CORPUS", "TraceCorpus", "corpus_path",
           "load_corpus", "TraceCorpusScenario"]
