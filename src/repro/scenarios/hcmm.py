"""HCMM-style load sweeps: load-optimized ``het_mds`` operating points.

"Coded Computation over Heterogeneous Clusters" (Reisizadeh et al.,
HCMM) and heterogeneous-worker coded computation (Sun et al.) study the
regime our ``het_mds`` scheme models: each worker gets a coded load
``l_k`` proportional to its rate with aggregate redundancy ``r``, and
the run completes when the finished workers' loads cover ``N``.  The
axis that moves the optimal redundancy in this unit model is the
*per-worker load* ``N / K``: at a few units per worker, straggler noise
is large relative to the work (Var[T_k]/E[T_k]^2 ~ 1/l_k) and extra
redundancy lets the early finishers cover for the tail (r* ~ 1.25 at
~4 units/worker in the paper's Section-7 population); at hundreds of
units per worker the noise averages out and every duplicated unit just
delays the cover (r* -> 1).

``hcmm_sweep`` materializes that axis: one heterogeneity draw per load
point (pinned derived seeds) and a per-point Monte-Carlo redundancy
optimization (eq.-6-style candidate sweep, also pinned -- the family
stays a pure value) that emits the load-optimized ``het_mds`` operating
point for each scenario:

    fam = HCMMSweepScenario(K=50, mu=50.0, sigma2=50.0**2/6, seed=3)
    fam.specs()               # one HetSpec per load point
    fam.point_N(g)            # the point's total work  loads[g] * K
    fam.operating_points()    # [(HetSpec, N_g, r*), ...]
    fam.het_mds_params(g)     # {"redundancy": r*} for scheme_spec()
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Tuple

import numpy as np

from repro.core.types import HetSpec

from .base import ScenarioFamily, check_keys, register_family

# namespace tags: per-point heterogeneity draws and the optimizer's rng
# stream are independent of each other and of other families
_DRAW_STREAM = 0x4C32
_OPT_STREAM = 0x4C33


@register_family("hcmm_sweep")
@dataclasses.dataclass(frozen=True)
class HCMMSweepScenario(ScenarioFamily):
    """Per-worker-load sweep with per-point MC-optimized ``het_mds``
    redundancy (the HCMM granularity axis)."""

    K: int
    mu: float
    sigma2: float
    seed: int
    loads: Tuple[int, ...] = (4, 16, 64, 256)    # units per worker
    redundancies: Tuple[float, ...] = (1.0, 1.05, 1.1, 1.25, 1.5, 2.0)
    opt_trials: int = 128

    def __post_init__(self):
        loads = tuple(int(x) for x in self.loads)
        rs = tuple(float(r) for r in self.redundancies)
        if not loads or any(x <= 0 for x in loads):
            raise ValueError("loads must be positive units-per-worker")
        if not rs or any(r < 1.0 for r in rs):
            raise ValueError("redundancy candidates must be >= 1")
        if int(self.K) <= 0:
            raise ValueError("hcmm_sweep needs K > 0")
        if int(self.opt_trials) <= 0:
            raise ValueError("opt_trials must be > 0")
        object.__setattr__(self, "K", int(self.K))
        object.__setattr__(self, "mu", float(self.mu))
        object.__setattr__(self, "sigma2", float(self.sigma2))
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(self, "loads", loads)
        object.__setattr__(self, "redundancies", rs)
        object.__setattr__(self, "opt_trials", int(self.opt_trials))

    def __len__(self) -> int:
        return len(self.loads)

    def point_N(self, g: int) -> int:
        """Total work at load point ``g``: ``loads[g] * K`` units."""
        return self.loads[g] * self.K

    def specs(self) -> List[HetSpec]:
        """One pinned Section-7 draw per load point (derived seeds, so
        adding/removing points never perturbs the others)."""
        return [HetSpec.uniform_random(
                    self.K, self.mu, self.sigma2,
                    np.random.default_rng([self.seed, _DRAW_STREAM, g]))
                for g in range(len(self.loads))]

    def optimal_redundancy(self, g: int) -> float:
        """MC-optimized ``het_mds`` redundancy at load point ``g``
        (pinned rng; eq.-6-style candidate sweep over
        ``redundancies``)."""
        from repro.core.schemes import HetMDSScheme
        het = self.specs()[g]
        N = self.point_N(g)
        best = (self.redundancies[0], np.inf)
        for r in self.redundancies:
            rng = np.random.default_rng(
                [self.seed, _OPT_STREAM, g, int(round(r * 1000))])
            ts = HetMDSScheme(redundancy=r)._cover_times(
                het, N, self.opt_trials, rng)
            mean_t = float(ts.mean())
            if mean_t < best[1]:
                best = (r, mean_t)
        return best[0]

    def operating_points(self) -> List[Tuple[HetSpec, int, float]]:
        """The load-optimized ``het_mds`` operating point per scenario:
        ``(HetSpec, N, redundancy*)`` triples."""
        return [(het, self.point_N(g), self.optimal_redundancy(g))
                for g, het in enumerate(self.specs())]

    def het_mds_params(self, g: int) -> Dict[str, Any]:
        """Constructor params for ``scheme_spec("het_mds", ...)`` at the
        point's load-optimized redundancy."""
        return {"redundancy": self.optimal_redundancy(g)}

    def to_dict(self) -> Dict[str, Any]:
        return {
            "family": "hcmm_sweep",
            "K": self.K,
            "mu": self.mu,
            "sigma2": self.sigma2,
            "seed": self.seed,
            "loads": list(self.loads),
            "redundancies": list(self.redundancies),
            "opt_trials": self.opt_trials,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "HCMMSweepScenario":
        check_keys(d, frozenset({"K", "mu", "sigma2", "seed"}),
                   frozenset({"loads", "redundancies", "opt_trials"}),
                   "hcmm_sweep")
        kwargs: Dict[str, Any] = {}
        if "opt_trials" in d:
            kwargs["opt_trials"] = int(d["opt_trials"])
        for k in ("loads", "redundancies"):
            if k in d:
                kwargs[k] = tuple(d[k])
        return cls(K=int(d["K"]), mu=float(d["mu"]),
                   sigma2=float(d["sigma2"]), seed=int(d["seed"]), **kwargs)


__all__ = ["HCMMSweepScenario"]
