"""Deterministic synthetic data: seeded token streams per (epoch, unit).

Units are addressable by id so the work-exchange scheduler can ship them
between workers without coordination beyond the id (the "sharded data
store" of DESIGN §3): unit id -> deterministic content, anywhere.
"""
from __future__ import annotations

import numpy as np


def unit_tokens(unit_id: int, batch: int, seq_len: int, vocab: int,
                seed: int = 0) -> dict:
    """One microbatch unit: (tokens, labels) with next-token labels."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, unit_id]))
    toks = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int64)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def structured_unit(unit_id: int, batch: int, seq_len: int, vocab: int,
                    seed: int = 0) -> dict:
    """Learnable synthetic task: next token = (3 * tok + 7) % vocab with
    occasional noise -- a model must actually learn to reduce this loss
    (used by the end-to-end training example to show loss descent)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, unit_id, 1]))
    first = rng.integers(0, vocab, size=(batch, 1), dtype=np.int64)
    toks = np.empty((batch, seq_len + 1), dtype=np.int64)
    toks[:, :1] = first
    for t in range(1, seq_len + 1):
        toks[:, t] = (3 * toks[:, t - 1] + 7) % vocab
    noise = rng.random((batch, seq_len + 1)) < 0.02
    toks[noise] = rng.integers(0, vocab, size=int(noise.sum()))
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}
