from .pipeline import HetShardedLoader, UnitStore
from .synthetic import structured_unit, unit_tokens

__all__ = ["HetShardedLoader", "UnitStore", "structured_unit", "unit_tokens"]
