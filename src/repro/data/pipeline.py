"""Heterogeneity-aware data pipeline.

``UnitStore`` maps unit ids to microbatch contents (synthetic here; a
sharded object store in production).  ``HetShardedLoader`` tracks, per
training step, which worker group owns which units; re-ownership between
steps is decided by the work-exchange scheduler and the loader counts the
re-fetch traffic (the paper's N_comm, eq. 1-2, in tokens)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .synthetic import structured_unit, unit_tokens


@dataclasses.dataclass
class UnitStore:
    unit_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    structured: bool = False

    def fetch(self, unit_id: int) -> dict:
        fn = structured_unit if self.structured else unit_tokens
        return fn(unit_id, self.unit_batch, self.seq_len, self.vocab,
                  self.seed)

    def tokens_per_unit(self) -> int:
        return self.unit_batch * self.seq_len


class HetShardedLoader:
    """Tracks unit ownership across steps; counts re-fetch traffic."""

    def __init__(self, store: UnitStore, n_workers: int):
        self.store = store
        self.K = n_workers
        self._owned: List[set] = [set() for _ in range(n_workers)]
        self.refetched_units = 0
        self.refetched_tokens = 0

    def assign(self, worker: int, unit_ids: Sequence[int]) -> List[dict]:
        """Feed units to a worker; fetch-and-count those it doesn't hold."""
        out = []
        for u in unit_ids:
            if u not in self._owned[worker]:
                self.refetched_units += 1
                self.refetched_tokens += self.store.tokens_per_unit()
                self._owned[worker].add(u)
            out.append(self.store.fetch(u))
        return out

    def touch(self, worker: int, unit_ids: Sequence[int]) -> None:
        """Ownership/refetch accounting without materializing batches --
        what the batched scan engine uses (it fetches units itself, in
        canonical order, one stacked dispatch per group)."""
        for u in unit_ids:
            if u not in self._owned[worker]:
                self.refetched_units += 1
                self.refetched_tokens += self.store.tokens_per_unit()
                self._owned[worker].add(u)

    def prefetch(self, worker: int, unit_ids: Sequence[int]) -> None:
        """Initial placement (not counted -- paper counts from epoch 2)."""
        self._owned[worker].update(unit_ids)

    def evict(self, worker: int) -> None:
        self._owned[worker].clear()
