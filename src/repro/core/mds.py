"""Optimized (K, L) MDS-coded computation baseline (paper Section 3).

Two evaluation paths:
  * exact      -- eq. (3)-(6) via the Erlang order-statistics recursion
                  (``core.erlang``); tractable for small K and m = N/L.
  * monte carlo -- ``core.simulator.mds_optimize``; used at paper scale
                  (N = 1e6), where the combinatorial formula is infeasible
                  (the paper's own simulations are MC as well).
"""
from __future__ import annotations

import numpy as np

from . import erlang, simulator
from .types import HetSpec


def mds_mean_time_exact(het: HetSpec, N: int, L: int) -> float:
    """E[T^MDS(L)] = mu_(L, ceil(N/L)) -- exact, small instances only."""
    m = int(np.ceil(N / L))
    return erlang.erlang_order_stat_mean(het, m, L)


def mds_optimize_exact(het: HetSpec, N: int) -> tuple[int, float]:
    """Eq. (6) with the exact recursion."""
    best = (1, np.inf)
    for L in range(1, het.K + 1):
        t = mds_mean_time_exact(het, N, L)
        if t < best[1]:
            best = (L, t)
    return best


def mds_optimize_mc(het: HetSpec, N: int, trials: int,
                    rng: np.random.Generator) -> tuple[int, float]:
    return simulator.mds_optimize(het, N, trials, rng)
