"""Unit-level work-exchange master protocol (Algorithms 1 & 3), id-aware.

``simulator.py`` is the fast count-based Monte-Carlo engine for the paper's
figures; this module is the *executable* protocol the training/serving
runtimes drive.  It tracks concrete unit ids so that

  * real computations (per-microbatch gradients) can be attached to units,
  * N_comm is counted by actual unit movement (a worker keeping its own
    leftover costs nothing -- eq. 1),
  * failures/elasticity reduce to returning a worker's unfinished ids to
    the pool and re-running the same assignment rule.

The master is deliberately synchronous-at-iteration-boundaries, mirroring
the paper's stop-flag protocol adapted to SPMD unit granularity (DESIGN §3).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .assignment import largest_remainder_round, proportional_assignment
from .estimator import CumulativeRateEstimator, RateEstimator


@dataclasses.dataclass
class IterationLog:
    assignment_sizes: np.ndarray
    done_counts: np.ndarray
    elapsed: float
    moved_units: int          # N_comm contribution of this epoch


@dataclasses.dataclass
class Assignment:
    """Per-worker ordered unit queues plus the master's wait mode."""
    queues: List[List[int]]
    wait_all: bool            # final phase below the cutting threshold

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(q) for q in self.queues], dtype=np.int64)


class MasterScheduler:
    """Work-exchange master (Algorithm 1 when rates given, Algorithm 3 when not)."""

    def __init__(self, unit_ids: Sequence[int], K: int,
                 rates: Optional[np.ndarray] = None,
                 estimator: Optional[RateEstimator] = None,
                 threshold_frac: float = 0.01,
                 storage_cap_frac: Optional[float] = 1.0,
                 prior_rate: float = 1.0):
        self.K = K
        self.N = len(unit_ids)
        self.known = rates is not None
        self.rates = None if rates is None else np.asarray(rates, np.float64)
        self.estimator = estimator or CumulativeRateEstimator(K, prior_rate)
        self.threshold = threshold_frac * self.N / K
        self.cap = (None if self.known or storage_cap_frac is None
                    else int(np.ceil(storage_cap_frac * self.N / K)))
        self.pool: List[int] = list(unit_ids)       # unassigned units
        self.holding: List[List[int]] = [[] for _ in range(K)]  # leftover ids
        self.alive = np.ones(K, dtype=bool)
        self.done_ids: List[int] = []
        self.logs: List[IterationLog] = []
        self.n_comm = 0
        self._finished = False

    # -- assignment -------------------------------------------------------

    def _rule_sizes(self, n_rem: int) -> np.ndarray:
        rates = self.rates if self.known else self.estimator.rates()
        rates = np.where(self.alive, rates, 0.0)
        sizes = largest_remainder_round(rates, n_rem)
        if self.cap is not None:
            sizes = np.minimum(sizes, self.cap)   # Alg. 3 storage cap; carry rest
        return sizes

    def next_assignment(self) -> Optional[Assignment]:
        """Build the next epoch's queues, or None if all units are done."""
        n_rem = len(self.pool) + sum(len(h) for h in self.holding)
        if n_rem == 0:
            self._finished = True
            return None
        wait_all = n_rem <= self.threshold
        sizes = self._rule_sizes(n_rem)
        if sizes.sum() == 0:     # degenerate rounding; push everything out
            sizes = largest_remainder_round(self.alive.astype(float), n_rem)
        # Workers first keep their own leftover (free), then the master ships
        # surplus leftover back to the pool and pool units to deficit workers.
        queues: List[List[int]] = [[] for _ in range(self.K)]
        moved = 0
        for k in range(self.K):
            keep = self.holding[k][: int(sizes[k])]
            spill = self.holding[k][int(sizes[k]):]
            queues[k] = list(keep)
            self.pool.extend(spill)
            self.holding[k] = []
        for k in range(self.K):
            deficit = int(sizes[k]) - len(queues[k])
            if deficit > 0:
                ship = self.pool[:deficit]
                del self.pool[:deficit]
                queues[k].extend(ship)
                if self.logs:          # eq. (2): initial assignment is free
                    moved += len(ship)
        self.n_comm += moved
        self._pending = Assignment(queues=queues, wait_all=wait_all)
        self._pending_moved = moved
        return self._pending

    # -- feedback ---------------------------------------------------------

    def report(self, done_counts: Sequence[int], elapsed: float) -> None:
        """Workers processed the first ``done_counts[k]`` units of their queue."""
        a = self._pending
        done_counts = np.asarray(done_counts, dtype=np.int64)
        for k in range(self.K):
            q = a.queues[k]
            d = int(done_counts[k])
            if d > len(q):
                raise ValueError(f"worker {k} reported {d} > assigned {len(q)}")
            self.done_ids.extend(q[:d])
            self.holding[k] = q[d:]
        self.estimator.update(done_counts, elapsed)
        self.logs.append(IterationLog(a.sizes, done_counts, elapsed,
                                      self._pending_moved))
        if len(self.done_ids) == self.N:
            self._finished = True

    # -- fault tolerance / elasticity --------------------------------------

    def mark_failed(self, k: int) -> None:
        """Worker k died: return its unfinished units; stop assigning to it."""
        self.alive[k] = False
        self.pool.extend(self.holding[k])
        self.holding[k] = []

    def revive(self, k: int) -> None:
        self.alive[k] = True

    # -- stats --------------------------------------------------------------

    @property
    def finished(self) -> bool:
        return self._finished

    @property
    def iterations(self) -> int:
        return len(self.logs)

    @property
    def t_comp(self) -> float:
        return float(sum(l.elapsed for l in self.logs))

    def estimated_rates(self) -> np.ndarray:
        return self.rates if self.known else self.estimator.rates()


class CoverScheduler:
    """One-shot replicated master: coded redundancy instead of exchange.

    The registry scheduler surface for ``gradient_coded`` (fractional
    repetition, Tandon-style): every unit is replicated ``s + 1`` times
    across disjoint worker groups, the single epoch dispatches the
    replicated queues, and the run completes at the earliest instant the
    fully-finished workers jointly *cover* all N units -- up to ``s``
    stragglers (or failures) tolerated with zero coordination rounds.

    Unlike ``MasterScheduler`` the feedback is a whole-queue finish-time
    vector (``VirtualWorkerPool.finish_times``), resolved via
    ``resolve(t_k)``; executors branch on the ``cover`` attribute.
    ``n_comm`` is the shipped redundancy (sizes.sum() - N, eq. 2's
    analogue for coded schemes).
    """

    cover = True

    def __init__(self, unit_ids: Sequence[int], K: int, s: int = 1):
        from .coded import GradientCoding
        self.N = len(unit_ids)
        self.K = int(K)
        self.s = int(s)
        K_used = self.K - self.K % (self.s + 1)   # FR needs (s+1) | K
        if K_used < self.s + 1:
            raise ValueError(f"need >= {self.s + 1} workers for s={self.s}")
        ids = list(unit_ids)
        owners = GradientCoding(K=K_used, s=self.s).assignment(self.N)
        self.queues: List[List[int]] = [[ids[i] for i in o] for o in owners]
        self.queues += [[] for _ in range(self.K - K_used)]
        self.n_comm = int(sum(len(q) for q in self.queues) - self.N)
        self.dead = np.zeros(self.K, dtype=bool)
        self._dispatched = False
        self._finished = False
        self._t_comp = 0.0

    def next_assignment(self) -> Optional[Assignment]:
        if self._dispatched:
            return None
        self._dispatched = True
        return Assignment(queues=[list(q) for q in self.queues],
                          wait_all=True)

    def resolve(self, t_k: np.ndarray):
        """Walk finishers in time order until every unit is covered.

        Returns ``(t_done, done_counts, groups)`` where ``groups`` is the
        per-worker list of units whose *first* replica to finish came
        from that worker -- exactly one credited replica per unit, so the
        union is the full step (work conserved)."""
        t_k = np.asarray(t_k, dtype=np.float64)
        order = np.argsort(t_k, kind="stable")
        covered: set = set()
        done = np.zeros(self.K, dtype=np.int64)
        groups: List[tuple] = []
        t_done = None
        for w in order:
            if not np.isfinite(t_k[w]) or not self.queues[w]:
                continue
            fresh = [u for u in self.queues[w] if u not in covered]
            covered.update(fresh)
            done[w] = len(fresh)
            if fresh:
                groups.append((int(w), fresh))
            if len(covered) == self.N:
                t_done = float(t_k[w])
                break
        if t_done is None:
            raise RuntimeError(
                f"coverage impossible: {len(covered)}/{self.N} units "
                f"reachable (more than s={self.s} workers lost?)")
        self._finished = True
        self._t_comp = t_done
        return t_done, done, groups

    def mark_failed(self, k: int) -> None:
        self.dead[int(k)] = True

    @property
    def finished(self) -> bool:
        return self._finished

    @property
    def iterations(self) -> int:
        return 1 if self._finished else 0

    @property
    def t_comp(self) -> float:
        return self._t_comp
