"""Pluggable sampler backends for the work-exchange Monte-Carlo engine.

The engine's hot loop is a round pipeline -- batched Gamma service draws,
argmin over workers, Binomial done-counts -- repeated for ~60 exchange
rounds.  Two backends implement it behind one grid-shaped contract:

``numpy``
    The exact integer-unit engine (largest-remainder assignments, exact
    ``Generator.gamma`` / ``Generator.binomial`` draws).  Bit-identical to
    the PR-1 trial-vectorized engine: with a single heterogeneity spec it
    consumes randomness in exactly the order of
    ``schemes.work_exchange_mc_batched``, which itself reduces to the
    scalar reference at ``trials=1``.

``jax``
    One jitted function fusing the whole pipeline -- assignment, Gamma,
    argmin, Binomial, estimator update -- with a ``lax.while_loop`` over
    exchange rounds and the ``(grid x trials)`` batch as the leading axis.
    It samples the paper's *fluid relaxation*: assignments are the exact
    real-valued proportional shares (the paper's eqs. 16/18/22 before
    unit rounding), Gamma draws use a mean-exact Marsaglia-Tsang transform
    (with the small-shape boost ``Gamma(a) = Gamma(a+1) * U^{1/a}``), and
    Binomial done-counts use their mean/variance-exact normal limit.
    Statistically equivalent to ``numpy`` at Monte-Carlo tolerance (unit
    rounding perturbs real shares by <1 unit in thousands); NOT
    bit-identical, and float32.  ``jax.random.gamma``'s per-element
    rejection loop is ~100x slower than NumPy on CPU, so the transform
    sampler is what makes the fused engine a win rather than a loss.

Backends are registered in ``SAMPLER_BACKENDS`` and selected per call
(``mc(..., backend="jax")``) or globally (``REPRO_SAMPLER_BACKEND=jax``);
the default is ``numpy``.  The grid contract returns flat per-run arrays
``(t_comp, iterations, n_comm)`` of length ``G * trials`` in
grid-major order; ``repro.core.schemes`` reshapes them into per-spec
``MCReport`` rows.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Literal, Tuple

import numpy as np

from .assignment import (capped_proportional_assignment_batch,
                         largest_remainder_round_batch)
from .types import ExchangeConfig

ENV_VAR = "REPRO_SAMPLER_BACKEND"
DEFAULT_BACKEND = "numpy"

# (t_comp, iterations, n_comm), each shape (G * trials,), grid-major
GridArrays = Tuple[np.ndarray, np.ndarray, np.ndarray]
WEGridFn = Callable[[np.ndarray, int, ExchangeConfig, int,
                     np.random.Generator, str], GridArrays]


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplerBackend:
    """One RNG/compute backend behind the work-exchange MC pipeline."""

    name: str
    work_exchange_grid: WEGridFn
    description: str = ""

    def available(self) -> bool:
        return _BACKEND_AVAILABLE.get(self.name, lambda: True)()


SAMPLER_BACKENDS: Dict[str, SamplerBackend] = {}
_BACKEND_AVAILABLE: Dict[str, Callable[[], bool]] = {}


def register_backend(backend: SamplerBackend,
                     available: Callable[[], bool] = lambda: True) -> None:
    if backend.name in SAMPLER_BACKENDS:
        raise ValueError(f"sampler backend {backend.name!r} already "
                         f"registered")
    SAMPLER_BACKENDS[backend.name] = backend
    _BACKEND_AVAILABLE[backend.name] = available


def list_backends() -> List[str]:
    return sorted(SAMPLER_BACKENDS)


def get_backend(name: str) -> SamplerBackend:
    if name not in SAMPLER_BACKENDS:
        raise KeyError(f"unknown sampler backend {name!r}; "
                       f"have {list_backends()}")
    return SAMPLER_BACKENDS[name]


def resolve_backend(backend: str | None = None) -> str:
    """Explicit kwarg > ``REPRO_SAMPLER_BACKEND`` > ``numpy`` default."""
    name = backend or os.environ.get(ENV_VAR) or DEFAULT_BACKEND
    b = get_backend(name)      # raises on unknown names, env or kwarg
    if not b.available():
        raise RuntimeError(
            f"sampler backend {name!r} is registered but unavailable "
            f"(is its runtime installed?); set {ENV_VAR} or pass "
            f"backend= one of {[n for n in list_backends() if get_backend(n).available()]}")
    return name


# ---------------------------------------------------------------------------
# numpy backend: exact integer-unit engine, generalized to per-row rates
# ---------------------------------------------------------------------------

def work_exchange_grid_numpy(lam: np.ndarray, N: int, cfg: ExchangeConfig,
                             trials: int, rng: np.random.Generator,
                             capped_mode: Literal["carry", "waterfill"]
                             = "carry") -> GridArrays:
    """Exact batched engine over a ``(G, K)`` heterogeneity grid.

    Every row of the ``(G * trials, K)`` state is one independent run of
    Algorithm 1/3; rows are grid-major (``g * trials + t``).  With
    ``G == 1`` the randomness is consumed in exactly the order of the
    PR-1 trial-batched engine (and hence, at ``trials == 1``, of the
    scalar reference) -- the bit-identity the tests pin down.
    """
    lam = np.asarray(lam, dtype=np.float64)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    G, K = lam.shape
    T = int(trials)
    B = G * T
    known = cfg.known_heterogeneity
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or known
           else int(np.ceil(cfg.storage_cap_frac * N / K)))
    lam_rows = np.repeat(lam, T, axis=0)          # (B, K), grid-major
    inv_lam = 1.0 / lam_rows

    est_done = np.zeros((B, K))
    est_time = np.zeros(B)
    lam_hat = np.ones((B, K))
    n_rem = np.full(B, N, dtype=np.int64)
    n_left_prev = np.zeros((B, K), dtype=np.int64)
    n_done = np.zeros((B, K), dtype=np.int64)
    t_comp = np.zeros(B)
    n_comm = np.zeros(B)
    iters = np.zeros(B, dtype=np.int64)
    in_loop = np.ones(B, dtype=bool)

    while True:
        # compact every pass to the runs still above the threshold; row
        # order is ascending, so a lone run draws in exactly the scalar
        # order and the tail of long-running runs stays cheap
        in_loop &= (n_rem > threshold) & (iters < cfg.max_iterations)
        idx = np.flatnonzero(in_loop)
        if idx.size == 0:
            break
        n = idx.size
        rates = lam_rows[idx] if known else lam_hat[idx]
        rem = n_rem[idx]
        if np.isinf(cap):
            assign = largest_remainder_round_batch(rates, rem)
        elif capped_mode == "waterfill":
            assign = capped_proportional_assignment_batch(rates, rem, cap)
        else:
            assign = np.minimum(largest_remainder_round_batch(rates, rem),
                                cap)
        assigned = assign.sum(axis=1)
        carried = rem - assigned
        # degenerate rounding: that run leaves the loop without drawing
        live = assigned > 0
        if not live.all():
            in_loop[idx[~live]] = False
            idx, assign, carried = idx[live], assign[live], carried[live]
            n = idx.size
            if n == 0:
                break

        started = iters[idx] > 0
        comm_add = np.maximum(assign - n_left_prev[idx], 0).sum(axis=1)
        n_comm[idx] += np.where(started, comm_add, 0.0)

        # batched iteration outcome (same draw order as the scalar path)
        scale = inv_lam[idx]
        busy = assign > 0
        if busy.all():      # the common case: draw the full matrix directly
            t_k = rng.gamma(shape=assign, scale=scale)
        else:
            t_k = np.full((n, K), np.inf)
            t_k[busy] = rng.gamma(shape=assign[busy], scale=scale[busy])
        finisher = np.argmin(t_k, axis=1)
        rows = np.arange(n)
        t_star = t_k[rows, finisher]
        done = np.zeros((n, K), dtype=np.int64)
        done[rows, finisher] = assign[rows, finisher]
        others = busy.copy()
        others[rows, finisher] = False
        o_rows, o_cols = np.nonzero(others)      # C order == scalar draw order
        if o_rows.size:
            n_oth = np.maximum(assign[o_rows, o_cols] - 1, 0)
            p_oth = np.clip(t_star[o_rows] / t_k[o_rows, o_cols], 0.0, 1.0)
            done[o_rows, o_cols] = rng.binomial(n_oth, p_oth)

        iters[idx] += 1
        t_comp[idx] += t_star
        n_done[idx] += done
        leftover = assign - done
        n_left_prev[idx] = leftover
        n_rem[idx] = carried + leftover.sum(axis=1)
        if not known:        # online estimate, eq. (23)
            ed = est_done[idx] + done
            et = est_time[idx] + t_star
            est_done[idx] = ed
            est_time[idx] = et
            lam_hat[idx] = np.where(ed > 0,
                                    ed / np.maximum(et, 1e-300)[:, None], 1.0)

    # final phase below the threshold: assign the remainder, wait for all
    idx = np.flatnonzero(n_rem > 0)
    if idx.size:
        n = idx.size
        rates = lam_rows[idx] if known else lam_hat[idx]
        assign = largest_remainder_round_batch(rates, n_rem[idx])
        comm_add = np.maximum(assign - n_left_prev[idx], 0).sum(axis=1)
        n_comm[idx] += np.where(iters[idx] > 0, comm_add, 0.0)
        scale = inv_lam[idx]
        busy = assign > 0
        if busy.all():
            t_k = rng.gamma(shape=assign, scale=scale)
        else:
            t_k = np.zeros((n, K))
            t_k[busy] = rng.gamma(shape=assign[busy], scale=scale[busy])
        t_comp[idx] += t_k.max(axis=1)
        n_done[idx] += assign
        iters[idx] += 1

    totals = n_done.sum(axis=1)
    if not (totals == N).all():
        bad = int(np.flatnonzero(totals != N)[0])
        raise AssertionError(f"work conservation violated in run {bad}: "
                             f"processed {int(totals[bad])} of {N}")
    return t_comp, iters.astype(np.float64), n_comm


# ---------------------------------------------------------------------------
# jax backend: one jitted fluid-relaxation pipeline
# ---------------------------------------------------------------------------

def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


_JAX_ENGINE = None           # built once; jax.jit caches per (B, K) shape


def _build_jax_engine():
    """Construct the jitted grid engine (imports jax lazily)."""
    import jax
    import jax.numpy as jnp

    def gamma_mt_large(key, alpha, inv_rate):
        """Raw Marsaglia-Tsang transform d*(1 + Z/(3 sqrt(d)))^3 with
        d = alpha - 1/3: mean-exact, variance alpha + 1/9, for alpha >= 3
        (there the rejection step it omits accepts with prob > 99.8% and
        the cube-root argument goes negative with prob < 2e-7)."""
        d = alpha - 1.0 / 3.0
        z = jax.random.normal(key, alpha.shape)
        c = jnp.maximum(1.0 + z / (3.0 * jnp.sqrt(d)), 0.0)
        return d * c ** 3 * inv_rate

    def _boosted(key, alpha, inv_rate, levels):
        """Boost sub-3 shapes through the exact identity
        Gamma(a) = Gamma(a+1) * U^(1/a), chained ``levels`` times, so the
        MT transform always runs at shape alpha + levels (>= 3 whenever
        alpha >= 3 - levels).  The chained mean telescopes exactly:
        (alpha + levels) * alpha/(alpha + levels) = alpha."""
        kz, ku = jax.random.split(key)
        boost = alpha < 3.0
        a = jnp.where(boost, alpha + levels, alpha)
        u = jax.random.uniform(ku, (levels,) + alpha.shape, minval=1e-12)
        inv_shapes = jnp.stack([1.0 / jnp.maximum(alpha + i, 1e-12)
                                for i in range(levels)])
        pow_u = jnp.exp((jnp.log(u) * inv_shapes).sum(0))
        return gamma_mt_large(kz, a, inv_rate) * jnp.where(boost, pow_u, 1.0)

    def gamma_mt_boost2(key, alpha, inv_rate):
        """Mean-exact for alpha >= 1 (callers mask smaller elements)."""
        return _boosted(key, alpha, inv_rate, 2)

    def gamma_mt(key, alpha, inv_rate):
        """Mean-exact MT transform sampler for any alpha > 0."""
        return _boosted(key, alpha, inv_rate, 3)

    def binomial_normal(key, n, p):
        """Binomial(n, p) in its mean/variance-exact normal limit (fluid
        done-counts stay real-valued; clipping to [0, n] is the only
        deviation and is negligible for the unit counts in play)."""
        mean = n * p
        std = jnp.sqrt(jnp.maximum(n * p * (1.0 - p), 0.0))
        z = jax.random.normal(key, n.shape)
        return jnp.clip(mean + z * std, 0.0, n)

    def engine(key, lam, n0, threshold, cap, known, max_iter):
        # ``known`` is STATIC: the known-heterogeneity engine compiles
        # with the whole online-estimator block dead-code-eliminated
        B, K = lam.shape
        inv_lam = 1.0 / lam
        lam_sum = lam.sum(1)

        def cond(st):
            return st["active"].any()

        def body(st):
            key, kg, kb = jax.random.split(st["key"], 3)
            if known:
                share = lam * (st["n_rem"] / lam_sum)[:, None]
            else:
                rates = st["lam_hat"]
                share = rates * (st["n_rem"] / rates.sum(1))[:, None]
            assign = jnp.minimum(share, cap)
            # integer engine's "assign > 0" becomes "at least half a unit";
            # sub-half slivers are carried as leftover, and a round where
            # nothing reaches half a unit exits like degenerate rounding
            busy = assign > 0.5
            # tiered per-round gamma path keyed on the smallest live share:
            # >= 3 needs no boost (one normal, no uniforms), >= 1 a 2-chain
            # boost, only sub-unit rounds pay the full 3-chain -- the bit
            # stream is the engine's bottleneck, so draw no more than the
            # round's smallest shape requires
            live_min = jnp.where(busy & st["active"][:, None], assign,
                                 jnp.inf).min()
            t_raw = jax.lax.cond(
                live_min >= 3.0, gamma_mt_large,
                lambda k, a, i: jax.lax.cond(live_min >= 1.0,
                                             gamma_mt_boost2, gamma_mt,
                                             k, a, i),
                kg, jnp.maximum(assign, 0.5), inv_lam)
            t_k = jnp.where(busy, t_raw, jnp.inf)
            t_star = t_k.min(1)
            proceed = st["active"] & jnp.isfinite(t_star)
            fin = t_k == t_star[:, None]          # finisher clears its queue
            p = jnp.clip(t_star[:, None] / t_k, 0.0, 1.0)
            done = binomial_normal(kb, jnp.maximum(assign - 1.0, 0.0), p)
            done = jnp.where(fin, assign, jnp.where(busy, done, 0.0))
            # carried + leftover-sum telescopes: units either finish or stay
            # remaining, so conservation is structural
            n_rem = st["n_rem"] - done.sum(1)

            started = st["iters"] > 0
            comm = jnp.maximum(assign - st["n_left"], 0.0).sum(1)
            upd = lambda new, old: jnp.where(  # noqa: E731
                proceed if new.ndim == 1 else proceed[:, None], new, old)
            iters = st["iters"] + proceed
            n_rem_m = upd(n_rem, st["n_rem"])
            out = {
                "key": key,
                "n_rem": n_rem_m,
                "n_left": upd(assign - done, st["n_left"]),
                "t_comp": upd(st["t_comp"] + t_star, st["t_comp"]),
                "n_comm": upd(st["n_comm"] + jnp.where(started, comm, 0.0),
                              st["n_comm"]),
                "iters": iters,
                "active": proceed & (n_rem_m > threshold)
                          & (iters < max_iter),
            }
            if not known:
                # est accumulators go unmasked -- frozen lanes only read
                # them through lam_hat, which IS masked
                ed = st["est_done"] + done
                et = st["est_time"] + t_star
                out["est_done"] = ed
                out["est_time"] = et
                out["lam_hat"] = upd(
                    jnp.where(ed > 0.0,
                              ed / jnp.maximum(et, 1e-30)[:, None], 1.0),
                    st["lam_hat"])
            return out

        st = {
            "key": key,
            "n_rem": jnp.full(B, n0),
            "n_left": jnp.zeros((B, K)),
            "t_comp": jnp.zeros(B),
            "n_comm": jnp.zeros(B),
            "iters": jnp.zeros(B, dtype=jnp.int32),
            "active": jnp.full(B, n0) > threshold,
        }
        if not known:
            st.update(est_done=jnp.zeros((B, K)), est_time=jnp.zeros(B),
                      lam_hat=jnp.ones((B, K)))
        st = jax.lax.while_loop(cond, body, st)

        # final phase: assign the remainder proportionally, wait for all
        kf = jax.random.split(st["key"])[0]
        has_rem = st["n_rem"] > 1e-6
        rates = lam if known else st["lam_hat"]
        share = rates * (st["n_rem"] / rates.sum(1))[:, None]
        comm = jnp.maximum(share - st["n_left"], 0.0).sum(1)
        t_k = jnp.where(share > 1e-9, gamma_mt(kf, share, inv_lam), 0.0)
        t_comp = st["t_comp"] + jnp.where(has_rem, t_k.max(1), 0.0)
        n_comm = st["n_comm"] + jnp.where(has_rem & (st["iters"] > 0),
                                          comm, 0.0)
        iters = st["iters"] + has_rem
        return t_comp, iters, n_comm

    return jax.jit(engine, static_argnames=("known",))


def work_exchange_grid_jax(lam: np.ndarray, N: int, cfg: ExchangeConfig,
                           trials: int, rng: np.random.Generator,
                           capped_mode: Literal["carry", "waterfill"]
                           = "carry") -> GridArrays:
    """Fused fluid-relaxation engine: one device dispatch per grid call.

    The jitted function is cached per ``(G * trials, K)`` shape and
    known/unknown flag -- ``known`` is static so the known-heterogeneity
    engine compiles with the online-estimator block dead-code-eliminated
    (two compilations per shape bucket, each reused by every later call);
    threshold, cap and N stay traced.  The numpy ``rng`` only seeds the
    JAX key stream (one draw), keeping call sites generator-driven like
    every other scheme.
    """
    if capped_mode != "carry":
        raise ValueError(
            "the jax sampler backend implements the paper-faithful 'carry' "
            "storage mode only; use backend='numpy' for 'waterfill'")
    global _JAX_ENGINE
    if _JAX_ENGINE is None:
        _JAX_ENGINE = _build_jax_engine()
    import jax

    lam = np.asarray(lam, dtype=np.float32)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    G, K = lam.shape
    known = cfg.known_heterogeneity
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or known
           else float(np.ceil(cfg.storage_cap_frac * N / K)))
    lam_rows = np.repeat(lam, int(trials), axis=0)       # (B, K), grid-major
    # pad the batch to a power-of-two bucket: jit caches per shape, so
    # fig5/fig6/fig7-sized grids land in a handful of compilations per
    # process instead of one per panel shape
    B = lam_rows.shape[0]
    pad = max(64, 1 << (B - 1).bit_length()) - B
    if pad:
        lam_rows = np.concatenate([lam_rows, np.repeat(lam_rows[:1], pad,
                                                       axis=0)])
    # rbg keys: counter-based bit generation is ~3x faster than threefry on
    # CPU and ample for Monte Carlo
    key = jax.random.key(int(rng.integers(2 ** 63 - 1)), impl="rbg")
    t, it, cm = _JAX_ENGINE(key, lam_rows, float(N), float(threshold),
                            cap, bool(known), int(cfg.max_iterations))
    return (np.asarray(t, dtype=np.float64)[:B],
            np.asarray(it, dtype=np.float64)[:B],
            np.asarray(cm, dtype=np.float64)[:B])


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

register_backend(SamplerBackend(
    name="numpy",
    work_exchange_grid=work_exchange_grid_numpy,
    description="exact integer-unit engine (Generator.gamma/binomial); "
                "bit-identical to the scalar reference at trials=1"))

register_backend(SamplerBackend(
    name="jax",
    work_exchange_grid=work_exchange_grid_jax,
    description="one jitted fluid-relaxation pipeline (mean-exact MT gamma "
                "+ normal-limit binomial, float32); statistically "
                "equivalent, not bit-identical"),
    available=_jax_available)


__all__ = [
    "ENV_VAR", "DEFAULT_BACKEND", "SAMPLER_BACKENDS", "SamplerBackend",
    "register_backend", "get_backend", "list_backends", "resolve_backend",
    "work_exchange_grid_numpy", "work_exchange_grid_jax",
]
