"""Pluggable sampler backends for the work-exchange Monte-Carlo engine.

The engine's hot loop is a round pipeline -- batched Gamma service draws,
argmin over workers, Binomial done-counts -- repeated for ~60 exchange
rounds.  Two backends implement it behind one grid-shaped contract:

``numpy``
    The exact integer-unit engine (largest-remainder assignments, exact
    ``Generator.gamma`` / ``Generator.binomial`` draws).  Bit-identical to
    the PR-1 trial-vectorized engine: with a single heterogeneity spec it
    consumes randomness in exactly the order of
    ``schemes.work_exchange_mc_batched``, which itself reduces to the
    scalar reference at ``trials=1``.

``jax``
    One jitted function fusing the whole pipeline -- assignment, Gamma,
    argmin, Binomial, estimator update -- with a ``lax.while_loop`` over
    exchange rounds and the ``(grid x trials)`` batch as the leading axis.
    It samples the paper's *fluid relaxation*: assignments are the exact
    real-valued proportional shares (the paper's eqs. 16/18/22 before
    unit rounding), Gamma draws use a mean-exact Marsaglia-Tsang transform
    (with the small-shape boost ``Gamma(a) = Gamma(a+1) * U^{1/a}``), and
    Binomial done-counts use their mean/variance-exact normal limit.
    Statistically equivalent to ``numpy`` at Monte-Carlo tolerance (unit
    rounding perturbs real shares by <1 unit in thousands); NOT
    bit-identical, and float32.  ``jax.random.gamma``'s per-element
    rejection loop is ~100x slower than NumPy on CPU, so the transform
    sampler is what makes the fused engine a win rather than a loss.

``pallas``
    The same fluid relaxation as ``jax``, but the whole round pipeline --
    counter-based Threefry-2x32 bit generation keyed per ``(trial,
    worker, round)``, the MT Gamma transform, the per-trial argmin, the
    normal-limit Binomial -- fused into ONE tiled Pallas kernel
    (``repro.kernels.we_rounds``): each program owns a ``(block_b, K)``
    tile of trials and runs the exchange-round loop to completion in
    VMEM.  On hosts without Pallas lowering (CPU CI) it executes a
    bit-identical jitted ``jnp`` reference (or the kernel under the
    Pallas interpreter -- ``REPRO_WE_ROUNDS_MODE=interpret``), so the
    backend is always selectable; the kernel wins on TPU where the jax
    backend is bit-generation-bound.

Backends are registered in ``SAMPLER_BACKENDS`` and selected per call
(``mc(..., backend="jax")``) or globally (``REPRO_SAMPLER_BACKEND=jax``);
the default is ``numpy``.  The grid contract returns flat per-run arrays
``(t_comp, iterations, n_comm)`` of length ``G * trials`` in
grid-major order; ``repro.core.schemes`` reshapes them into per-spec
``MCReport`` rows.  Backends also expose ``gamma_rows`` -- batched
``Gamma(shape) * scale`` over an ``(R, K)`` matrix in one call -- which
is what the batched MDS L-sweep draws through (``numpy`` is bit-identical
to the per-L loop; ``jax``/``pallas`` use their transform samplers).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable, Dict, List, Literal, Optional, Tuple

import numpy as np

from .registry import Registry
from .assignment import (capped_proportional_assignment_batch,
                         largest_remainder_round_batch)
from .types import ExchangeConfig

ENV_VAR = "REPRO_SAMPLER_BACKEND"
DEFAULT_BACKEND = "numpy"

# (t_comp, iterations, n_comm), each shape (G * trials,), grid-major
GridArrays = Tuple[np.ndarray, np.ndarray, np.ndarray]
# backend contract: (lam (G, K), N, cfg, trials, rng,
#                    capped_mode: "carry"|"waterfill",
#                    rate_schedule: Optional[(G, R, K)]) -> GridArrays.
# rate_schedule is the optional per-exchange-round service-rate schedule
# (scenario drift): round r >= R holds the last row, assignment rates
# stay nominal (known) / estimated (unknown) -- only the realized
# service draws follow the schedule.  (Callable[...] because the last
# two parameters are keyword-or-defaulted; the registered backends are
# the normative signatures.)
WEGridFn = Callable[..., GridArrays]
# (shape_rows, scale_rows, rng) -> (R, K) Gamma(shape) * scale draws
GammaRowsFn = Callable[[np.ndarray, np.ndarray, np.random.Generator],
                       np.ndarray]


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplerBackend:
    """One RNG/compute backend behind the work-exchange MC pipeline.

    ``gamma_rows`` (optional) is the batched order-statistic primitive
    the MDS L-sweep draws through; backends that leave it ``None`` fall
    back to the exact numpy draw (``get_gamma_rows``), so any future
    backend gets the full scheme surface for free.

    ``coupled_mds_sweep`` opts the backend into the common-random-numbers
    L-sweep: candidate Erlangs built as cumulative Gamma *increments*
    over one shared trial axis, which stabilizes exactly the mean
    differences the argmin needs, so half the sweep trials match the
    independent sweep's selection accuracy (the winner's reported samples
    always come from an independent exact-marginal top-up draw).  Exact
    backends leave it False to stay bit-identical to the per-L loop.
    """

    name: str
    work_exchange_grid: WEGridFn
    description: str = ""
    gamma_rows: Optional[GammaRowsFn] = None
    coupled_mds_sweep: bool = False
    # fused whole-panel dispatch for the work-exchange known/unknown pair:
    # (lam (G, K), N, cfg_known, cfg_unknown, trials, rng,
    #  rate_schedule=None) -> {"known": GridArrays, "unknown": GridArrays}.
    # Backends that leave it None run the pair as two grid dispatches.
    work_exchange_panel: Optional[Callable] = None

    def available(self) -> bool:
        return _BACKEND_AVAILABLE.get(self.name, lambda: True)()


SAMPLER_BACKENDS: Registry[SamplerBackend] = Registry("sampler backend")
_BACKEND_AVAILABLE: Dict[str, Callable[[], bool]] = {}


def register_backend(backend: SamplerBackend,
                     available: Callable[[], bool] = lambda: True) -> None:
    SAMPLER_BACKENDS.register(backend.name, backend)
    _BACKEND_AVAILABLE[backend.name] = available


def list_backends() -> List[str]:
    return SAMPLER_BACKENDS.names()


def get_backend(name: str) -> SamplerBackend:
    return SAMPLER_BACKENDS.get(name)


def resolve_backend(backend: str | None = None) -> str:
    """Explicit kwarg > ``REPRO_SAMPLER_BACKEND`` > ``numpy`` default."""
    name = backend or os.environ.get(ENV_VAR) or DEFAULT_BACKEND
    b = get_backend(name)      # raises on unknown names, env or kwarg
    if not b.available():
        raise RuntimeError(
            f"sampler backend {name!r} is registered but unavailable "
            f"(is its runtime installed?); set {ENV_VAR} or pass "
            f"backend= one of {[n for n in list_backends() if get_backend(n).available()]}")
    return name


def validate_backend(backend: str | None = None) -> str:
    """Fail fast on unknown backend names without requiring availability.

    Every ``Scheme.mc``/``mc_grid`` entry point calls this, including
    schemes that never draw through a backend, so a typo in ``backend=``
    or ``REPRO_SAMPLER_BACKEND`` raises a ``KeyError`` listing the
    registered backends instead of being silently ignored (or surfacing
    later as an opaque attribute error)."""
    name = backend or os.environ.get(ENV_VAR) or DEFAULT_BACKEND
    get_backend(name)          # KeyError with the registered list
    return name


def get_gamma_rows(name: str) -> GammaRowsFn:
    """The backend's batched Gamma-rows primitive (numpy fallback)."""
    fn = get_backend(name).gamma_rows
    return fn if fn is not None else gamma_rows_numpy


# ---------------------------------------------------------------------------
# multi-device grid sharding (the experiment engine's scale layer)
# ---------------------------------------------------------------------------

_GRID_MESH: List[Optional[object]] = [None]   # active jax Mesh, or None


@contextlib.contextmanager
def grid_sharding(devices: Optional[int] = None):
    """Shard backend grid dispatches across devices inside the context.

    Builds a 1-D ``'grid'`` mesh (``repro.distributed.sharding.grid_mesh``)
    over up to ``devices`` devices (None = all) and routes the ``jax`` and
    ``pallas`` ``work_exchange_grid`` calls through a ``shard_map``
    executor that splits the scenario x trials batch rows across it --
    each device runs an independent round pipeline on its own key stream
    (embarrassingly parallel, no collectives).  The ``numpy`` backend is
    untouched: it stays the bit-exact single-device oracle.  With one
    device the context is a no-op, so callers can wrap unconditionally.
    """
    from repro.distributed.sharding import grid_mesh
    mesh = grid_mesh(devices)
    prev = _GRID_MESH[0]
    _GRID_MESH[0] = mesh if mesh.size > 1 else None
    try:
        yield mesh
    finally:
        _GRID_MESH[0] = prev


def active_grid_mesh():
    """The Mesh installed by ``grid_sharding``, or None outside it."""
    return _GRID_MESH[0]


# ---------------------------------------------------------------------------
# numpy backend: exact integer-unit engine, generalized to per-row rates
# ---------------------------------------------------------------------------

def work_exchange_grid_numpy(lam: np.ndarray, N: int, cfg: ExchangeConfig,
                             trials: int, rng: np.random.Generator,
                             capped_mode: Literal["carry", "waterfill"]
                             = "carry",
                             rate_schedule: Optional[np.ndarray] = None
                             ) -> GridArrays:
    """Exact batched engine over a ``(G, K)`` heterogeneity grid.

    Every row of the ``(G * trials, K)`` state is one independent run of
    Algorithm 1/3; rows are grid-major (``g * trials + t``).  With
    ``G == 1`` the randomness is consumed in exactly the order of the
    PR-1 trial-batched engine (and hence, at ``trials == 1``, of the
    scalar reference) -- the bit-identity the tests pin down.

    ``rate_schedule`` (optional, ``(G, R, K)``) drives scenario drift:
    the service draws of exchange round ``r`` use row ``min(r, R - 1)``
    of the point's schedule while the *assignment* keeps using the
    nominal ``lam`` (known) or the online estimate (unknown), exactly
    the scheduler-sees-nominal / reality-drifts split of the drifting
    and trace-corpus scenario families.  With ``rate_schedule=None``
    this path is byte-for-byte the stationary engine.
    """
    lam = np.asarray(lam, dtype=np.float64)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    G, K = lam.shape
    T = int(trials)
    B = G * T
    known = cfg.known_heterogeneity
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or known
           else int(np.ceil(cfg.storage_cap_frac * N / K)))
    lam_rows = np.repeat(lam, T, axis=0)          # (B, K), grid-major
    inv_lam = 1.0 / lam_rows
    inv_sched = None
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float64)
        if sched.ndim != 3 or sched.shape[0] != G or sched.shape[2] != K:
            raise ValueError(f"rate_schedule must be (G={G}, R, K={K}); "
                             f"got shape {sched.shape}")
        inv_sched = 1.0 / np.repeat(sched, T, axis=0)   # (B, R, K)

    est_done = np.zeros((B, K))
    est_time = np.zeros(B)
    lam_hat = np.ones((B, K))
    n_rem = np.full(B, N, dtype=np.int64)
    n_left_prev = np.zeros((B, K), dtype=np.int64)
    n_done = np.zeros((B, K), dtype=np.int64)
    t_comp = np.zeros(B)
    n_comm = np.zeros(B)
    iters = np.zeros(B, dtype=np.int64)
    in_loop = np.ones(B, dtype=bool)

    while True:
        # compact every pass to the runs still above the threshold; row
        # order is ascending, so a lone run draws in exactly the scalar
        # order and the tail of long-running runs stays cheap
        in_loop &= (n_rem > threshold) & (iters < cfg.max_iterations)
        idx = np.flatnonzero(in_loop)
        if idx.size == 0:
            break
        n = idx.size
        rates = lam_rows[idx] if known else lam_hat[idx]
        rem = n_rem[idx]
        if np.isinf(cap):
            assign = largest_remainder_round_batch(rates, rem)
        elif capped_mode == "waterfill":
            assign = capped_proportional_assignment_batch(rates, rem, cap)
        else:
            assign = np.minimum(largest_remainder_round_batch(rates, rem),
                                cap)
        assigned = assign.sum(axis=1)
        carried = rem - assigned
        # degenerate rounding: that run leaves the loop without drawing
        live = assigned > 0
        if not live.all():
            in_loop[idx[~live]] = False
            idx, assign, carried = idx[live], assign[live], carried[live]
            n = idx.size
            if n == 0:
                break

        started = iters[idx] > 0
        comm_add = np.maximum(assign - n_left_prev[idx], 0).sum(axis=1)
        n_comm[idx] += np.where(started, comm_add, 0.0)

        # batched iteration outcome (same draw order as the scalar path)
        if inv_sched is None:
            scale = inv_lam[idx]
        else:        # service rates of THIS round (clamped to the last row)
            r_idx = np.minimum(iters[idx], inv_sched.shape[1] - 1)
            scale = inv_sched[idx, r_idx]
        busy = assign > 0
        if busy.all():      # the common case: draw the full matrix directly
            t_k = rng.gamma(shape=assign, scale=scale)
        else:
            t_k = np.full((n, K), np.inf)
            t_k[busy] = rng.gamma(shape=assign[busy], scale=scale[busy])
        finisher = np.argmin(t_k, axis=1)
        rows = np.arange(n)
        t_star = t_k[rows, finisher]
        done = np.zeros((n, K), dtype=np.int64)
        done[rows, finisher] = assign[rows, finisher]
        others = busy.copy()
        others[rows, finisher] = False
        o_rows, o_cols = np.nonzero(others)      # C order == scalar draw order
        if o_rows.size:
            n_oth = np.maximum(assign[o_rows, o_cols] - 1, 0)
            p_oth = np.clip(t_star[o_rows] / t_k[o_rows, o_cols], 0.0, 1.0)
            done[o_rows, o_cols] = rng.binomial(n_oth, p_oth)

        iters[idx] += 1
        t_comp[idx] += t_star
        n_done[idx] += done
        leftover = assign - done
        n_left_prev[idx] = leftover
        n_rem[idx] = carried + leftover.sum(axis=1)
        if not known:        # online estimate, eq. (23)
            ed = est_done[idx] + done
            et = est_time[idx] + t_star
            est_done[idx] = ed
            est_time[idx] = et
            lam_hat[idx] = np.where(ed > 0,
                                    ed / np.maximum(et, 1e-300)[:, None], 1.0)

    # final phase below the threshold: assign the remainder, wait for all
    idx = np.flatnonzero(n_rem > 0)
    if idx.size:
        n = idx.size
        rates = lam_rows[idx] if known else lam_hat[idx]
        assign = largest_remainder_round_batch(rates, n_rem[idx])
        comm_add = np.maximum(assign - n_left_prev[idx], 0).sum(axis=1)
        n_comm[idx] += np.where(iters[idx] > 0, comm_add, 0.0)
        if inv_sched is None:
            scale = inv_lam[idx]
        else:
            r_idx = np.minimum(iters[idx], inv_sched.shape[1] - 1)
            scale = inv_sched[idx, r_idx]
        busy = assign > 0
        if busy.all():
            t_k = rng.gamma(shape=assign, scale=scale)
        else:
            t_k = np.zeros((n, K))
            t_k[busy] = rng.gamma(shape=assign[busy], scale=scale[busy])
        t_comp[idx] += t_k.max(axis=1)
        n_done[idx] += assign
        iters[idx] += 1

    totals = n_done.sum(axis=1)
    if not (totals == N).all():
        bad = int(np.flatnonzero(totals != N)[0])
        raise AssertionError(f"work conservation violated in run {bad}: "
                             f"processed {int(totals[bad])} of {N}")
    return t_comp, iters.astype(np.float64), n_comm


def gamma_rows_numpy(shape_rows: np.ndarray, scale_rows: np.ndarray,
                     rng: np.random.Generator) -> np.ndarray:
    """Exact ``Generator.gamma`` over an ``(R, K)`` matrix in one call.

    ``shape_rows`` and ``scale_rows`` broadcast against each other (e.g.
    an ``(R, 1)`` shape column against ``(R, K)`` scales).  With rows
    laid out L-major this consumes randomness in exactly the order of
    the PR-2 per-L sweep loop (``Generator.gamma`` fills the broadcast
    output element by element in C order whether the shape argument is
    scalar or array), which is what makes the batched MDS sweep
    bit-identical to the loop.
    """
    shape_rows = np.asarray(shape_rows, dtype=np.float64)
    out_shape = np.broadcast_shapes(shape_rows.shape,
                                    np.asarray(scale_rows).shape)
    if len(out_shape) != 2:
        raise ValueError(f"shape/scale rows must broadcast to (R, K); "
                         f"got {out_shape}")
    return rng.gamma(shape=shape_rows, scale=scale_rows)


# ---------------------------------------------------------------------------
# jax backend: one jitted fluid-relaxation pipeline
# ---------------------------------------------------------------------------

def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


_JAX_TX = None               # transform-sampler namespace, built once
_JAX_ENGINES: Dict[bool, Callable] = {}   # drift? -> jitted engine


def _jax_transforms():
    """The fluid-relaxation transform samplers, shared by the fused
    engine and the batched MDS ``gamma_rows`` path (lazy jax import)."""
    global _JAX_TX
    if _JAX_TX is not None:
        return _JAX_TX
    import types

    import jax
    import jax.numpy as jnp

    def gamma_mt_large(key, alpha, inv_rate):
        """Raw Marsaglia-Tsang transform d*(1 + Z/(3 sqrt(d)))^3 with
        d = alpha - 1/3: mean-exact, variance alpha + 1/9, for alpha >= 3
        (there the rejection step it omits accepts with prob > 99.8% and
        the cube-root argument goes negative with prob < 2e-7)."""
        d = alpha - 1.0 / 3.0
        z = jax.random.normal(key, alpha.shape)
        c = jnp.maximum(1.0 + z / (3.0 * jnp.sqrt(d)), 0.0)
        return d * c ** 3 * inv_rate

    def _boosted(key, alpha, inv_rate, levels):
        """Boost sub-3 shapes through the exact identity
        Gamma(a) = Gamma(a+1) * U^(1/a), chained ``levels`` times, so the
        MT transform always runs at shape alpha + levels (>= 3 whenever
        alpha >= 3 - levels).  The chained mean telescopes exactly:
        (alpha + levels) * alpha/(alpha + levels) = alpha."""
        kz, ku = jax.random.split(key)
        boost = alpha < 3.0
        a = jnp.where(boost, alpha + levels, alpha)
        u = jax.random.uniform(ku, (levels,) + alpha.shape, minval=1e-12)
        inv_shapes = jnp.stack([1.0 / jnp.maximum(alpha + i, 1e-12)
                                for i in range(levels)])
        pow_u = jnp.exp((jnp.log(u) * inv_shapes).sum(0))
        return gamma_mt_large(kz, a, inv_rate) * jnp.where(boost, pow_u, 1.0)

    def gamma_mt_boost2(key, alpha, inv_rate):
        """Mean-exact for alpha >= 1 (callers mask smaller elements)."""
        return _boosted(key, alpha, inv_rate, 2)

    def gamma_mt(key, alpha, inv_rate):
        """Mean-exact MT transform sampler for any alpha > 0."""
        return _boosted(key, alpha, inv_rate, 3)

    def binomial_normal(key, n, p):
        """Binomial(n, p) in its mean/variance-exact normal limit (fluid
        done-counts stay real-valued; clipping to [0, n] is the only
        deviation and is negligible for the unit counts in play)."""
        mean = n * p
        std = jnp.sqrt(jnp.maximum(n * p * (1.0 - p), 0.0))
        z = jax.random.normal(key, n.shape)
        return jnp.clip(mean + z * std, 0.0, n)

    _JAX_TX = types.SimpleNamespace(
        gamma_mt_large=gamma_mt_large, gamma_mt_boost2=gamma_mt_boost2,
        gamma_mt=gamma_mt, binomial_normal=binomial_normal,
        gamma_mt_large_jit=jax.jit(gamma_mt_large),
        gamma_mt_jit=jax.jit(gamma_mt))
    return _JAX_TX


def _build_jax_engine(drift: bool = False):
    """Construct the jitted grid engine (imports jax lazily).

    ``drift=True`` builds the drifting-rates variant: an extra traced
    ``(B, R, K)`` schedule argument supplies each round's true service
    rates (row ``min(round, R - 1)``); the assignment shares keep using
    the nominal ``lam`` / online estimate.  ``drift=False`` compiles to
    exactly the stationary PR-4 engine (no schedule argument, no
    gathers).
    """
    import jax
    import jax.numpy as jnp

    tx = _jax_transforms()
    gamma_mt_large = tx.gamma_mt_large
    gamma_mt_boost2 = tx.gamma_mt_boost2
    gamma_mt = tx.gamma_mt
    binomial_normal = tx.binomial_normal

    def engine(key, lam, sched, n0, threshold, cap, known, max_iter):
        # ``known`` is STATIC: the known-heterogeneity engine compiles
        # with the whole online-estimator block dead-code-eliminated
        B, K = lam.shape
        inv_lam0 = 1.0 / lam
        lam_sum = lam.sum(1)
        # zero-rate columns are masked padding from the K-axis shape
        # buckets: the estimator must hold a zero estimate for them so
        # they are never assigned work (identical to ones without padding)
        prior = jnp.where(lam > 0.0, 1.0, 0.0)
        R = sched.shape[1] if drift else 1

        def inv_lam_at(iters):
            """1/rate in effect at each row's current round (per-row
            gather -- final phase only; the loop uses the scalar trip
            counter and one dynamic slice per round)."""
            if not drift:
                return inv_lam0
            r_idx = jnp.minimum(iters, R - 1)
            cur = jnp.take_along_axis(sched, r_idx[:, None, None],
                                      axis=1)[:, 0, :]
            return 1.0 / cur

        def cond(st):
            return st["active"].any()

        def body(st):
            key, kg, kb = jax.random.split(st["key"], 3)
            if drift:
                # every active row has proceeded on every prior trip, so
                # its round == the scalar trip counter: one row load
                # replaces the per-row take_along_axis gather (frozen
                # rows' stale reads are fully masked)
                r = jnp.minimum(st["round"], R - 1)
                inv_lam = 1.0 / jax.lax.dynamic_slice_in_dim(
                    sched, r, 1, axis=1)[:, 0, :]
            else:
                inv_lam = inv_lam0
            if known:
                share = lam * (st["n_rem"] / lam_sum)[:, None]
            else:
                rates = st["lam_hat"]
                share = rates * (st["n_rem"] / rates.sum(1))[:, None]
            assign = jnp.minimum(share, cap)
            # integer engine's "assign > 0" becomes "at least half a unit";
            # sub-half slivers are carried as leftover, and a round where
            # nothing reaches half a unit exits like degenerate rounding
            busy = assign > 0.5
            # tiered per-round gamma path keyed on the smallest live share:
            # >= 3 needs no boost (one normal, no uniforms), >= 1 a 2-chain
            # boost, only sub-unit rounds pay the full 3-chain -- the bit
            # stream is the engine's bottleneck, so draw no more than the
            # round's smallest shape requires
            live_min = jnp.where(busy & st["active"][:, None], assign,
                                 jnp.inf).min()
            t_raw = jax.lax.cond(
                live_min >= 3.0, gamma_mt_large,
                lambda k, a, i: jax.lax.cond(live_min >= 1.0,
                                             gamma_mt_boost2, gamma_mt,
                                             k, a, i),
                kg, jnp.maximum(assign, 0.5), inv_lam)
            t_k = jnp.where(busy, t_raw, jnp.inf)
            t_star = t_k.min(1)
            proceed = st["active"] & jnp.isfinite(t_star)
            fin = t_k == t_star[:, None]          # finisher clears its queue
            p = jnp.clip(t_star[:, None] / t_k, 0.0, 1.0)
            done = binomial_normal(kb, jnp.maximum(assign - 1.0, 0.0), p)
            done = jnp.where(fin, assign, jnp.where(busy, done, 0.0))
            # carried + leftover-sum telescopes: units either finish or stay
            # remaining, so conservation is structural
            n_rem = st["n_rem"] - done.sum(1)

            started = st["iters"] > 0
            comm = jnp.maximum(assign - st["n_left"], 0.0).sum(1)
            upd = lambda new, old: jnp.where(  # noqa: E731
                proceed if new.ndim == 1 else proceed[:, None], new, old)
            iters = st["iters"] + proceed
            n_rem_m = upd(n_rem, st["n_rem"])
            out = {
                "key": key,
                "n_rem": n_rem_m,
                "n_left": upd(assign - done, st["n_left"]),
                "t_comp": upd(st["t_comp"] + t_star, st["t_comp"]),
                "n_comm": upd(st["n_comm"] + jnp.where(started, comm, 0.0),
                              st["n_comm"]),
                "iters": iters,
                "active": proceed & (n_rem_m > threshold)
                          & (iters < max_iter),
            }
            if drift:
                out["round"] = st["round"] + jnp.int32(1)
            if not known:
                # est accumulators go unmasked -- frozen lanes only read
                # them through lam_hat, which IS masked
                ed = st["est_done"] + done
                et = st["est_time"] + t_star
                out["est_done"] = ed
                out["est_time"] = et
                out["lam_hat"] = upd(
                    jnp.where(ed > 0.0,
                              ed / jnp.maximum(et, 1e-30)[:, None], prior),
                    st["lam_hat"])
            return out

        st = {
            "key": key,
            "n_rem": jnp.full(B, n0),
            "n_left": jnp.zeros((B, K)),
            "t_comp": jnp.zeros(B),
            "n_comm": jnp.zeros(B),
            "iters": jnp.zeros(B, dtype=jnp.int32),
            "active": jnp.full(B, n0) > threshold,
        }
        if drift:
            st["round"] = jnp.int32(0)
        if not known:
            st.update(est_done=jnp.zeros((B, K)), est_time=jnp.zeros(B),
                      lam_hat=prior)
        st = jax.lax.while_loop(cond, body, st)

        # final phase: assign the remainder proportionally, wait for all
        kf = jax.random.split(st["key"])[0]
        has_rem = st["n_rem"] > 1e-6
        rates = lam if known else st["lam_hat"]
        inv_lam = inv_lam_at(st["iters"])
        share = rates * (st["n_rem"] / rates.sum(1))[:, None]
        comm = jnp.maximum(share - st["n_left"], 0.0).sum(1)
        t_k = jnp.where(share > 1e-9, gamma_mt(kf, share, inv_lam), 0.0)
        t_comp = st["t_comp"] + jnp.where(has_rem, t_k.max(1), 0.0)
        n_comm = st["n_comm"] + jnp.where(has_rem & (st["iters"] > 0),
                                          comm, 0.0)
        iters = st["iters"] + has_rem
        return t_comp, iters, n_comm

    if drift:
        return jax.jit(engine, static_argnames=("known",))

    def stationary(key, lam, n0, threshold, cap, known, max_iter):
        return engine(key, lam, None, n0, threshold, cap, known, max_iter)

    return jax.jit(stationary, static_argnames=("known",))


def _get_jax_engine(drift: bool = False):
    if drift not in _JAX_ENGINES:
        _JAX_ENGINES[drift] = _build_jax_engine(drift)
    return _JAX_ENGINES[drift]


_JAX_SHARDED: Dict[Tuple[object, bool], Callable] = {}   # (Mesh, drift?)


def _sharded_jax_engine(mesh, drift: bool = False):
    """Jitted shard_map wrapper of the fused engine, cached per mesh.

    Each device runs the whole ``lax.while_loop`` pipeline on its own
    block of batch rows with its own rbg key -- no collectives, so the
    shards never synchronize until the final gather.  ``check_rep=False``
    because jax<=0.4 has no replication rule for ``while``.  The drift
    variant also shards the ``(B, R, K)`` rate schedule along the batch
    rows, so each device carries only its own rows' schedules.
    """
    if (mesh, drift) in _JAX_SHARDED:
        return _JAX_SHARDED[(mesh, drift)]
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    eng = _get_jax_engine(drift)
    spec = PartitionSpec(mesh.axis_names[0])

    if drift:
        def sharded(keys, lam, sched, n0, threshold, cap, known, max_iter):
            def block(keys_b, lam_b, sched_b):
                return eng(keys_b[0], lam_b, sched_b, n0, threshold, cap,
                           known, max_iter)
            return shard_map(block, mesh=mesh, in_specs=(spec, spec, spec),
                             out_specs=spec, check_rep=False)(keys, lam,
                                                              sched)
    else:
        def sharded(keys, lam, n0, threshold, cap, known, max_iter):
            def block(keys_b, lam_b):
                return eng(keys_b[0], lam_b, n0, threshold, cap, known,
                           max_iter)
            return shard_map(block, mesh=mesh, in_specs=(spec, spec),
                             out_specs=spec, check_rep=False)(keys, lam)

    fn = jax.jit(sharded, static_argnames=("n0", "threshold", "cap",
                                           "known", "max_iter"))
    _JAX_SHARDED[(mesh, drift)] = fn
    return fn


def work_exchange_grid_jax(lam: np.ndarray, N: int, cfg: ExchangeConfig,
                           trials: int, rng: np.random.Generator,
                           capped_mode: Literal["carry", "waterfill"]
                           = "carry",
                           rate_schedule: Optional[np.ndarray] = None
                           ) -> GridArrays:
    """Fused fluid-relaxation engine: one device dispatch per grid call.

    The jitted function is cached per ``(G * trials, K)`` shape and
    known/unknown flag -- ``known`` is static so the known-heterogeneity
    engine compiles with the online-estimator block dead-code-eliminated
    (two compilations per shape bucket, each reused by every later call);
    threshold, cap and N stay traced.  The numpy ``rng`` only seeds the
    JAX key stream (one draw), keeping call sites generator-driven like
    every other scheme.  ``rate_schedule`` (``(G, R, K)``) selects the
    drift engine variant: per-round service rates follow the schedule
    while assignments stay nominal/estimated (same contract as the numpy
    backend, statistically -- not bitwise -- equivalent to it).
    """
    if capped_mode != "carry":
        raise ValueError(
            "the jax sampler backend implements the paper-faithful 'carry' "
            "storage mode only; use backend='numpy' for 'waterfill'")
    import jax

    lam = np.asarray(lam, dtype=np.float32)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    G, K = lam.shape
    known = cfg.known_heterogeneity
    # threshold / cap come from the REAL worker count; the K bucket below
    # only adds masked zero-rate columns
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or known
           else float(np.ceil(cfg.storage_cap_frac * N / K)))
    lam_rows = np.repeat(_pad_cols(lam, bucket_cols(K)), int(trials),
                         axis=0)                         # (B, Kb), grid-major
    # pad the batch to a shape bucket (shared _pad_rows policy): jit
    # caches per shape, so fig5/fig6/fig7-sized grids land in a handful
    # of compilations per process instead of one per panel shape
    lam_rows, B = _pad_rows(lam_rows)
    drift = rate_schedule is not None
    sched_rows = None
    if drift:
        sched = np.asarray(rate_schedule, dtype=np.float32)
        if sched.ndim != 3 or sched.shape[0] != G or sched.shape[2] != K:
            raise ValueError(f"rate_schedule must be (G={G}, R, K={K}); "
                             f"got shape {sched.shape}")
        sched = _pad_sched(sched, bucket_rounds(sched.shape[1]),
                           bucket_cols(K))
        sched_rows = np.repeat(sched, int(trials), axis=0)
        sched_rows = _pad_rows_like(sched_rows, lam_rows.shape[0])
    mesh = active_grid_mesh()
    if mesh is not None:
        # sharded executor: one independent engine per device over its
        # block of rows, each on its own split of the key stream (NOT
        # bit-identical to the single-device jax path; statistically
        # equivalent -- the numpy oracle is the bit-exact reference)
        D = int(mesh.size)
        extra = (-lam_rows.shape[0]) % D
        if extra:
            lam_rows = np.concatenate(
                [lam_rows, np.repeat(lam_rows[:1], extra, axis=0)])
        keys = jax.random.split(
            jax.random.key(int(rng.integers(2 ** 63 - 1)), impl="rbg"), D)
        if drift:
            sched_rows = _pad_rows_like(sched_rows, lam_rows.shape[0])
            t, it, cm = _sharded_jax_engine(mesh, drift=True)(
                keys, lam_rows, sched_rows, float(N), float(threshold),
                cap, bool(known), int(cfg.max_iterations))
        else:
            t, it, cm = _sharded_jax_engine(mesh)(
                keys, lam_rows, float(N), float(threshold), cap,
                bool(known), int(cfg.max_iterations))
    else:
        # rbg keys: counter-based bit generation is ~3x faster than
        # threefry on CPU and ample for Monte Carlo
        key = jax.random.key(int(rng.integers(2 ** 63 - 1)), impl="rbg")
        if drift:
            t, it, cm = _get_jax_engine(drift=True)(
                key, lam_rows, sched_rows, float(N), float(threshold),
                cap, bool(known), int(cfg.max_iterations))
        else:
            t, it, cm = _get_jax_engine()(
                key, lam_rows, float(N), float(threshold), cap,
                bool(known), int(cfg.max_iterations))
    return (np.asarray(t, dtype=np.float64)[:B],
            np.asarray(it, dtype=np.float64)[:B],
            np.asarray(cm, dtype=np.float64)[:B])


def _rows_target(R: int, bucket: int = 64) -> int:
    """Batch-axis bucket: power-of-two (>= ``bucket``) up to 8192 rows,
    multiples of 8192 above (pow2 would waste up to 2x the draw work on
    panel-sized grids)."""
    if R > 8192:
        return -(-R // 8192) * 8192
    return max(bucket, 1 << (R - 1).bit_length())


def _shape_buckets_enabled() -> bool:
    return os.environ.get("REPRO_SHAPE_BUCKETS", "1").lower() not in (
        "0", "off", "false")


def bucket_cols(K: int) -> int:
    """Worker-axis (K) shape bucket: power-of-two up to 16 workers, then
    the next multiple of 8.  Padded columns carry ``lambda = 0`` and are
    fully masked (never busy, never assigned, estimator prior 0), so two
    panels whose K lands in the same bucket share one compilation -- and
    one ``REPRO_JAX_CACHE_DIR`` persistent-cache entry -- instead of
    compiling per shape.  ``REPRO_SHAPE_BUCKETS=0`` disables K/R
    bucketing (exact shapes, one compile per shape)."""
    if not _shape_buckets_enabled():
        return K
    if K <= 16:
        return 1 << max(K - 1, 0).bit_length()
    return -(-K // 8) * 8


def bucket_rounds(R: int) -> int:
    """Drift-schedule round-axis (R) bucket: power-of-two up to 16
    rounds, then the next multiple of 16.  Padding repeats the last
    schedule row, which is exactly the engines' round >= R clamp --
    value-preserving, not just masked."""
    if not _shape_buckets_enabled():
        return R
    if R <= 16:
        return 1 << max(R - 1, 0).bit_length()
    return -(-R // 16) * 16


def grid_bucket_shape(G: int, trials: int, K: int,
                      R: Optional[int] = None,
                      backend: Optional[str] = None) -> Dict[str, int]:
    """The padded ``(rows, K[, R])`` bucket a ``(G, trials, K[, R])``
    panel dispatches at -- the compile/persistent-cache key's shape part.
    Two panels with equal buckets (and equal static config) share one
    compilation and one ``REPRO_JAX_CACHE_DIR`` entry."""
    bucket = 128 if resolve_backend(backend) == "pallas" else 64
    shape = {"rows": _rows_target(G * int(trials), bucket),
             "K": bucket_cols(K)}
    if R is not None:
        shape["R"] = bucket_rounds(R)
    return shape


def _pad_rows(rows: np.ndarray, bucket: int = 64) -> Tuple[np.ndarray, int]:
    """Pad the leading axis to its ``_rows_target`` bucket with copies of
    row 0, so jit caches land in a handful of compilations."""
    R = rows.shape[0]
    target = _rows_target(R, bucket)
    if target - R:
        rows = np.concatenate([rows, np.repeat(rows[:1], target - R,
                                               axis=0)])
    return rows, R


def _pad_cols(rows: np.ndarray, Kb: int) -> np.ndarray:
    """Zero-pad the trailing worker axis to the ``Kb`` bucket (masked
    columns: rate 0 means never busy, never assigned)."""
    K = rows.shape[-1]
    if Kb > K:
        rows = np.pad(rows, [(0, 0)] * (rows.ndim - 1) + [(0, Kb - K)])
    return rows


def _pad_sched(sched: np.ndarray, Rb: int, Kb: int) -> np.ndarray:
    """Bucket-pad a ``(..., R, K)`` rate schedule: zero columns on the
    worker axis (masked), last-row repeats on the round axis (the
    round >= R clamp made explicit)."""
    R, K = sched.shape[-2], sched.shape[-1]
    if Kb > K:
        sched = _pad_cols(sched, Kb)
    if Rb > R:
        sched = np.concatenate(
            [sched, np.repeat(sched[..., -1:, :], Rb - R, axis=-2)],
            axis=-2)
    return sched


def _pad_rows_like(rows: np.ndarray, target: int) -> np.ndarray:
    """Pad the leading axis to an already-chosen target length with
    copies of row 0 (the schedule companion of ``_pad_rows``: schedule
    rows must stay aligned with the padded rate rows)."""
    extra = target - rows.shape[0]
    if extra > 0:
        rows = np.concatenate([rows, np.repeat(rows[:1], extra, axis=0)])
    return rows


def _pad_rows_to(rows: np.ndarray, R: int) -> np.ndarray:
    """Bucket-pad 2-D arrays whose leading axis carries the ``R``
    broadcast rows; leave size-1 leading axes and 1-D ``(K,)`` vectors
    (both pure-broadcast operands) untouched."""
    if rows.ndim == 2 and rows.shape[0] == R and R > 1:
        return _pad_rows(rows)[0]
    return rows


def _gamma_rows_prep(shape_rows: np.ndarray, scale_rows: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, int, bool]:
    """Shared gamma_rows prologue: float32 conversion, broadcast-shape
    validation, bucket padding of the row-carrying operands, and the
    static sub-3-shape (boost) flag.  Returns
    ``(padded_shape, padded_scale, R, boost)``."""
    shape_rows = np.asarray(shape_rows, dtype=np.float32)
    scale_rows = np.asarray(scale_rows, dtype=np.float32)
    out_shape = np.broadcast_shapes(shape_rows.shape, scale_rows.shape)
    if len(out_shape) != 2:
        raise ValueError(f"shape/scale rows must broadcast to (R, K); "
                         f"got {out_shape}")
    R = out_shape[0]
    return (_pad_rows_to(shape_rows, R),
            _pad_rows_to(np.ascontiguousarray(scale_rows), R),
            R, bool((shape_rows < 3.0).any()))


_JAX_GAMMA_ROWS = None


def gamma_rows_jax(shape_rows: np.ndarray, scale_rows: np.ndarray,
                   rng: np.random.Generator) -> np.ndarray:
    """Batched MT-transform Gammas in one jitted dispatch (mean-exact;
    the boost chain compiles in only when some shape is below 3).

    ``shape_rows``/``scale_rows`` broadcast against each other -- compact
    ``(R, 1)`` shape columns stay compact until the kernel, where the
    normal draw materializes the full broadcast shape.  The numpy ``rng``
    only seeds the key stream; output is float32 (the fluid pipeline's
    dtype), which callers may sort/average as-is.
    """
    global _JAX_GAMMA_ROWS
    import jax

    padded_shape, padded_scale, R, boost = _gamma_rows_prep(shape_rows,
                                                            scale_rows)
    if _JAX_GAMMA_ROWS is None:
        import functools

        import jax.numpy as jnp
        tx = _jax_transforms()

        def kernel(key, alpha, scale, boost):
            out = jnp.broadcast_shapes(alpha.shape, scale.shape)
            alpha = jnp.broadcast_to(alpha, out)
            fn = tx.gamma_mt if boost else tx.gamma_mt_large
            return fn(key, alpha, scale)

        _JAX_GAMMA_ROWS = jax.jit(kernel, static_argnames=("boost",))
    key = jax.random.key(int(rng.integers(2 ** 63 - 1)), impl="rbg")
    out = np.asarray(_JAX_GAMMA_ROWS(key, padded_shape, padded_scale,
                                     boost))[:R]
    return np.array(out)      # own the memory: callers sort in place


# ---------------------------------------------------------------------------
# pallas backend: the fused we_rounds kernel (repro.kernels.we_rounds)
# ---------------------------------------------------------------------------

def work_exchange_grid_pallas(lam: np.ndarray, N: int, cfg: ExchangeConfig,
                              trials: int, rng: np.random.Generator,
                              capped_mode: Literal["carry", "waterfill"]
                              = "carry",
                              rate_schedule: Optional[np.ndarray] = None
                              ) -> GridArrays:
    """One fused Pallas pass over the ``(G * trials, K)`` grid.

    Same fluid relaxation as the ``jax`` backend but with counter-based
    Threefry bits generated *inside* the kernel, so the whole round
    pipeline -- bit generation included -- is one tiled device pass.  On
    CPU hosts the bit-identical jnp reference (or the interpreted kernel,
    ``REPRO_WE_ROUNDS_MODE=interpret``) runs instead; see
    ``repro.kernels.we_rounds.ops``.  The numpy ``rng`` only seeds the
    Threefry key (one draw), keeping call sites generator-driven.
    """
    if capped_mode != "carry":
        raise ValueError(
            "the pallas sampler backend implements the paper-faithful "
            "'carry' storage mode only; use backend='numpy' for "
            "'waterfill'")
    from repro.kernels.we_rounds import we_rounds_grid

    lam = np.asarray(lam, dtype=np.float32)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    K = lam.shape[1]
    known = cfg.known_heterogeneity
    # real-K scalars first; the K bucket only adds masked zero columns
    # (note the Threefry counter namespace is keyed by the padded K, so
    # bucketed and unbucketed runs are different -- equally valid --
    # bit streams; kernel/interpret/reference stay mutually bit-identical
    # at the padded layout)
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or known
           else float(np.ceil(cfg.storage_cap_frac * N / K)))
    G = lam.shape[0]
    lam_rows = np.repeat(_pad_cols(lam, bucket_cols(K)), int(trials),
                         axis=0)                         # (B, Kb), grid-major
    # power-of-two bucket >= 128 (the kernel's tile height): panel-sized
    # grids share a handful of compilations per process, and the bucket
    # is always a whole number of tiles
    lam_rows, B = _pad_rows(lam_rows, bucket=128)
    sched_rows = None
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float32)
        if sched.ndim != 3 or sched.shape[0] != G or sched.shape[2] != K:
            raise ValueError(f"rate_schedule must be (G={G}, R, K={K}); "
                             f"got shape {sched.shape}")
        sched = _pad_sched(sched, bucket_rounds(sched.shape[1]),
                           bucket_cols(K))
        sched_rows = _pad_rows_like(np.repeat(sched, int(trials), axis=0),
                                    lam_rows.shape[0])
    mesh = active_grid_mesh()
    if mesh is not None:
        # sharded executor: one independent seed pair per device (each
        # shard keys its Threefry counters from its own seed row)
        seed = rng.integers(0, 2 ** 32, size=(int(mesh.size), 2),
                            dtype=np.uint32)
    else:
        seed = rng.integers(0, 2 ** 32, size=2, dtype=np.uint32)
    t, it, cm = we_rounds_grid(lam_rows, seed, n0=float(N),
                               threshold=float(threshold), cap=cap,
                               known=bool(known),
                               max_iter=int(cfg.max_iterations), mesh=mesh,
                               rate_schedule=sched_rows)
    return t[:B], it[:B], cm[:B]


def gamma_rows_pallas(shape_rows: np.ndarray, scale_rows: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
    """Counter-based Threefry + MT-transform Gamma rows (one dispatch;
    ``shape_rows``/``scale_rows`` broadcast like the other backends)."""
    from repro.kernels.we_rounds import gamma_rows_grid

    padded_shape, padded_scale, R, _ = _gamma_rows_prep(shape_rows,
                                                        scale_rows)
    seed = rng.integers(0, 2 ** 32, size=2, dtype=np.uint32)
    out = gamma_rows_grid(padded_shape, padded_scale, seed)[:R]
    return np.array(out)      # own the memory: callers sort in place


# ---------------------------------------------------------------------------
# fused whole-panel dispatch: the work-exchange pair in one engine
# ---------------------------------------------------------------------------
#
# A figure's per-scheme loop dispatches the known and the unknown
# work-exchange engines separately, even though both simulate the same
# trials at the same rates.  The panel path fuses them:
#
# * **coupled common random numbers** -- both schemes' trajectories of one
#   trial live in ONE state row and share one bit stream per round (one
#   Gamma normal + tier uniforms + one Binomial normal, transformed at
#   each scheme's own shapes).  Each scheme's marginal distribution is
#   exactly the per-scheme engine's; the positive coupling only stabilizes
#   scheme *differences* (a variance reduction, like the MDS CRN sweep).
# * **straggler compaction** -- the engine runs in short chunks of rounds
#   (``REPRO_PANEL_CHUNK``, default 4); between chunks the host drops
#   finished rows to the next power-of-two bucket, running their final
#   phase immediately.  Late rounds then cost the few surviving stragglers
#   instead of the whole batch -- total work tracks the *mean* round
#   count, the numpy engine's own compaction trick, applied panel-wide.
#
# The numbers come from one stream, so panel results differ from (while
# being statistically equivalent to) the per-scheme dispatches; the
# cross-backend conformance battery pins both against the numpy oracle.

PANEL_CHUNK_ENV = "REPRO_PANEL_CHUNK"


def _panel_chunk() -> int:
    return max(1, int(os.environ.get(PANEL_CHUNK_ENV, "4")))


def _panel_pair_check(cfg_known: ExchangeConfig,
                      cfg_unknown: ExchangeConfig) -> None:
    if (not cfg_known.known_heterogeneity
            or cfg_unknown.known_heterogeneity):
        raise ValueError("panel fusion takes the (known, unknown) "
                         "work-exchange config pair, in that order")
    if (cfg_known.threshold_frac != cfg_unknown.threshold_frac
            or cfg_known.max_iterations != cfg_unknown.max_iterations):
        raise ValueError("panel fusion requires the pair to share "
                         "threshold_frac and max_iterations")


_JAX_PANEL: Dict[bool, Dict[str, Callable]] = {}   # drift? -> stage/final


def _build_jax_panel(drift: bool = False) -> Dict[str, Callable]:
    """The coupled pair engine: a resumable ``stage`` (runs rounds up to a
    traced stop counter, so the host can compact between chunks) and the
    shared-bits ``final`` phase."""
    import jax
    import jax.numpy as jnp

    def pair_gamma(key, a_k, a_u, inv_rate, live_min):
        """One raw bit draw (a normal + the tier's boost uniforms),
        transformed through the mean-exact MT formula at BOTH schemes'
        shapes -- the CRN coupling.  The tier comes from the *joint*
        smallest live share, which is never above either scheme's own, so
        each marginal stays exactly the per-scheme engine's relaxation."""
        kz, ku = jax.random.split(key)
        z = jax.random.normal(kz, a_k.shape)

        def mt_large_z(alpha):
            d = alpha - 1.0 / 3.0
            c = jnp.maximum(1.0 + z / (3.0 * jnp.sqrt(d)), 0.0)
            return d * c ** 3 * inv_rate

        def boosted_z(alpha, lu):
            levels = lu.shape[0]
            boost = alpha < 3.0
            a = jnp.where(boost, alpha + levels, alpha)
            inv_shapes = jnp.stack([1.0 / jnp.maximum(alpha + i, 1e-12)
                                    for i in range(levels)])
            pow_u = jnp.exp((lu * inv_shapes).sum(0))
            return mt_large_z(a) * jnp.where(boost, pow_u, 1.0)

        def tier_large():
            return mt_large_z(a_k), mt_large_z(a_u)

        def tier(levels):
            def draw():
                lu = jnp.log(jax.random.uniform(
                    ku, (levels,) + a_k.shape, minval=1e-12))
                return boosted_z(a_k, lu), boosted_z(a_u, lu)
            return draw

        return jax.lax.cond(
            live_min >= 3.0, tier_large,
            lambda: jax.lax.cond(live_min >= 1.0, tier(2), tier(3)))

    def _stage(st, lam, sched_chunk, round0, round_stop, threshold, cap_u,
               max_iter):
        B, K = lam.shape
        inv_lam0 = jnp.where(lam > 0.0, 1.0 / lam, 0.0)
        lam_sum = lam.sum(1)
        prior = jnp.where(lam > 0.0, 1.0, 0.0)
        CH = sched_chunk.shape[1] if drift else 1

        def cond(s):
            return ((s["round"] < round_stop)
                    & (s["active_k"] | s["active_u"]).any())

        def body(s):
            key, kg, kb = jax.random.split(s["key"], 3)
            if drift:
                # the chunk schedule is host-sliced so row j is global
                # round round0 + j; all active rows share the scalar trip
                # counter (iters == round), same argument as the
                # per-scheme drift engines
                j = jnp.clip(s["round"] - round0, 0, CH - 1)
                inv_lam = 1.0 / jax.lax.dynamic_slice_in_dim(
                    sched_chunk, j, 1, axis=1)[:, 0, :]
            else:
                inv_lam = inv_lam0
            share_k = lam * (s["n_rem_k"] / lam_sum)[:, None]
            rates_u = s["lam_hat"]
            share_u = rates_u * (s["n_rem_u"] / rates_u.sum(1))[:, None]
            assign_u = jnp.minimum(share_u, cap_u)
            busy_k = share_k > 0.5
            busy_u = assign_u > 0.5
            live = lambda a, b, act: jnp.where(       # noqa: E731
                b & act[:, None], a, jnp.inf)
            live_min = jnp.minimum(
                live(share_k, busy_k, s["active_k"]).min(),
                live(assign_u, busy_u, s["active_u"]).min())
            t_raw_k, t_raw_u = pair_gamma(
                kg, jnp.maximum(share_k, 0.5), jnp.maximum(assign_u, 0.5),
                inv_lam, live_min)
            z_b = jax.random.normal(kb, (B, K))
            out = {"key": key, "round": s["round"] + jnp.int32(1)}

            def branch(sfx, assign, busy, t_raw):
                """One scheme's round update off the shared bits -- the
                same arithmetic as the per-scheme engine body."""
                t_k = jnp.where(busy, t_raw, jnp.inf)
                t_star = t_k.min(1)
                proceed = s["active_" + sfx] & jnp.isfinite(t_star)
                fin = t_k == t_star[:, None]
                p = jnp.clip(t_star[:, None] / t_k, 0.0, 1.0)
                n = jnp.maximum(assign - 1.0, 0.0)
                done = jnp.clip(n * p + z_b * jnp.sqrt(
                    jnp.maximum(n * p * (1.0 - p), 0.0)), 0.0, n)
                done = jnp.where(fin, assign, jnp.where(busy, done, 0.0))
                n_rem = s["n_rem_" + sfx] - done.sum(1)
                started = s["iters_" + sfx] > 0
                comm = jnp.maximum(assign - s["n_left_" + sfx], 0.0).sum(1)
                upd = lambda new, old: jnp.where(     # noqa: E731
                    proceed if new.ndim == 1 else proceed[:, None],
                    new, old)
                iters = s["iters_" + sfx] + proceed
                n_rem_m = upd(n_rem, s["n_rem_" + sfx])
                out["n_rem_" + sfx] = n_rem_m
                out["n_left_" + sfx] = upd(assign - done,
                                           s["n_left_" + sfx])
                out["t_comp_" + sfx] = upd(s["t_comp_" + sfx] + t_star,
                                           s["t_comp_" + sfx])
                out["n_comm_" + sfx] = upd(
                    s["n_comm_" + sfx] + jnp.where(started, comm, 0.0),
                    s["n_comm_" + sfx])
                out["iters_" + sfx] = iters
                out["active_" + sfx] = (proceed & (n_rem_m > threshold)
                                        & (iters < max_iter))
                return done, t_star, upd

            branch("k", share_k, busy_k, t_raw_k)
            done_u, t_star_u, upd_u = branch("u", assign_u, busy_u,
                                             t_raw_u)
            ed = s["est_done"] + done_u
            et = s["est_time"] + t_star_u
            out["est_done"] = ed
            out["est_time"] = et
            out["lam_hat"] = upd_u(
                jnp.where(ed > 0.0, ed / jnp.maximum(et, 1e-30)[:, None],
                          prior),
                s["lam_hat"])
            return out

        return jax.lax.while_loop(cond, body, st)

    def _final(key, lam, inv_k, inv_u, st):
        """Both final phases off one shared raw draw (z + 3 boost
        uniforms, the full 3-chain as in the per-scheme final)."""
        kz, ku = jax.random.split(key)
        z = jax.random.normal(kz, lam.shape)
        lu = jnp.log(jax.random.uniform(ku, (3,) + lam.shape,
                                        minval=1e-12))

        def g(alpha, inv_rate):
            boost = alpha < 3.0
            a = jnp.where(boost, alpha + 3.0, alpha)
            d = a - 1.0 / 3.0
            c = jnp.maximum(1.0 + z / (3.0 * jnp.sqrt(d)), 0.0)
            inv_shapes = jnp.stack([1.0 / jnp.maximum(alpha + i, 1e-12)
                                    for i in range(3)])
            pow_u = jnp.exp((lu * inv_shapes).sum(0))
            return d * c ** 3 * inv_rate * jnp.where(boost, pow_u, 1.0)

        def fin(sfx, rates, inv_lam):
            has_rem = st["n_rem_" + sfx] > 1e-6
            share = rates * (st["n_rem_" + sfx]
                             / rates.sum(1))[:, None]
            comm = jnp.maximum(share - st["n_left_" + sfx], 0.0).sum(1)
            t_k = jnp.where(share > 1e-9,
                            g(jnp.maximum(share, 1e-9), inv_lam), 0.0)
            t_comp = st["t_comp_" + sfx] + jnp.where(has_rem, t_k.max(1),
                                                     0.0)
            n_comm = st["n_comm_" + sfx] + jnp.where(
                has_rem & (st["iters_" + sfx] > 0), comm, 0.0)
            iters = st["iters_" + sfx] + has_rem
            return t_comp, iters.astype(jnp.float32), n_comm

        return fin("k", lam, inv_k) + fin("u", st["lam_hat"], inv_u)

    if drift:
        stage = jax.jit(_stage)
    else:
        stage = jax.jit(
            lambda st, lam, round_stop, threshold, cap_u, max_iter:
            _stage(st, lam, None, 0, round_stop, threshold, cap_u,
                   max_iter))
    return {"stage": stage, "final": jax.jit(_final)}


def _get_jax_panel(drift: bool = False) -> Dict[str, Callable]:
    if drift not in _JAX_PANEL:
        _JAX_PANEL[drift] = _build_jax_panel(drift)
    return _JAX_PANEL[drift]


def work_exchange_panel_jax(lam: np.ndarray, N: int,
                            cfg_known: ExchangeConfig,
                            cfg_unknown: ExchangeConfig,
                            trials: int, rng: np.random.Generator,
                            rate_schedule: Optional[np.ndarray] = None
                            ) -> Dict[str, GridArrays]:
    """The work-exchange pair over a whole ``(G, K)`` panel in one fused
    engine (coupled CRN rounds + host-side straggler compaction; see the
    section comment).  Returns ``{"known": (t, it, cm), "unknown": ...}``
    in the usual grid-major layout."""
    import jax
    import jax.numpy as jnp

    _panel_pair_check(cfg_known, cfg_unknown)
    lam = np.asarray(lam, dtype=np.float32)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    G, K = lam.shape
    N = float(N)
    threshold = cfg_known.threshold_frac * N / K
    cap_u = (np.inf if cfg_unknown.storage_cap_frac is None
             else float(np.ceil(cfg_unknown.storage_cap_frac * N / K)))
    max_iter = int(cfg_known.max_iterations)
    lam_rows = np.repeat(_pad_cols(lam, bucket_cols(K)), int(trials),
                         axis=0)
    lam_rows, B = _pad_rows(lam_rows)
    Bp, Kb = lam_rows.shape
    drift = rate_schedule is not None
    sched_np = R = None
    if drift:
        sched = np.asarray(rate_schedule, dtype=np.float32)
        if sched.ndim != 3 or sched.shape[0] != G or sched.shape[2] != K:
            raise ValueError(f"rate_schedule must be (G={G}, R, K={K}); "
                             f"got shape {sched.shape}")
        sched = _pad_sched(sched, bucket_rounds(sched.shape[1]),
                           bucket_cols(K))
        sched_np = _pad_rows_like(np.repeat(sched, int(trials), axis=0),
                                  Bp)
        R = sched_np.shape[1]
    fns = _get_jax_panel(drift)
    stage, final = fns["stage"], fns["final"]
    key = jax.random.key(int(rng.integers(2 ** 63 - 1)), impl="rbg")
    key, kfin = jax.random.split(key)
    st = {"key": key, "round": jnp.int32(0),
          "est_done": jnp.zeros((Bp, Kb), jnp.float32),
          "est_time": jnp.zeros(Bp, jnp.float32),
          "lam_hat": jnp.asarray((lam_rows > 0).astype(np.float32))}
    for sfx in ("k", "u"):
        st["n_rem_" + sfx] = jnp.full(Bp, N, jnp.float32)
        st["n_left_" + sfx] = jnp.zeros((Bp, Kb), jnp.float32)
        st["t_comp_" + sfx] = jnp.zeros(Bp, jnp.float32)
        st["n_comm_" + sfx] = jnp.zeros(Bp, jnp.float32)
        st["iters_" + sfx] = jnp.zeros(Bp, jnp.int32)
        st["active_" + sfx] = jnp.full(Bp, N > threshold)
    # idx maps current state rows to original panel rows (-1: dead
    # compaction padding, never finalized); out collects scattered final
    # results as rows drop out
    idx = np.concatenate([np.arange(B), np.full(Bp - B, -1)])
    out = np.zeros((Bp, 6))
    lam_cur = lam_rows
    sched_cur = sched_np
    lam_dev = jnp.asarray(lam_rows)
    chunk = _panel_chunk()
    ncall = [0]
    skip = ("key", "round", "est_done", "est_time")

    def finalize(sub, cur_st, cur_idx, cur_lam):
        """Final-phase the given current-state rows; scatter to out."""
        sub = sub[cur_idx[sub] >= 0]
        if sub.size == 0:
            return
        n = sub.size
        tgt = _rows_target(n)
        gather = np.concatenate([sub, np.repeat(sub[:1], tgt - n)])
        gidx = jnp.asarray(gather)
        st_sub = {kk: vv[gidx] for kk, vv in cur_st.items()
                  if kk not in skip}
        orig = cur_idx[gather]
        lam_sub = cur_lam[gather]
        if drift:
            it_k = np.asarray(cur_st["iters_k"])[gather]
            it_u = np.asarray(cur_st["iters_u"])[gather]
            rk = sched_np[orig, np.minimum(it_k, R - 1)]
            ru = sched_np[orig, np.minimum(it_u, R - 1)]
        else:
            rk = ru = lam_sub
        inv_k = np.where(rk > 0, 1.0 / np.maximum(rk, 1e-30),
                         0.0).astype(np.float32)
        inv_u = np.where(ru > 0, 1.0 / np.maximum(ru, 1e-30),
                         0.0).astype(np.float32)
        res = final(jax.random.fold_in(kfin, ncall[0]),
                    jnp.asarray(lam_sub), jnp.asarray(inv_k),
                    jnp.asarray(inv_u), st_sub)
        ncall[0] += 1
        rows = cur_idx[sub]
        for j, arr in enumerate(res):
            out[rows, j] = np.asarray(arr)[:n]

    r0 = 0
    while True:
        r1 = min(r0 + chunk, max_iter)
        if drift:
            cols = np.minimum(np.arange(r0, r1), R - 1)
            st = stage(st, lam_dev,
                       jnp.asarray(sched_cur[:, cols, :]),
                       jnp.int32(r0), jnp.int32(r1), threshold, cap_u,
                       max_iter)
        else:
            st = stage(st, lam_dev, jnp.int32(r1), threshold, cap_u,
                       max_iter)
        r0 = r1
        act = np.asarray(st["active_k"] | st["active_u"])
        live = np.flatnonzero(act & (idx >= 0))
        if live.size == 0 or r0 >= max_iter:
            finalize(np.flatnonzero(idx >= 0), st, idx, lam_cur)
            break
        tgt = max(_rows_target(live.size), 256)
        if tgt < idx.size:
            # compact: final-phase the frozen rows now, gather the rest
            # into the next bucket (padding gets active forced off and
            # idx -1, so it is never finalized)
            finalize(np.flatnonzero(~act & (idx >= 0)), st, idx, lam_cur)
            gather = np.concatenate(
                [live, np.repeat(live[:1], tgt - live.size)])
            gidx = jnp.asarray(gather)
            valid = jnp.arange(tgt) < live.size
            st = {kk: (vv if kk in ("key", "round") else vv[gidx])
                  for kk, vv in st.items()}
            st["active_k"] = st["active_k"] & valid
            st["active_u"] = st["active_u"] & valid
            idx = np.where(np.asarray(valid), idx[gather], -1)
            lam_cur = lam_cur[gather]
            lam_dev = jnp.asarray(lam_cur)
            if drift:
                sched_cur = sched_cur[gather]
    known = tuple(out[:B, j].astype(np.float64) for j in range(3))
    unknown = tuple(out[:B, j].astype(np.float64) for j in range(3, 6))
    return {"known": known, "unknown": unknown}


def work_exchange_panel_pallas(lam: np.ndarray, N: int,
                               cfg_known: ExchangeConfig,
                               cfg_unknown: ExchangeConfig,
                               trials: int, rng: np.random.Generator,
                               rate_schedule: Optional[np.ndarray] = None
                               ) -> Dict[str, GridArrays]:
    """The pair as ONE ``we_rounds`` launch: known rows stacked on top of
    unknown rows with a per-row flag column, so the whole figure is a
    single tiled kernel pass.  With a grid mesh active the stacked rows
    shard over the devices (flags travel with their rows); each shard
    keys its Threefry counters from its own seed pair, so sharded runs
    are statistically equivalent -- not bit-identical -- to the
    single-device launch."""
    from repro.kernels.we_rounds import we_rounds_grid

    _panel_pair_check(cfg_known, cfg_unknown)
    lam = np.asarray(lam, dtype=np.float32)
    if lam.ndim != 2:
        raise ValueError(f"lam must be (G, K); got shape {lam.shape}")
    G, K = lam.shape
    threshold = cfg_known.threshold_frac * N / K
    cap_u = (np.inf if cfg_unknown.storage_cap_frac is None
             else float(np.ceil(cfg_unknown.storage_cap_frac * N / K)))
    half = np.repeat(_pad_cols(lam, bucket_cols(K)), int(trials), axis=0)
    B = half.shape[0]
    stacked = np.concatenate([half, half])
    flags = np.concatenate([np.ones(B, np.float32),
                            np.zeros(B, np.float32)])
    stacked, _ = _pad_rows(stacked, bucket=128)
    flags = np.concatenate(
        [flags, np.ones(stacked.shape[0] - 2 * B, np.float32)])
    sched_rows = None
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float32)
        if sched.ndim != 3 or sched.shape[0] != G or sched.shape[2] != K:
            raise ValueError(f"rate_schedule must be (G={G}, R, K={K}); "
                             f"got shape {sched.shape}")
        sched = _pad_sched(sched, bucket_rounds(sched.shape[1]),
                           bucket_cols(K))
        sched_half = np.repeat(sched, int(trials), axis=0)
        sched_rows = _pad_rows_like(
            np.concatenate([sched_half, sched_half]), stacked.shape[0])
    mesh = active_grid_mesh()
    if mesh is not None:
        # sharded launch: one independent seed pair per device (same
        # discipline as work_exchange_grid_pallas)
        seed = rng.integers(0, 2 ** 32, size=(int(mesh.size), 2),
                            dtype=np.uint32)
    else:
        seed = rng.integers(0, 2 ** 32, size=2, dtype=np.uint32)
    t, it, cm = we_rounds_grid(stacked, seed, n0=float(N),
                               threshold=float(threshold), cap=cap_u,
                               known=flags,
                               max_iter=int(cfg_known.max_iterations),
                               mesh=mesh, rate_schedule=sched_rows)
    return {"known": (t[:B], it[:B], cm[:B]),
            "unknown": (t[B:2 * B], it[B:2 * B], cm[B:2 * B])}


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

register_backend(SamplerBackend(
    name="numpy",
    work_exchange_grid=work_exchange_grid_numpy,
    description="exact integer-unit engine (Generator.gamma/binomial); "
                "bit-identical to the scalar reference at trials=1",
    gamma_rows=gamma_rows_numpy))

register_backend(SamplerBackend(
    name="jax",
    work_exchange_grid=work_exchange_grid_jax,
    description="one jitted fluid-relaxation pipeline (mean-exact MT gamma "
                "+ normal-limit binomial, float32); statistically "
                "equivalent, not bit-identical",
    gamma_rows=gamma_rows_jax,
    coupled_mds_sweep=True,
    work_exchange_panel=work_exchange_panel_jax),
    available=_jax_available)

register_backend(SamplerBackend(
    name="pallas",
    work_exchange_grid=work_exchange_grid_pallas,
    description="fused we_rounds Pallas kernel (counter-based Threefry "
                "bits + MT gamma + argmin + normal-limit binomial in one "
                "tiled pass); compiled on TPU, bit-identical jnp "
                "reference / interpreted kernel on CPU",
    gamma_rows=gamma_rows_pallas,
    coupled_mds_sweep=True,
    work_exchange_panel=work_exchange_panel_pallas),
    available=_jax_available)


__all__ = [
    "ENV_VAR", "DEFAULT_BACKEND", "SAMPLER_BACKENDS", "SamplerBackend",
    "register_backend", "get_backend", "list_backends", "resolve_backend",
    "validate_backend", "get_gamma_rows",
    "grid_sharding", "active_grid_mesh",
    "work_exchange_grid_numpy", "work_exchange_grid_jax",
    "work_exchange_grid_pallas", "gamma_rows_numpy", "gamma_rows_jax",
    "gamma_rows_pallas", "work_exchange_panel_jax",
    "work_exchange_panel_pallas",
]
