"""Work assignment rules (paper eqs. 14, 16, 18, 22, 24).

All assignments are integral: the paper works with real-valued point counts;
we round with the largest-remainder method so that the assignment exactly
sums to the intended total (work conservation at the unit level).
"""
from __future__ import annotations

import numpy as np


def largest_remainder_round(shares: np.ndarray, total: int) -> np.ndarray:
    """Round non-negative real shares (summing ~ total) to ints summing to total.

    The trial-batched variant (`largest_remainder_round_batch`) applies the
    same sort routine per row, so both paths break remainder ties
    identically and produce bit-identical assignments from the same inputs.
    """
    shares = np.asarray(shares, dtype=np.float64)
    if total == 0:
        return np.zeros_like(shares, dtype=np.int64)
    if shares.sum() <= 0:
        shares = np.ones_like(shares)
    scaled = shares * (total / shares.sum())
    floor = np.floor(scaled).astype(np.int64)
    short = total - int(floor.sum())
    if short > 0:
        order = np.argsort(-(scaled - floor))  # biggest remainders first
        floor[order[:short]] += 1
    return floor


def largest_remainder_round_batch(shares: np.ndarray,
                                  totals: np.ndarray) -> np.ndarray:
    """Row-wise ``largest_remainder_round``: shares (T, K), totals (T,).

    Each row i is rounded exactly as ``largest_remainder_round(shares[i],
    totals[i])`` would round it (same ones-fallback for degenerate rows, same
    stable tie-break), but in O(T K log K) vectorized work with no Python
    loop over trials.
    """
    shares = np.asarray(shares, dtype=np.float64)
    totals = np.asarray(totals, dtype=np.int64)
    T, K = shares.shape
    row_sum = shares.sum(axis=1)
    if (row_sum <= 0).any():
        shares = np.where((row_sum <= 0)[:, None], 1.0, shares)
        row_sum = shares.sum(axis=1)
    scaled = shares * (totals / row_sum)[:, None]
    floor = scaled.astype(np.int64)        # scaled >= 0, so trunc == floor
    short = totals - floor.sum(axis=1)
    order = np.argsort(floor - scaled, axis=1)
    rank = np.empty_like(order)
    np.put_along_axis(rank, order, np.broadcast_to(np.arange(K), (T, K)), 1)
    floor += rank < short[:, None]
    if (totals == 0).any():
        floor = np.where((totals == 0)[:, None], 0, floor)
    return floor


def proportional_assignment(lambdas: np.ndarray, n_rem: int) -> np.ndarray:
    """Eq. (16)/(18): N_assign^(k) = lambda_k * N_rem / lambda_sum, integral."""
    return largest_remainder_round(np.asarray(lambdas, np.float64), n_rem)


def capped_proportional_assignment(lambdas: np.ndarray, n_rem: int,
                                   cap: int) -> np.ndarray:
    """Eq. (22)/(24): min(cap, lambda_k * N_rem / lambda_sum).

    Per Algorithm 3, the capped assignment may not exhaust ``n_rem``; the
    shortfall is *carried over* to the next iteration by the caller.
    Water-filling refinement: units freed by the cap are re-offered to
    uncapped workers proportionally (still respecting the cap), which
    strictly reduces the carried remainder without violating storage.
    """
    lam = np.asarray(lambdas, dtype=np.float64)
    K = lam.size
    assign = np.zeros(K, dtype=np.int64)
    remaining = int(n_rem)
    active = np.ones(K, dtype=bool)
    # Iterate the water-filling: at most K rounds (each round caps >=1 worker
    # or distributes everything).
    for _ in range(K):
        if remaining <= 0 or not active.any():
            break
        share = largest_remainder_round(
            np.where(active, lam, 0.0), remaining)
        room = cap - assign
        take = np.minimum(share, np.maximum(room, 0))
        assign += take
        remaining -= int(take.sum())
        newly_capped = assign >= cap
        if not (newly_capped & active).any():
            break
        active &= ~newly_capped
    return assign


def capped_proportional_assignment_batch(lambdas: np.ndarray,
                                         n_rem: np.ndarray,
                                         cap: int) -> np.ndarray:
    """Row-wise ``capped_proportional_assignment``: lambdas (T, K), n_rem (T,).

    Replays the scalar water-filling rounds for every trial at once; trials
    exit the round loop independently (same break conditions as the scalar
    code), so row i equals ``capped_proportional_assignment(lambdas[i],
    n_rem[i], cap)`` exactly.
    """
    lam = np.asarray(lambdas, dtype=np.float64)
    T, K = lam.shape
    assign = np.zeros((T, K), dtype=np.int64)
    remaining = np.asarray(n_rem, dtype=np.int64).copy()
    active = np.ones((T, K), dtype=bool)
    looping = np.ones(T, dtype=bool)
    for _ in range(K):
        looping &= (remaining > 0) & active.any(axis=1)
        if not looping.any():
            break
        share = largest_remainder_round_batch(np.where(active, lam, 0.0),
                                              np.where(looping, remaining, 0))
        room = cap - assign
        take = np.minimum(share, np.maximum(room, 0))
        take = np.where(looping[:, None], take, 0)
        assign += take
        remaining -= take.sum(axis=1)
        newly_capped = assign >= cap
        looping &= (newly_capped & active).any(axis=1)
        active &= ~newly_capped
    return assign


def uniform_assignment(K: int, n: int) -> np.ndarray:
    """Initial assignment of the unknown-heterogeneity variant: N/K each."""
    return largest_remainder_round(np.ones(K), n)


def water_filling_view(lambdas: np.ndarray, n: int) -> np.ndarray:
    """The oracle allocation (Cor. 2) seen as water-filling: every worker's
    *finish time* is equalized at N/lambda_sum; faster channels (higher
    lambda) absorb more load. Returns per-worker expected finish times."""
    lam = np.asarray(lambdas, np.float64)
    alloc = lam * (n / lam.sum())
    return alloc / lam  # == n/lam.sum() for every worker: the "water level"
