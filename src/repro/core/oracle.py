"""Oracle lower bound via the work-conservation principle (paper Section 4).

Theorem 1:  E[T_comp^oracle] = N / lambda_sum.
Corollary 2: E[N_done^(k)]   = N * lambda_k / lambda_sum.

Under the oracle's assumptions (full data everywhere, perfect coordination,
nobody idle, no overlap) the K independent Poisson service processes merge
into one Poisson process of rate lambda_sum, so the completion time of N
units is Gamma(N, lambda_sum)-distributed.  ``oracle_time_samples`` exploits
that identity for exact Monte-Carlo sampling; ``oracle_mean_time_enumerated``
evaluates the paper's finite sum (eqs. 8-12) term by term, which is used in
tests to confirm the telescoping to N/lambda_sum.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from .types import HetSpec


def oracle_mean_time(het: HetSpec, N: int) -> float:
    """Theorem 1 closed form."""
    return N / het.lambda_sum


def oracle_expected_done(het: HetSpec, N: int) -> np.ndarray:
    """Corollary 2: water-filling-like proportional split."""
    return N * het.lambdas / het.lambda_sum


def oracle_time_samples(het: HetSpec, N: int, trials: int,
                        rng: np.random.Generator) -> np.ndarray:
    """Exact samples of T_comp^oracle = N-th arrival of the merged process."""
    return rng.gamma(shape=N, scale=1.0 / het.lambda_sum, size=trials)


def oracle_mean_time_enumerated(het: HetSpec, N: int) -> float:
    """Paper eqs. (10)-(11): E[T] = sum over {n: n_sum < N} of
    (1/lam_sum) * multinomial(n_sum; n) * prod_k (lam_k/lam_sum)^{n_k}.

    Exponential-cost enumeration -- only for small N, K (tests of Thm 1's
    internal consistency: the sum telescopes to N/lambda_sum).
    """
    lam = het.lambdas
    K = het.K
    lam_sum = het.lambda_sum
    p = lam / lam_sum
    total = 0.0
    # enumerate all n with n_1 + ... + n_K = n for n in [0, N)
    for n in range(N):
        for comp in _compositions(n, K):
            coef = math.factorial(n)
            for c in comp:
                coef //= math.factorial(c)
            total += coef * float(np.prod(p ** np.array(comp)))
    return total / lam_sum


def _compositions(n: int, k: int):
    """All k-tuples of non-negative ints summing to n."""
    if k == 1:
        yield (n,)
        return
    for first in range(n + 1):
        for rest in _compositions(n - first, k - 1):
            yield (first,) + rest
