"""Shared dataclasses for the work-exchange core.

Terminology follows the paper (Attia & Tandon, 2017):
  N        -- total number of work units ("data points")
  K        -- number of workers
  lambdas  -- heterogeneity set, one Poisson service rate per worker
  I        -- number of reassignment iterations (coordination rounds)
  N_comm   -- extra communication: units shipped beyond a worker's leftover
              from the previous assignment (eq. 1-2)
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class HetSpec:
    """Heterogeneity description of a K-worker cluster.

    Value-semantic: two specs with the same rate vector compare equal,
    hash equal, and round-trip losslessly through ``to_dict`` /
    ``from_dict`` (floats survive JSON exactly -- shortest-repr
    round-trip), so a spec can key a dict, live in a set, and address a
    results-store entry (``canonical_hash``).
    """

    lambdas: np.ndarray  # shape (K,), rates > 0 (units/sec)

    def __post_init__(self):
        # always copy: the array is frozen below and must not alias (and
        # thereby freeze) a caller-owned buffer
        lam = np.array(self.lambdas, dtype=np.float64)
        if lam.ndim != 1 or lam.size == 0:
            raise ValueError("lambdas must be a non-empty 1-D array")
        if np.any(lam < 0) or not np.all(np.isfinite(lam)):
            raise ValueError("lambdas must be finite and non-negative")
        lam.setflags(write=False)
        object.__setattr__(self, "lambdas", lam)

    @property
    def K(self) -> int:
        return int(self.lambdas.size)

    @property
    def lambda_sum(self) -> float:
        return float(self.lambdas.sum())

    # -- value semantics ----------------------------------------------------

    def __eq__(self, other) -> bool:
        if not isinstance(other, HetSpec):
            return NotImplemented
        return (self.lambdas.shape == other.lambdas.shape
                and bool(np.all(self.lambdas == other.lambdas)))

    def __hash__(self) -> int:
        return hash(self._canonical_bytes())

    def _canonical_bytes(self) -> bytes:
        # fixed endianness so the hash is platform-stable
        return self.lambdas.astype(">f8").tobytes()

    def canonical_hash(self) -> str:
        """Stable content hash of the exact float64 rate vector."""
        return hashlib.sha256(self._canonical_bytes()).hexdigest()

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able dict; exact (float -> shortest repr -> same float)."""
        return {"lambdas": [float(x) for x in self.lambdas]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HetSpec":
        return cls(np.asarray(d["lambdas"], dtype=np.float64))

    @staticmethod
    def uniform_random(K: int, mu: float, sigma2: float,
                       rng: np.random.Generator) -> "HetSpec":
        """Paper Section 7: lambda_k ~ Uniform(mu - sqrt(3 sigma^2), mu + sqrt(3 sigma^2)).

        Requires 0 <= sigma2 <= mu^2/3 so rates stay non-negative.
        """
        if not 0 <= sigma2 <= mu * mu / 3 + 1e-12:
            raise ValueError(f"sigma2 must be in [0, mu^2/3]; got {sigma2}")
        half = np.sqrt(3.0 * sigma2)
        lam = rng.uniform(mu - half, mu + half, size=K)
        return HetSpec(np.maximum(lam, 1e-12))


@dataclasses.dataclass
class RunStats:
    """Outcome of one simulated (or real) run of a scheduling policy."""

    t_comp: float              # total computation time (sum over iterations)
    iterations: int            # I, number of reassignment epochs
    n_comm: float              # extra communication in units (eq. 2)
    n_done: np.ndarray         # per-worker totals, shape (K,)
    t_iter: Optional[np.ndarray] = None  # per-iteration durations

    def check_work_conserved(self, N: int) -> None:
        total = int(round(float(self.n_done.sum())))
        if total != N:
            raise AssertionError(
                f"work conservation violated: processed {total} of {N}")


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    """Knobs of the work-exchange master protocol (Algorithms 1 & 3)."""

    known_heterogeneity: bool = True
    # Cutting threshold (Remark 1): stop reassigning once N_rem <= threshold
    # and wait for all workers. The paper default is 0.01 * N/K.
    threshold_frac: float = 0.01     # of N/K
    # Storage cap per worker for the unknown-het variant (Section 6): N/K.
    storage_cap_frac: Optional[float] = 1.0   # of N/K; None = uncapped
    max_iterations: int = 10_000
