"""Coded-computation baselines in executable form.

1. ``MDSCodedMatmul`` -- the paper's original setting: (K, L) MDS-coded
   distributed matrix-vector multiplication with a real Vandermonde encode
   and a real decode from ANY L of K replies (Section 3 / Figure 1a).

2. ``GradientCoding``  -- the ML analogue for non-linear work: the gradient
   *sum* is linear in per-unit gradients, so a fractional-repetition code
   over units lets the master recover the exact full-batch gradient from
   any (K - s) workers (tolerating s stragglers).  This is the natural
   translation of the paper's MDS baseline to training (DESIGN §3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# (K, L) MDS coded matmul
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MDSCodedMatmul:
    """Encode A (rows) into K coded chunks; decode Ax from any L replies."""

    K: int
    L: int

    def encode(self, A: np.ndarray) -> List[np.ndarray]:
        n = A.shape[0]
        if n % self.L:
            pad = self.L - n % self.L
            A = np.concatenate([A, np.zeros((pad, *A.shape[1:]), A.dtype)], 0)
        self._orig_rows = n
        chunks = np.stack(np.split(A, self.L, axis=0))     # (L, n/L, d)
        # Vandermonde generator: row k of G codes chunk-space -> worker k
        alphas = np.arange(1, self.K + 1, dtype=np.float64)
        self.G = np.vander(alphas, N=self.L, increasing=True)  # (K, L)
        return [np.tensordot(self.G[k], chunks, axes=(0, 0))
                for k in range(self.K)]

    def decode(self, replies: dict[int, np.ndarray]) -> np.ndarray:
        """replies: worker index -> coded chunk result (any >= L of them)."""
        if len(replies) < self.L:
            raise ValueError(f"need >= {self.L} replies, got {len(replies)}")
        idx = sorted(replies)[: self.L]
        Gs = self.G[idx]                                   # (L, L)
        Y = np.stack([replies[i] for i in idx])            # (L, m, ...)
        flat = Y.reshape(self.L, -1)
        decoded = np.linalg.solve(Gs, flat).reshape(Y.shape)
        out = np.concatenate(list(decoded), axis=0)
        return out[: self._orig_rows]


# ---------------------------------------------------------------------------
# fractional-repetition gradient coding
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GradientCoding:
    """Fractional-repetition gradient code tolerating ``s`` stragglers.

    Requires (s+1) | K.  Workers are split into s+1 replica groups; each
    group partitions the units.  Every unit is computed by exactly s+1
    workers; the master recovers the exact gradient sum from any K-s
    replies by, per unit, using one surviving owner.
    """

    K: int
    s: int

    def __post_init__(self):
        if self.K % (self.s + 1):
            raise ValueError("fractional repetition needs (s+1) | K")
        self.group_size = self.K // (self.s + 1)

    def assignment(self, n_units: int) -> List[List[int]]:
        """unit ids owned by each worker (len K)."""
        units = list(range(n_units))
        per = [[] for _ in range(self.K)]
        for g in range(self.s + 1):               # replica group g
            for i, u in enumerate(units):
                w = g * self.group_size + (i % self.group_size)
                per[w].append(u)
        return per

    def decode(self, n_units: int, replies: dict[int, dict[int, np.ndarray]]
               ) -> np.ndarray:
        """replies: worker -> {unit id -> gradient (flat np array)}.

        Any K - s workers suffice; raises if some unit is uncovered.
        """
        covered: dict[int, np.ndarray] = {}
        for w, grads in replies.items():
            for u, g in grads.items():
                covered.setdefault(u, g)
        missing = [u for u in range(n_units) if u not in covered]
        if missing:
            raise ValueError(f"units {missing} uncovered by replies")
        return np.sum(np.stack([covered[u] for u in range(n_units)]), axis=0)

    def redundancy_factor(self) -> float:
        return float(self.s + 1)
