"""Work-exchange core: the paper's contribution as a composable library.

Layout
  types        -- HetSpec / RunStats / ExchangeConfig dataclasses
  oracle       -- Theorem 1 lower bound + Corollary 2 (+ enumerated check)
  erlang       -- exact non-iid Erlang order statistics (eqs. 4-5)
  mds          -- optimized (K, L) MDS baseline (eq. 6), exact + Monte Carlo
  assignment   -- proportional / capped / uniform allocation rules
  estimator    -- online rate estimation (paper eq. 23 + EMA + Bayesian)
  exchange     -- unit-id-level master protocol (Algorithms 1 & 3)
  simulator    -- exact vectorized Monte-Carlo engine (paper figures)
  coded        -- executable MDS matmul + gradient coding baselines
  runtime      -- real-JAX-gradients / virtual-clock heterogeneous runtime
"""
from . import assignment, coded, erlang, estimator, exchange, mds, oracle, simulator
from .types import ExchangeConfig, HetSpec, RunStats

__all__ = [
    "assignment", "coded", "erlang", "estimator", "exchange", "mds",
    "oracle", "simulator", "ExchangeConfig", "HetSpec", "RunStats",
]
