"""Work-exchange core: the paper's contribution as a composable library.

Layout
  types        -- HetSpec / RunStats / ExchangeConfig dataclasses
  oracle       -- Theorem 1 lower bound + Corollary 2 (+ enumerated check)
  erlang       -- exact non-iid Erlang order statistics (eqs. 4-5)
  mds          -- optimized (K, L) MDS baseline (eq. 6), exact + Monte Carlo
  assignment   -- proportional / capped / uniform allocation rules, scalar
                  and trial-batched (largest-remainder, water-filling)
  estimator    -- online rate estimation (paper eq. 23 + EMA + Bayesian)
  exchange     -- unit-id-level master protocol (Algorithms 1 & 3)
  samplers     -- pluggable MC sampler backends (exact numpy engine /
                  fused jitted jax pipeline) behind Scheme.mc + mc_grid;
                  select with REPRO_SAMPLER_BACKEND or mc(..., backend=)
  schemes      -- THE policy surface: Scheme protocol + SCHEME_REGISTRY +
                  trial-vectorized Monte-Carlo engine.  All five paper
                  schemes (fixed, uniform, oracle, mds/mds_opt, work
                  exchange known/unknown) plus scenario schemes (het_mds,
                  trace_replay, gradient_coded) live here; figures,
                  examples, and the training driver resolve policies via
                  get_scheme(name).
  simulator    -- DEPRECATED free-function shims over ``schemes``
  coded        -- executable MDS matmul + gradient coding baselines
  runtime      -- real-JAX-gradients / virtual-clock heterogeneous runtime
                  (``VirtualWorkerPool`` incl. measured-trace replay)

Three-line API:

    >>> from repro.core import HetSpec, get_scheme
    >>> het = HetSpec.uniform_random(50, 50.0, 50.0**2 / 6, rng)
    >>> report = get_scheme("work_exchange").mc(het, N=1_000_000,
    ...                                         trials=100, rng=rng)
"""
from . import (assignment, coded, erlang, estimator, exchange, mds, oracle,
               registry, samplers, schemes, simulator)
from .registry import Registry
from .samplers import (SAMPLER_BACKENDS, get_backend, list_backends,
                       register_backend, resolve_backend)
from .schemes import (MCReport, Scheme, SCHEME_REGISTRY, get_scheme,
                      list_schemes, register_scheme)
from .types import ExchangeConfig, HetSpec, RunStats

__all__ = [
    "assignment", "coded", "erlang", "estimator", "exchange", "mds",
    "oracle", "registry", "samplers", "schemes", "simulator", "Registry",
    "MCReport", "Scheme", "SCHEME_REGISTRY", "get_scheme", "list_schemes",
    "register_scheme",
    "SAMPLER_BACKENDS", "get_backend", "list_backends", "register_backend",
    "resolve_backend",
    "ExchangeConfig", "HetSpec", "RunStats",
]
