"""Unified ``Scheme`` API: one registry-driven policy surface.

Every scheduling policy in the repo -- the paper's five (fixed, oracle,
MDS / optimized MDS, work exchange with known/unknown heterogeneity) and
the beyond-paper scenario schemes (heterogeneous-coded ``het_mds``,
``trace_replay``, ``gradient_coded``) -- implements the same three-method
surface:

    plan(het, N)                -> Assignment   (id-level initial queues)
    simulate(het, N, rng)       -> RunStats     (one exact trial)
    mc(het, N, trials, rng)     -> MCReport     (uniform mean/std report)

Schemes are string-keyed in ``SCHEME_REGISTRY`` (the same pattern as
``repro.configs.ARCHS``): ``@register_scheme`` / ``get_scheme`` /
``list_schemes``.  Adding a scheme here makes it reachable from every
figure driver (``benchmarks/fig5|6|7``), the examples, and the training
driver (``distributed/hetsched.py``) with no further wiring:

    >>> rng = np.random.default_rng(0)
    >>> het = HetSpec.uniform_random(50, mu=50.0, sigma2=50**2/6, rng=rng)
    >>> get_scheme("work_exchange").mc(het, N=1_000_000, trials=100, rng=rng)

The work-exchange Monte Carlo is fully vectorized across trials (batched
Gamma/argmin/Binomial under a per-trial active mask); the scalar
single-trial path is kept both as the per-trial reference the batched
engine is validated against seed-for-seed (``engine="loop"``) and as the
``simulate`` implementation.

The draw pipeline itself is pluggable (``repro.core.samplers``): the
``numpy`` backend is the exact engine above, the ``jax`` backend fuses the
whole round pipeline into one jitted dispatch.  Select per call
(``mc(..., backend="jax")``) or globally (``REPRO_SAMPLER_BACKEND``).  On
top of it, ``mc_grid(het_specs, N, trials, rng)`` batches a whole
``(mu, sigma^2)`` scenario grid through one engine call instead of a
Python loop of ``mc()``s -- the figure drivers are one dispatch per panel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Sequence, Tuple, Type

import numpy as np

from .assignment import (capped_proportional_assignment,
                         largest_remainder_round, proportional_assignment,
                         uniform_assignment)
from .exchange import Assignment, MasterScheduler
from .registry import Registry
from .samplers import (get_backend, get_gamma_rows, resolve_backend,
                       validate_backend)
from .types import ExchangeConfig, HetSpec, RunStats


# ---------------------------------------------------------------------------
# uniform Monte-Carlo report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MCReport:
    """What every scheme's ``mc`` returns: same shape for all policies.

    Means/stds are over trials.  Per-trial arrays are attached only when
    ``mc(..., keep_trials=True)`` -- the report stays cheap by default.
    ``extra`` carries scheme-specific derived values (e.g. the optimized
    MDS ``L``); the uniform fields never move there.
    """

    scheme: str
    trials: int
    t_comp: float               # mean completion time
    t_comp_std: float
    iterations: float           # mean reassignment epochs I
    iterations_std: float
    n_comm: float               # mean extra communication (units, eq. 2)
    n_comm_std: float
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)
    t_comp_trials: Optional[np.ndarray] = None
    iterations_trials: Optional[np.ndarray] = None
    n_comm_trials: Optional[np.ndarray] = None

    # legacy ExchangeMC field names (pre-registry callers)
    @property
    def t_std(self) -> float:
        return self.t_comp_std

    @property
    def i_std(self) -> float:
        return self.iterations_std

    @property
    def c_std(self) -> float:
        return self.n_comm_std

    # -- serialization (the results-store record format) ---------------------

    def to_dict(self, include_trials: bool = True) -> Dict:
        """JSON-able dict; the per-trial arrays ride along (as lists) only
        when attached AND ``include_trials`` -- stored reports stay small
        by default because ``mc(keep_trials=False)`` never attaches them."""
        d = {
            "scheme": self.scheme, "trials": self.trials,
            "t_comp": self.t_comp, "t_comp_std": self.t_comp_std,
            "iterations": self.iterations,
            "iterations_std": self.iterations_std,
            "n_comm": self.n_comm, "n_comm_std": self.n_comm_std,
            "extra": dict(self.extra),
        }
        if include_trials:
            for field in ("t_comp_trials", "iterations_trials",
                          "n_comm_trials"):
                arr = getattr(self, field)
                if arr is not None:
                    d[field] = [float(x) for x in arr]
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "MCReport":
        trials = {field: (np.asarray(d[field], dtype=np.float64)
                          if d.get(field) is not None else None)
                  for field in ("t_comp_trials", "iterations_trials",
                                "n_comm_trials")}
        return cls(scheme=d["scheme"], trials=int(d["trials"]),
                   t_comp=float(d["t_comp"]),
                   t_comp_std=float(d["t_comp_std"]),
                   iterations=float(d["iterations"]),
                   iterations_std=float(d["iterations_std"]),
                   n_comm=float(d["n_comm"]),
                   n_comm_std=float(d["n_comm_std"]),
                   extra=dict(d.get("extra", {})), **trials)


def _report(scheme: str, ts: np.ndarray, its: np.ndarray, cs: np.ndarray,
            keep_trials: bool = False,
            extra: Optional[Dict[str, float]] = None) -> MCReport:
    ts, its, cs = (np.asarray(a, dtype=np.float64) for a in (ts, its, cs))
    return MCReport(
        scheme=scheme, trials=int(ts.size),
        t_comp=float(ts.mean()), t_comp_std=float(ts.std()),
        iterations=float(its.mean()), iterations_std=float(its.std()),
        n_comm=float(cs.mean()), n_comm_std=float(cs.std()),
        extra=dict(extra or {}),
        t_comp_trials=ts if keep_trials else None,
        iterations_trials=its if keep_trials else None,
        n_comm_trials=cs if keep_trials else None)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

SCHEME_REGISTRY: Registry[Type["Scheme"]] = Registry("scheme",
                                                     dup_label="scheme name")


def register_scheme(name: str, *, aliases: Sequence[str] = ()):
    """Class decorator: key a Scheme subclass under ``name`` (+ aliases)."""
    def deco(cls: Type["Scheme"]) -> Type["Scheme"]:
        SCHEME_REGISTRY.register(name, cls, aliases=aliases)
        cls.name = name
        return cls
    return deco


def get_scheme(name: str, **params) -> "Scheme":
    """Instantiate a registered scheme by canonical name or alias."""
    return SCHEME_REGISTRY.get(name)(**params)


def list_schemes(include_aliases: bool = False) -> List[str]:
    return SCHEME_REGISTRY.names(include_aliases)


# ---------------------------------------------------------------------------
# base class
# ---------------------------------------------------------------------------

class Scheme:
    """Common surface of every scheduling policy.

    Subclasses implement ``initial_sizes`` + ``simulate`` and may override
    ``mc`` with a trial-vectorized engine; the default ``mc`` loops
    ``simulate``.  ``redundant`` marks schemes that ship more than N units
    (coded redundancy), where exact unit-level conservation does not apply.
    """

    name: str = "abstract"
    redundant: bool = False
    plan_wait_all: bool = True    # static schemes wait for the max
    # redundant schemes whose live execution (repro.control) completes at
    # the size-cover instant: finished workers' assigned sizes >= N
    live_cover: bool = False
    # schemes whose mc/mc_grid accept a per-exchange-round rate_schedule
    # (drifting scenario families); single-shot schemes run at the
    # nominal (round-0) rates and leave this False
    supports_rate_schedule: bool = False

    # -- planning -----------------------------------------------------------

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        raise NotImplementedError

    def plan(self, het: HetSpec, N: int) -> Assignment:
        """Initial id-level queues (contiguous unit ids per worker)."""
        sizes = self.initial_sizes(het, N)
        queues: List[List[int]] = []
        nxt = 0
        for s in sizes:
            queues.append(list(range(nxt, nxt + int(s))))
            nxt += int(s)
        return Assignment(queues=queues, wait_all=self.plan_wait_all)

    # -- simulation ---------------------------------------------------------

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        raise NotImplementedError

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None) -> MCReport:
        """Monte-Carlo report over ``trials`` runs.

        ``backend`` selects the sampler backend (``repro.core.samplers``)
        for schemes with a fused draw pipeline; schemes without one --
        this default per-trial loop included -- always draw with numpy,
        but still validate the name so a typo'd ``backend=`` or
        ``REPRO_SAMPLER_BACKEND`` raises a ``KeyError`` listing the
        registered backends instead of being silently ignored.
        """
        validate_backend(backend)
        ts = np.empty(trials)
        its = np.empty(trials)
        cs = np.empty(trials)
        for i in range(trials):
            s = self.simulate(het, N, rng)
            ts[i], its[i], cs[i] = s.t_comp, s.iterations, s.n_comm
        return _report(self.name, ts, its, cs, keep_trials)

    def mc_grid(self, het_specs: Sequence[HetSpec], N: int, trials: int,
                rng: np.random.Generator, keep_trials: bool = False,
                backend: Optional[str] = None) -> List[MCReport]:
        """``mc`` over a scenario grid, one ``MCReport`` per spec.

        The base implementation loops ``mc`` (drawing from the shared
        ``rng`` in spec order); schemes with a batched engine override it
        to run the whole ``len(het_specs) x trials`` batch in one engine
        dispatch.
        """
        return [self.mc(het, N, trials, rng, keep_trials=keep_trials,
                        backend=backend) for het in het_specs]

    # -- executable protocol (training/serving runtimes) --------------------

    def make_scheduler(self, unit_ids: Sequence[int],
                       rates: Optional[np.ndarray] = None,
                       estimator=None,
                       threshold_frac: Optional[float] = None
                       ) -> MasterScheduler:
        raise NotImplementedError(
            f"scheme {self.name!r} has no executable master protocol")


# ---------------------------------------------------------------------------
# scalar single-trial primitives (the reference path)
# ---------------------------------------------------------------------------

def _iteration_outcome(assign: np.ndarray, lambdas: np.ndarray,
                       rng: np.random.Generator):
    """One work-exchange iteration: returns (t_star, done) exactly.

    Poisson-process conditioning: given worker k's n_k-th arrival at T_k,
    the earlier n_k - 1 epochs are uniform order statistics on (0, T_k), so
    N_done | T_k ~ Binomial(n_k - 1, T*/T_k) for non-finishing workers.
    """
    K = assign.size
    t_k = np.full(K, np.inf)
    busy = assign > 0
    t_k[busy] = rng.gamma(shape=assign[busy], scale=1.0 / lambdas[busy])
    finisher = int(np.argmin(t_k))
    t_star = float(t_k[finisher])
    done = np.zeros(K, dtype=np.int64)
    done[finisher] = assign[finisher]
    others = busy.copy()
    others[finisher] = False
    if others.any():
        n = assign[others] - 1
        p = np.clip(t_star / t_k[others], 0.0, 1.0)
        done[others] = rng.binomial(np.maximum(n, 0), p)
    return t_star, done


def _final_phase(assign: np.ndarray, lambdas: np.ndarray,
                 rng: np.random.Generator) -> float:
    """Below the cutting threshold: assign and wait for ALL workers (max)."""
    busy = assign > 0
    if not busy.any():
        return 0.0
    t_k = rng.gamma(shape=assign[busy], scale=1.0 / lambdas[busy])
    return float(t_k.max())


def simulate_work_exchange_scalar(het: HetSpec, N: int, cfg: ExchangeConfig,
                                  rng: np.random.Generator,
                                  capped_mode: Literal["carry", "waterfill"]
                                  = "carry",
                                  rate_schedule: Optional[np.ndarray] = None
                                  ) -> RunStats:
    """Algorithms 1 (known het) and 3 (unknown het), single trial.

    ``rate_schedule`` (optional ``(R, K)``) drives drifting scenarios:
    round ``r``'s service draws use row ``min(r, R - 1)`` while the
    assignment keeps using the nominal ``het.lambdas`` (known) or the
    online estimate (unknown) -- the exact per-trial reference the
    batched drift engines are validated against.
    """
    lam = het.lambdas
    K = het.K
    sched = None
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float64)
        if sched.ndim != 2 or sched.shape[1] != K:
            raise ValueError(f"rate_schedule must be (R, K={K}); "
                             f"got shape {sched.shape}")
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or cfg.known_heterogeneity
           else int(np.ceil(cfg.storage_cap_frac * N / K)))

    # estimator state (paper eq. 23)
    est_done = np.zeros(K, dtype=np.float64)
    est_time = 0.0
    lam_hat = np.ones(K, dtype=np.float64)

    n_rem = N                       # unassigned + leftover units
    n_left_prev = np.zeros(K, dtype=np.int64)   # leftover held by workers
    n_done = np.zeros(K, dtype=np.int64)
    t_comp = 0.0
    n_comm = 0.0
    iters = 0
    t_iter = []

    while n_rem > threshold and iters < cfg.max_iterations:
        rates = lam if cfg.known_heterogeneity else lam_hat
        if np.isinf(cap):
            assign = proportional_assignment(rates, n_rem)
        elif capped_mode == "waterfill":
            assign = capped_proportional_assignment(rates, n_rem, cap)
        else:  # paper-faithful: plain min(cap, share), carry the remainder
            share = largest_remainder_round(rates, n_rem)
            assign = np.minimum(share, cap).astype(np.int64)
        carried = n_rem - int(assign.sum())    # Algorithm 3 carry-over
        if assign.sum() == 0:   # degenerate rounding for tiny n_rem
            break
        # communication overhead, eq. (1): only units beyond the leftover
        if iters > 0:
            n_comm += float(np.maximum(assign - n_left_prev, 0).sum())
        lam_t = (lam if sched is None
                 else sched[min(iters, sched.shape[0] - 1)])
        t_star, done = _iteration_outcome(assign, lam_t, rng)
        iters += 1
        t_iter.append(t_star)
        t_comp += t_star
        n_done += done
        n_left_prev = assign - done
        n_rem = carried + int(n_left_prev.sum())
        # online estimate, eq. (23)
        est_done += done
        est_time += t_star
        if est_time > 0:
            lam_hat = np.where(est_done > 0, est_done / est_time, 1.0)

    if n_rem > 0:
        rates = lam if cfg.known_heterogeneity else lam_hat
        assign = proportional_assignment(rates, n_rem)
        if iters > 0:
            n_comm += float(np.maximum(assign - n_left_prev, 0).sum())
        lam_t = (lam if sched is None
                 else sched[min(iters, sched.shape[0] - 1)])
        t_comp += _final_phase(assign, lam_t, rng)
        n_done += assign
        iters += 1
        t_iter.append(t_iter[-1] if t_iter else t_comp)

    stats = RunStats(t_comp=t_comp, iterations=iters, n_comm=n_comm,
                     n_done=n_done, t_iter=np.asarray(t_iter))
    stats.check_work_conserved(N)
    return stats


# ---------------------------------------------------------------------------
# trial-vectorized work-exchange Monte-Carlo engine
# ---------------------------------------------------------------------------

def work_exchange_mc_batched(het: HetSpec, N: int, cfg: ExchangeConfig,
                             trials: int, rng: np.random.Generator,
                             capped_mode: Literal["carry", "waterfill"]
                             = "carry", keep_trials: bool = False,
                             scheme_name: str = "work_exchange",
                             backend: Optional[str] = None,
                             rate_schedule: Optional[np.ndarray] = None
                             ) -> MCReport:
    """All ``trials`` work-exchange runs at once through a sampler backend.

    The heavy lifting lives in ``repro.core.samplers``: the ``numpy``
    backend is the exact batched Gamma / argmin / Binomial engine (with a
    single trial it consumes randomness in exactly the order of
    ``simulate_work_exchange_scalar``, which the tests exploit for
    seed-for-seed validation); the ``jax`` backend fuses the same pipeline
    into one jitted dispatch.  ``rate_schedule`` (optional ``(R, K)``) is
    the per-exchange-round service-rate schedule of the drifting
    scenarios, threaded through every backend.
    """
    name = resolve_backend(backend)
    kwargs = {}
    if rate_schedule is not None:   # only drift-aware backends see the kwarg
        kwargs["rate_schedule"] = np.asarray(rate_schedule,
                                             dtype=np.float64)[None, :, :]
    ts, its, cs = get_backend(name).work_exchange_grid(
        het.lambdas[None, :], N, cfg, int(trials), rng, capped_mode,
        **kwargs)
    return _report(scheme_name, ts, its, cs, keep_trials,
                   extra={"backend": name})


def _grid_reports(scheme_name: str, specs: Sequence[HetSpec], trials: int,
                  arrays, keep_trials: bool, backend_name: str,
                  extra: Optional[Dict[str, float]] = None
                  ) -> List[MCReport]:
    """Slice flat grid-major engine output back into per-spec reports."""
    ts, its, cs = (np.asarray(a).reshape(len(specs), trials) for a in arrays)
    base = {"backend": backend_name, **(extra or {})}
    return [_report(scheme_name, ts[g], its[g], cs[g], keep_trials,
                    extra=dict(base))
            for g in range(len(specs))]


# ---------------------------------------------------------------------------
# paper schemes
# ---------------------------------------------------------------------------

@register_scheme("oracle", aliases=("work_conservation",))
class OracleScheme(Scheme):
    """Theorem 1 lower bound: merged process, T ~ Gamma(N, lambda_sum)."""

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        return proportional_assignment(het.lambdas, N)

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        t = float(rng.gamma(shape=N, scale=1.0 / het.lambda_sum))
        return RunStats(t_comp=t, iterations=1, n_comm=0.0,
                        n_done=self.initial_sizes(het, N))

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None) -> MCReport:
        validate_backend(backend)
        ts = rng.gamma(shape=N, scale=1.0 / het.lambda_sum, size=trials)
        return _report(self.name, ts, np.ones(trials), np.zeros(trials),
                       keep_trials, extra={"exact_mean": N / het.lambda_sum})


class _StaticScheme(Scheme):
    """Assign once (``initial_sizes``) and wait for the max -- no exchange."""

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        assign = self.initial_sizes(het, N)
        t = _final_phase(assign, het.lambdas, rng)
        return RunStats(t_comp=t, iterations=1, n_comm=0.0, n_done=assign)

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None) -> MCReport:
        validate_backend(backend)
        assign = self.initial_sizes(het, N)
        busy = assign > 0
        t = rng.gamma(shape=assign[busy], scale=1.0 / het.lambdas[busy],
                      size=(trials, int(busy.sum())))
        return _report(self.name, t.max(axis=1), np.ones(trials),
                       np.zeros(trials), keep_trials)

    def mc_grid(self, het_specs: Sequence[HetSpec], N: int, trials: int,
                rng: np.random.Generator, keep_trials: bool = False,
                backend: Optional[str] = None) -> List[MCReport]:
        """One draw for the whole grid: (G * trials, K) Gamma matrix, max
        over busy workers per row.  Same distribution as looped ``mc``."""
        validate_backend(backend)
        specs = list(het_specs)
        if not specs or len({h.K for h in specs}) != 1:
            return super().mc_grid(specs, N, trials, rng,
                                   keep_trials=keep_trials, backend=backend)
        T = int(trials)
        shape = np.repeat(np.stack([self.initial_sizes(h, N)
                                    for h in specs]), T, axis=0)
        scale = np.repeat(np.stack([1.0 / h.lambdas for h in specs]),
                          T, axis=0)
        t = np.zeros(shape.shape)
        busy = shape > 0
        t[busy] = rng.gamma(shape=shape[busy], scale=scale[busy])
        ts = t.max(axis=1).reshape(len(specs), T)
        return [_report(self.name, ts[g], np.ones(T), np.zeros(T),
                        keep_trials, extra={"backend": "numpy"})
                for g in range(len(specs))]

    def _scheduler_rates(self, rates: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def make_scheduler(self, unit_ids, rates=None, estimator=None,
                       threshold_frac=None) -> MasterScheduler:
        rates = self._scheduler_rates(np.asarray(rates, dtype=np.float64))
        return MasterScheduler(unit_ids, rates.size, rates=rates,
                               threshold_frac=1e9)


@register_scheme("fixed", aliases=("het_static", "fixed_proportional"))
class FixedScheme(_StaticScheme):
    """Section 5.1: heterogeneity-aware fixed assignment; wait for the max."""

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        return proportional_assignment(het.lambdas, N)

    def _scheduler_rates(self, rates: np.ndarray) -> np.ndarray:
        return rates


@register_scheme("uniform", aliases=("equal_static",))
class UniformScheme(_StaticScheme):
    """Naive baseline: N/K each, wait for the max (heterogeneity-blind)."""

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        return uniform_assignment(het.K, N)

    def _scheduler_rates(self, rates: np.ndarray) -> np.ndarray:
        return np.ones(rates.size)


@register_scheme("mds", aliases=("mds_opt", "mds-opt"))
class MDSScheme(Scheme):
    """Section 3: (K, L) MDS-coded run; T = L-th order statistic of
    Erlang(ceil(N/L), lambda_k).  ``L=None`` optimizes L by Monte Carlo
    (eq. 6) inside ``mc``; ``opt_trials`` bounds that inner sweep.

    The L-sweep is batched: all candidate L values become extra grid rows
    of ONE ``gamma_rows`` call through the selected sampler backend
    (``mds_sweep_batched``), and ``mc_grid`` batches the whole
    ``specs x L x trials`` cube the same way -- no per-L Python loop on
    any backend.  On the numpy backend the batched draw consumes
    randomness in exactly the per-L loop's order, so the chosen L (and
    every sample) is bit-identical to the PR-2 sweep.
    """

    redundant = True    # K * ceil(N/L) coded units are shipped for N useful
    live_cover = True   # live: complete at size-cover (== L finishers
                        # whenever ceil(N/m) == L)

    def __init__(self, L: Optional[int] = None, opt_trials: int = 64):
        self.L = L
        self.opt_trials = int(opt_trials)

    def _resolve_L(self, het: HetSpec, N: int,
                   rng: np.random.Generator) -> int:
        if self.L is not None:
            if not 1 <= self.L <= het.K:
                raise ValueError(f"L must be in [1, {het.K}]; got {self.L}")
            return self.L
        # simulate() is the exact single-trial reference: sweep with the
        # exact numpy draws regardless of the global backend selection
        L, _ = mds_sweep_batched(het, N, self.opt_trials, rng,
                                 backend="numpy")[:2]
        return L

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        L = self.L if self.L is not None else het.K
        return np.full(het.K, int(np.ceil(N / L)), dtype=np.int64)

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        L = self._resolve_L(het, N, rng)
        m = int(np.ceil(N / L))
        t_k = rng.gamma(shape=m, scale=1.0 / het.lambdas)
        order = np.argsort(t_k, kind="stable")
        t = float(t_k[order[L - 1]])
        n_done = np.zeros(het.K, dtype=np.int64)
        n_done[order[:L]] = m      # the L earliest finishers are decoded
        return RunStats(t_comp=t, iterations=1,
                        n_comm=float(m * het.K - N), n_done=n_done)

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None) -> MCReport:
        name = resolve_backend(backend)
        if self.L is None:
            # the K-candidate sweep only picks L*: bound its per-candidate
            # budget at opt_trials, then spend the full trial budget on the
            # winner alone (identical to the old behaviour whenever
            # trials <= opt_trials)
            sweep_trials = min(trials, self.opt_trials)
            [(L, ts)] = _mds_select_L_grid([het], N, sweep_trials, rng,
                                           name)
            if ts is None or sweep_trials < trials:
                ts = mds_time_samples(het, N, L, trials, rng, backend=name)
        else:
            L = self._resolve_L(het, N, rng)
            ts = mds_time_samples(het, N, L, trials, rng, backend=name)
        m = int(np.ceil(N / L))
        return _report(self.name, ts, np.ones(trials),
                       np.full(trials, float(m * het.K - N)), keep_trials,
                       extra={"L": L, "backend": name})

    def mc_grid(self, het_specs: Sequence[HetSpec], N: int, trials: int,
                rng: np.random.Generator, keep_trials: bool = False,
                backend: Optional[str] = None) -> List[MCReport]:
        """The whole ``specs x candidate-L x trials`` cube in one
        ``gamma_rows`` dispatch (plus one winner top-up dispatch),
        instead of a per-spec per-L loop.

        Requires every spec to share K; mixed-K grids fall back to the
        per-spec loop.
        """
        specs = list(het_specs)
        if not specs or len({h.K for h in specs}) != 1:
            return super().mc_grid(specs, N, trials, rng,
                                   keep_trials=keep_trials, backend=backend)
        name = resolve_backend(backend)
        K = specs[0].K
        T = int(trials)
        draw = get_gamma_rows(name)
        if self.L is not None:
            if not 1 <= self.L <= K:
                raise ValueError(f"L must be in [1, {K}]; got {self.L}")
            selection = [(self.L, None)] * len(specs)
        else:
            selection = _mds_select_L_grid(specs, N,
                                           min(T, self.opt_trials), rng,
                                           name)
        winners = [L for L, _ in selection]
        sweep_ts = [ts for _, ts in selection]
        if any(ts is None for ts in sweep_ts) or min(T, self.opt_trials) < T:
            sweep_ts = _mds_order_stat_rows(specs, N, winners, T, draw, rng)
        return [self._grid_report(specs[g], N, winners[g], sweep_ts[g], T,
                                  keep_trials, name)
                for g in range(len(specs))]

    def _grid_report(self, het: HetSpec, N: int, L: int, ts: np.ndarray,
                     trials: int, keep_trials: bool, name: str) -> MCReport:
        m = int(np.ceil(N / L))
        return _report(self.name, ts, np.ones(trials),
                       np.full(trials, float(m * het.K - N)), keep_trials,
                       extra={"L": L, "backend": name})


def _mds_select_L_grid(specs: Sequence[HetSpec], N: int, sweep_trials: int,
                       rng: np.random.Generator, name: str
                       ) -> List[Tuple[int, Optional[np.ndarray]]]:
    """Pick L* per spec: all candidate L of all specs as grid rows of ONE
    ``gamma_rows`` dispatch.  Returns ``(L*, sweep samples at L*)`` per
    spec; the samples slot is ``None`` for coupled sweeps (cross-candidate
    correlated -- callers must top up from an independent draw).

    Exact backends run the *independent* cube: spec-major then L-major
    rows, bit-identical in stream order to looping ``mds_sweep`` per
    spec.  Transform backends (``coupled_mds_sweep``) run the
    *common-random-numbers* cube: per spec, ONE shared trial axis with
    candidate Erlangs built as cumulative Gamma increments
    ``T(m_L) = T(m_{L+1}) + Gamma(m_L - m_{L+1})`` (Gamma additivity), so
    the mean differences the argmin compares are positively correlated
    and half the trials (``ceil(sweep_trials / 2)``, floor 16) match the
    independent sweep's selection accuracy at half the draws.
    """
    K = specs[0].K
    G = len(specs)
    draw = get_gamma_rows(name)
    cand = list(range(1, K + 1))
    m = np.array([int(np.ceil(N / L)) for L in cand], dtype=np.float64)
    inv_lam = np.stack([1.0 / h.lambdas for h in specs])

    if get_backend(name).coupled_mds_sweep:
        ct = max(16, (int(sweep_trials) + 1) // 2)
        m_asc = m[::-1]                      # ascending m: L = K, K-1, ... 1
        diffs = np.empty(K)
        diffs[0] = m_asc[0]
        diffs[1:] = np.diff(m_asc)
        # rows spec-major then increment-major, drawn at unit rate (one
        # compact shape column, a (1, K) scale row -- no G*K*ct-row scale
        # matrix); the per-worker 1/lambda lands in the same fused pass
        # that zeroes tied increments (ceil(N/L) ties draw at shape 1)
        shape_col = np.tile(np.repeat(np.maximum(diffs, 1.0), ct),
                            G)[:, None]
        t = draw(shape_col, np.ones((1, K), dtype=np.float32), rng)
        t = t.reshape(G, K, ct, K)
        t *= (diffs > 0)[None, :, None, None] * inv_lam[:, None, None, :]
        cube = np.cumsum(t, axis=1)
        cube.sort(axis=3)                    # cube[g, i] = T at m_asc[i]
        out: List[Tuple[int, Optional[np.ndarray]]] = []
        for g in range(G):
            best = (1, np.inf)
            for L in cand:
                mean_t = float(cube[g, K - L, :, L - 1].mean())
                if mean_t < best[1]:
                    best = (L, mean_t)
            out.append((best[0], None))
        return out

    sweep_trials = int(sweep_trials)
    shape_col = np.tile(np.repeat(m, sweep_trials), G)[:, None]
    scale_rows = np.repeat(inv_lam, K * sweep_trials, axis=0)
    t = draw(shape_col, scale_rows, rng)
    t.sort(axis=1)
    t = t.reshape(G, K, sweep_trials, K)
    out = []
    for g in range(G):
        best: Tuple[int, float, Optional[np.ndarray]] = (1, np.inf, None)
        for i, L in enumerate(cand):
            ts = t[g, i, :, L - 1]
            mean_t = float(ts.mean())
            if mean_t < best[1]:
                best = (L, mean_t, ts)
        out.append((best[0], best[2]))
    return out


def _mds_order_stat_rows(specs: Sequence[HetSpec], N: int,
                         Ls: Sequence[int], trials: int, draw,
                         rng: np.random.Generator) -> List[np.ndarray]:
    """Per-spec T^MDS(L_g) samples, all specs in one gamma_rows call."""
    K = specs[0].K
    shape_col = np.repeat(
        np.array([float(np.ceil(N / L)) for L in Ls]), trials)[:, None]
    scale_rows = np.repeat(np.stack([1.0 / h.lambdas for h in specs]),
                           trials, axis=0)
    t = draw(shape_col, scale_rows, rng)
    t.sort(axis=1)
    t = t.reshape(len(specs), trials, K)
    return [t[g, :, Ls[g] - 1] for g in range(len(specs))]


def mds_time_samples(het: HetSpec, N: int, L: int, trials: int,
                     rng: np.random.Generator,
                     backend: Optional[str] = None) -> np.ndarray:
    """Per-trial T^MDS(L): L-th order statistic of the worker Erlangs,
    drawn through the selected sampler backend (numpy = exact, and
    bit-identical to the pre-backend ``rng.gamma(size=(trials, K))``)."""
    name = resolve_backend(backend)
    m = float(np.ceil(N / L))
    shape_rows = np.broadcast_to(np.float64(m), (trials, het.K))
    t = get_gamma_rows(name)(shape_rows, 1.0 / het.lambdas, rng)
    t.sort(axis=1)
    return t[:, L - 1]


def mds_sweep(het: HetSpec, N: int, trials: int, rng: np.random.Generator
              ) -> Tuple[int, float, np.ndarray]:
    """Eq. (6) as the PR-2 per-L reference loop (numpy draws).

    Kept verbatim as the validation baseline ``mds_sweep_batched`` is
    pinned against (and as the loop the ``mds_grid`` benchmark times).
    """
    best: Tuple[int, float, Optional[np.ndarray]] = (1, np.inf, None)
    for L in range(1, het.K + 1):
        m = int(np.ceil(N / L))
        t = rng.gamma(shape=m, scale=1.0 / het.lambdas,
                      size=(trials, het.K))
        t.sort(axis=1)
        ts = t[:, L - 1]
        mean_t = float(ts.mean())
        if mean_t < best[1]:
            best = (L, mean_t, ts)
    return best  # type: ignore[return-value]


def mds_sweep_batched(het: HetSpec, N: int, trials: int,
                      rng: np.random.Generator,
                      backend: Optional[str] = None
                      ) -> Tuple[int, float, np.ndarray]:
    """Eq. (6) with every candidate L as extra grid rows of ONE batched
    ``gamma_rows`` draw: rows are L-major ``(K * trials, K)``, so on the
    numpy backend the random stream -- and therefore the chosen L and
    every sample -- is bit-identical to the ``mds_sweep`` loop.
    Returns ``(L*, E[T(L*)], samples at L*)``.
    """
    name = resolve_backend(backend)
    K = het.K
    m = np.array([int(np.ceil(N / L)) for L in range(1, K + 1)],
                 dtype=np.float64)
    shape_rows = np.broadcast_to(np.repeat(m, trials)[:, None],
                                 (K * trials, K))
    t = get_gamma_rows(name)(shape_rows, 1.0 / het.lambdas, rng)
    t.sort(axis=1)
    best: Tuple[int, float, Optional[np.ndarray]] = (1, np.inf, None)
    for L in range(1, K + 1):
        ts = t[(L - 1) * trials:L * trials, L - 1]
        mean_t = float(ts.mean())
        if mean_t < best[1]:
            best = (L, mean_t, ts)
    return best  # type: ignore[return-value]


class _WorkExchangeBase(Scheme):
    """Shared machinery of the known/unknown work-exchange variants."""

    known: bool = True
    plan_wait_all = False
    supports_rate_schedule = True   # drifting scenarios thread through

    def __init__(self, threshold_frac: float = 0.01,
                 storage_cap_frac: Optional[float] = 1.0,
                 capped_mode: Literal["carry", "waterfill"] = "carry",
                 max_iterations: int = 10_000,
                 engine: Literal["vectorized", "loop"] = "vectorized"):
        self.threshold_frac = float(threshold_frac)
        self.storage_cap_frac = storage_cap_frac
        self.capped_mode = capped_mode
        self.max_iterations = int(max_iterations)
        if engine not in ("vectorized", "loop"):
            raise ValueError(f"unknown engine {engine!r}")
        self.engine = engine

    def config(self) -> ExchangeConfig:
        return ExchangeConfig(known_heterogeneity=self.known,
                              threshold_frac=self.threshold_frac,
                              storage_cap_frac=self.storage_cap_frac,
                              max_iterations=self.max_iterations)

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        if self.known:
            return proportional_assignment(het.lambdas, N)
        # unknown rates start from the uniform prior (lambda_hat = 1)
        sizes = uniform_assignment(het.K, N)
        if self.storage_cap_frac is not None:
            cap = int(np.ceil(self.storage_cap_frac * N / het.K))
            sizes = np.minimum(sizes, cap)
        return sizes

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator,
                 rate_schedule: Optional[np.ndarray] = None) -> RunStats:
        return simulate_work_exchange_scalar(het, N, self.config(), rng,
                                             self.capped_mode,
                                             rate_schedule=rate_schedule)

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None,
           rate_schedule: Optional[np.ndarray] = None) -> MCReport:
        if self.engine == "loop":    # the per-trial validation reference
            # backend is unused by the scalar loop but still validated,
            # so a typo'd name fails fast here like everywhere else
            if rate_schedule is None:
                return super().mc(het, N, trials, rng, keep_trials,
                                  backend=backend)
            validate_backend(backend)
            ts, its, cs = (np.empty(trials) for _ in range(3))
            for i in range(trials):
                s = self.simulate(het, N, rng, rate_schedule=rate_schedule)
                ts[i], its[i], cs[i] = s.t_comp, s.iterations, s.n_comm
            return _report(self.name, ts, its, cs, keep_trials)
        return work_exchange_mc_batched(het, N, self.config(), trials, rng,
                                        self.capped_mode, keep_trials,
                                        scheme_name=self.name,
                                        backend=backend,
                                        rate_schedule=rate_schedule)

    def mc_grid(self, het_specs: Sequence[HetSpec], N: int, trials: int,
                rng: np.random.Generator, keep_trials: bool = False,
                backend: Optional[str] = None,
                rate_schedule: Optional[np.ndarray] = None
                ) -> List[MCReport]:
        """One engine dispatch for the whole ``(het_specs) x trials`` batch.

        Requires every spec to share K (one rate matrix row per spec);
        mixed-K grids and the ``engine="loop"`` reference fall back to the
        per-spec loop.  ``rate_schedule`` (optional ``(G, R, K)``, one
        per-round schedule per spec) is the drifting-scenario contract:
        service draws follow the schedule, assignments stay nominal /
        estimated.
        """
        specs = list(het_specs)
        if (self.engine == "loop" or not specs
                or len({h.K for h in specs}) != 1):
            if rate_schedule is None:
                return super().mc_grid(specs, N, trials, rng,
                                       keep_trials=keep_trials,
                                       backend=backend)
            sched = np.asarray(rate_schedule, dtype=np.float64)
            return [self.mc(het, N, trials, rng, keep_trials=keep_trials,
                            backend=backend, rate_schedule=sched[g])
                    for g, het in enumerate(specs)]
        name = resolve_backend(backend)
        lam = np.stack([h.lambdas for h in specs])
        kwargs = {}
        if rate_schedule is not None:
            kwargs["rate_schedule"] = np.asarray(rate_schedule,
                                                 dtype=np.float64)
        arrays = get_backend(name).work_exchange_grid(
            lam, N, self.config(), int(trials), rng, self.capped_mode,
            **kwargs)
        return _grid_reports(self.name, specs, int(trials), arrays,
                             keep_trials, name)

    def make_scheduler(self, unit_ids, rates=None, estimator=None,
                       threshold_frac=None) -> MasterScheduler:
        thr = self.threshold_frac if threshold_frac is None else threshold_frac
        if self.known:
            rates = np.asarray(rates, dtype=np.float64)
            return MasterScheduler(unit_ids, rates.size, rates=rates,
                                   threshold_frac=thr,
                                   storage_cap_frac=self.storage_cap_frac)
        K = np.asarray(rates).size
        return MasterScheduler(unit_ids, K, rates=None, estimator=estimator,
                               threshold_frac=thr,
                               storage_cap_frac=self.storage_cap_frac)


@register_scheme("work_exchange", aliases=("work_exchange_known", "we_known"))
class WorkExchangeScheme(_WorkExchangeBase):
    """Algorithm 1: iterative proportional reassignment, rates known."""

    known = True


@register_scheme("work_exchange_unknown",
                 aliases=("we_unknown", "work_exchange_online"))
class WorkExchangeUnknownScheme(_WorkExchangeBase):
    """Algorithm 3: rates estimated online (eq. 23), storage-capped."""

    known = False


# ---------------------------------------------------------------------------
# fused whole-panel dispatch
# ---------------------------------------------------------------------------

def _panel_pair(schemes: Dict[str, Scheme]) -> Optional[Tuple[str, str]]:
    """The fusable known/unknown work-exchange pair of a panel, or None.

    Fusable means: exactly the canonical pairing -- one known and one
    unknown ``_WorkExchangeBase`` (first of each wins), both on the
    vectorized engine with the paper's ``carry`` capped mode, sharing
    ``threshold_frac`` and ``max_iterations`` (the panel engine runs one
    round loop for both trajectories, so per-scheme values cannot
    differ).  Anything else -> None, and the caller falls back to
    per-scheme dispatch for every entry.
    """
    known_key = unknown_key = None
    for key, sch in schemes.items():
        if (not isinstance(sch, _WorkExchangeBase)
                or sch.engine != "vectorized"
                or sch.capped_mode != "carry"):
            continue
        if sch.known and known_key is None:
            known_key = key
        elif not sch.known and unknown_key is None:
            unknown_key = key
    if known_key is None or unknown_key is None:
        return None
    k, u = schemes[known_key], schemes[unknown_key]
    if (k.threshold_frac != u.threshold_frac
            or k.max_iterations != u.max_iterations):
        return None
    return known_key, unknown_key


def mc_grid_panel(schemes: Dict[str, Scheme], het_specs: Sequence[HetSpec],
                  N: int, trials: int, rng, keep_trials: bool = False,
                  backend: Optional[str] = None,
                  rate_schedule: Optional[np.ndarray] = None
                  ) -> Dict[str, List[MCReport]]:
    """A whole figure panel -- ordered ``report_key -> Scheme`` -- over the
    scenario grid, with the work-exchange known/unknown pair fused into
    ONE engine dispatch when the backend has a ``work_exchange_panel``
    executor (jax: the coupled common-random-numbers engine; pallas: one
    stacked kernel launch).  Everything else runs its own ``mc_grid``.

    ``rng`` is either one Generator (each scheme gets a child stream
    derived in input order) or a ``key -> Generator`` mapping (the
    executor's per-task seeds).  With a mapping, non-fused schemes draw
    from exactly the stream per-scheme dispatch would hand them, so their
    reports are bit-identical to ``panel="per_scheme"``; only the fused
    pair's numbers move (one shared CRN stream -- statistically
    equivalent, not bit-equal, to two independent dispatches).  Fused
    reports carry ``extra["fused_panel"] = 1``.
    """
    specs = list(het_specs)
    name = resolve_backend(backend)
    panel_fn = get_backend(name).work_exchange_panel
    if isinstance(rng, dict):
        child = dict(rng)
        missing = [k for k in schemes if k not in child]
        if missing:
            raise ValueError(f"rng mapping is missing streams for {missing}")
    else:
        child = {key: np.random.default_rng(rng.integers(0, 2**63))
                 for key in schemes}
    pair = (_panel_pair(schemes)
            if panel_fn is not None and specs
            and len({h.K for h in specs}) == 1 else None)
    fused: Dict[str, List[MCReport]] = {}
    if pair is not None:
        kk, uk = pair
        lam = np.stack([h.lambdas for h in specs])
        kwargs = {}
        if rate_schedule is not None:
            kwargs["rate_schedule"] = np.asarray(rate_schedule,
                                                 dtype=np.float64)
        res = panel_fn(lam, N, schemes[kk].config(), schemes[uk].config(),
                       int(trials), child[kk], **kwargs)
        for key, slot in ((kk, "known"), (uk, "unknown")):
            fused[key] = _grid_reports(schemes[key].name, specs,
                                       int(trials), res[slot], keep_trials,
                                       name, extra={"fused_panel": 1})
    out: Dict[str, List[MCReport]] = {}
    for key, sch in schemes.items():
        if key in fused:
            out[key] = fused[key]
            continue
        kwargs = {}
        if rate_schedule is not None and sch.supports_rate_schedule:
            kwargs["rate_schedule"] = rate_schedule
        out[key] = sch.mc_grid(specs, N, int(trials), child[key],
                               keep_trials=keep_trials, backend=name,
                               **kwargs)
    return out


# ---------------------------------------------------------------------------
# beyond-paper scenario schemes
# ---------------------------------------------------------------------------

@register_scheme("het_mds", aliases=("hcmm",))
class HetMDSScheme(Scheme):
    """Heterogeneous coded loads (Reisizadeh et al. HCMM / Kim et al.).

    Instead of the paper's symmetric (K, L) code, each worker k gets a coded
    load l_k proportional to its rate with aggregate redundancy r >= 1
    (sum l_k = r N); the run completes at the earliest time the finished
    workers' loads cover N.  At r = 1 with exact rates this is the
    heterogeneity-aware fixed assignment; larger r trades completion time
    (every load scales by ~r under light-tailed service) for tolerance of
    stragglers and rate mismatch -- one draw per trial, no reassignment.
    """

    redundant = True
    live_cover = True   # cover >= N is this scheme's own completion rule

    def __init__(self, redundancy: float = 1.25):
        if redundancy < 1.0:
            raise ValueError("redundancy must be >= 1")
        self.redundancy = float(redundancy)

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        total = int(np.ceil(self.redundancy * N))
        return largest_remainder_round(het.lambdas, total)

    @staticmethod
    def _cover_times_rows(load_rows: np.ndarray, scale_rows: np.ndarray,
                          N: int, rng: np.random.Generator) -> np.ndarray:
        """Per-row cover time: earliest finish time at which the finished
        workers' coded loads jointly cover N (rows are independent runs)."""
        t = np.full(load_rows.shape, np.inf)
        busy = load_rows > 0
        t[busy] = rng.gamma(shape=load_rows[busy], scale=scale_rows[busy])
        order = np.argsort(t, axis=1, kind="stable")
        covered = np.cumsum(np.take_along_axis(load_rows, order, axis=1),
                            axis=1) >= N
        first = np.argmax(covered, axis=1)               # first covering rank
        t_sorted = np.take_along_axis(t, order, axis=1)
        return t_sorted[np.arange(first.size), first]

    def _cover_times(self, het: HetSpec, N: int, trials: int,
                     rng: np.random.Generator) -> np.ndarray:
        loads = self.initial_sizes(het, N)
        return self._cover_times_rows(
            np.broadcast_to(loads, (trials, het.K)),
            np.broadcast_to(1.0 / het.lambdas, (trials, het.K)), N, rng)

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        loads = self.initial_sizes(het, N)
        t = float(self._cover_times(het, N, 1, rng)[0])
        return RunStats(t_comp=t, iterations=1,
                        n_comm=float(loads.sum() - N), n_done=loads)

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None) -> MCReport:
        validate_backend(backend)
        loads = self.initial_sizes(het, N)
        ts = self._cover_times(het, N, trials, rng)
        return _report(self.name, ts, np.ones(trials),
                       np.full(trials, float(loads.sum() - N)), keep_trials,
                       extra={"redundancy": self.redundancy})

    def mc_grid(self, het_specs: Sequence[HetSpec], N: int, trials: int,
                rng: np.random.Generator, keep_trials: bool = False,
                backend: Optional[str] = None) -> List[MCReport]:
        """Cover times for the whole grid in one (G * trials, K) batch."""
        validate_backend(backend)
        specs = list(het_specs)
        if not specs or len({h.K for h in specs}) != 1:
            return super().mc_grid(specs, N, trials, rng,
                                   keep_trials=keep_trials, backend=backend)
        T = int(trials)
        loads = np.stack([self.initial_sizes(h, N) for h in specs])
        ts = self._cover_times_rows(
            np.repeat(loads, T, axis=0),
            np.repeat(np.stack([1.0 / h.lambdas for h in specs]), T, axis=0),
            N, rng).reshape(len(specs), T)
        return [_report(self.name, ts[g], np.ones(T),
                        np.full(T, float(loads[g].sum() - N)), keep_trials,
                        extra={"redundancy": self.redundancy,
                               "backend": "numpy"})
                for g in range(len(specs))]


@register_scheme("trace_replay")
class TraceReplayScheme(Scheme):
    """Replay measured per-epoch service-rate traces through the id-aware
    master protocol (``MasterScheduler`` + ``VirtualWorkerPool``'s
    measured-trace path).

    Trace sources, in precedence order:

    ``traces``
        A literal (K, E) array of observed rates (wrapping after E
        epochs).
    ``corpus``
        A named measured-trace corpus under ``results/traces/``
        (``repro.scenarios.traces``): the scheme replays the corpus
        window selected by ``worker_offset`` / ``epoch_start`` /
        ``epochs`` -- the same windowing the ``trace_corpus`` scenario
        family uses, so ``scheme_spec("trace_replay", corpus=...)``
        inside an experiment replays exactly the grid point's trace.
    *(neither)*
        A synthetic drift profile perturbs the HetSpec rates by
        +-``drift`` over ``period`` epochs, phase-shifted per worker --
        the pre-corpus stand-in, kept for back-compat.

    The scheduler sees only the *nominal* rates; realized epochs run at
    the trace rates.
    """

    plan_wait_all = False

    def __init__(self, traces: Optional[np.ndarray] = None,
                 drift: float = 0.3, period: int = 8,
                 threshold_frac: float = 0.05,
                 corpus: Optional[str] = None, worker_offset: int = 0,
                 epoch_start: int = 0, epochs: Optional[int] = None):
        self.traces = None if traces is None else np.asarray(traces, float)
        self.drift = float(drift)
        self.period = int(period)
        self.threshold_frac = float(threshold_frac)
        self.corpus = corpus
        self.worker_offset = int(worker_offset)
        self.epoch_start = int(epoch_start)
        self.epochs = None if epochs is None else int(epochs)

    def _traces_for(self, het: HetSpec) -> np.ndarray:
        if self.traces is not None:
            if self.traces.shape[0] != het.K:
                raise ValueError(f"traces have {self.traces.shape[0]} "
                                 f"workers; het has {het.K}")
            return self.traces
        if self.corpus is not None:
            from repro.scenarios.traces import load_corpus
            return load_corpus(self.corpus).window(
                het.K, self.worker_offset, self.epoch_start, self.epochs)
        e = np.arange(self.period)
        k = np.arange(het.K)[:, None]
        profile = 1.0 + self.drift * np.sin(
            2.0 * np.pi * (e[None, :] / self.period + k / het.K))
        return np.maximum(het.lambdas[:, None] * profile, 1e-9)

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        return proportional_assignment(het.lambdas, N)

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        from .runtime import VirtualWorkerPool
        sched = MasterScheduler(range(N), het.K, rates=het.lambdas,
                                threshold_frac=self.threshold_frac)
        pool = VirtualWorkerPool(het.lambdas, rng=rng,
                                 traces=self._traces_for(het))
        n_done = np.zeros(het.K, dtype=np.int64)
        guard = 0
        while not sched.finished and guard < 100_000:
            a = sched.next_assignment()
            if a is None:
                break
            elapsed, done = pool.run_epoch(a)
            sched.report(done, elapsed)
            n_done += done
            guard += 1
        return RunStats(t_comp=sched.t_comp, iterations=sched.iterations,
                        n_comm=float(sched.n_comm), n_done=n_done)

    def make_scheduler(self, unit_ids, rates=None, estimator=None,
                       threshold_frac=None) -> MasterScheduler:
        thr = self.threshold_frac if threshold_frac is None else threshold_frac
        rates = np.asarray(rates, dtype=np.float64)
        return MasterScheduler(unit_ids, rates.size, rates=rates,
                               threshold_frac=thr)


@register_scheme("gradient_coded")
class GradientCodedScheme(Scheme):
    """Fractional-repetition coding translated to the unit-count model:
    each unit is replicated s+1 times; the run completes at the earliest
    time the finished workers jointly cover all N units (no reassignment,
    no coordination -- redundancy instead of exchange)."""

    redundant = True
    # make_scheduler returns a one-shot CoverScheduler (whole-queue
    # finish-time feedback), not a MasterScheduler: training executors
    # branch on it; the live round-trip loop cannot drive it
    cover_scheduler = True

    def __init__(self, s: int = 1):
        self.s = int(s)

    def _coding(self, het: HetSpec):
        from .coded import GradientCoding
        K = het.K - het.K % (self.s + 1)    # FR needs (s+1) | K; drop extras
        if K < self.s + 1:
            raise ValueError(f"need >= {self.s + 1} workers for s={self.s}")
        return GradientCoding(K=K, s=self.s), K

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        gc, K = self._coding(het)
        sizes = np.zeros(het.K, dtype=np.int64)
        sizes[:K] = [len(o) for o in gc.assignment(N)]
        return sizes

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        gc, K = self._coding(het)
        owners = gc.assignment(N)
        sizes = np.array([len(o) for o in owners], dtype=np.int64)
        t_k = rng.gamma(shape=np.maximum(sizes, 1),
                        scale=1.0 / het.lambdas[:K])
        order = np.argsort(t_k, kind="stable")
        covered: set = set()
        n_done = np.zeros(het.K, dtype=np.int64)
        t_done = float(t_k[order[-1]])
        for w in order:
            fresh = set(owners[w]) - covered
            covered |= fresh
            n_done[w] = len(fresh)          # credit first replica to finish
            if len(covered) == N:
                t_done = float(t_k[w])
                break
        return RunStats(t_comp=t_done, iterations=1,
                        n_comm=float(sizes.sum() - N), n_done=n_done)

    def make_scheduler(self, unit_ids, rates=None, estimator=None,
                       threshold_frac=None) -> "CoverScheduler":
        """The registry scheduler path (replaces the bespoke training
        branch): a ``CoverScheduler`` over ``len(rates)`` workers."""
        from .exchange import CoverScheduler
        K = np.asarray(rates, dtype=np.float64).size
        return CoverScheduler(unit_ids, K, s=self.s)


@register_scheme("hedged", aliases=("replicate_slowest", "hedged_requests"))
class HedgedScheme(Scheme):
    """Replication-on-slowest (hedged requests, ROADMAP candidate).

    The fastest worker is withheld as a hot spare; the other K-1 workers
    take the heterogeneity-aware proportional shares of all N units.  The
    spare mirrors the queue of the predicted straggler -- the lowest-rate
    loaded worker, which has both the largest expected completion time
    and (Var[T_k] = n_k / lambda_k^2) the heaviest tail -- and whichever
    replica finishes first counts.  Classic tail-latency hedging: pay one
    duplicated shard instead of coordination rounds; ``n_comm`` is the
    duplicated units.  With K = 1 there is nobody to hedge with and the
    scheme degenerates to the fixed assignment.
    """

    redundant = True    # the straggler's shard ships twice
    live_cover = True   # cover >= N == the replica race (all others plus
                        # whichever of straggler/spare finishes first)

    def _layout(self, het: HetSpec, N: int):
        """Per-worker primary loads + (spare, straggler) worker ids."""
        loads = np.zeros(het.K, dtype=np.int64)
        if het.K == 1:
            loads[0] = N
            return loads, None, None
        spare = int(np.argmax(het.lambdas))
        others = np.delete(np.arange(het.K), spare)
        loads[others] = proportional_assignment(het.lambdas[others], N)
        loaded = others[loads[others] > 0]
        if loaded.size == 0:
            return loads, None, None
        strag = int(loaded[np.argmin(het.lambdas[loaded])])
        return loads, spare, strag

    def initial_sizes(self, het: HetSpec, N: int) -> np.ndarray:
        loads, spare, strag = self._layout(het, N)
        sizes = loads.copy()
        if spare is not None:
            sizes[spare] = loads[strag]      # the duplicated shard
        return sizes

    def _finish_times(self, het: HetSpec, N: int, trials: int,
                      rng: np.random.Generator):
        """Per-trial ``(t_comp, n_comm, t_strag_raw, t_spare)`` plus the
        layout, all trials at once (draw order: primaries, then spare)."""
        loads, spare, strag = self._layout(het, N)
        busy = loads > 0
        t_k = np.full((trials, het.K), -np.inf)   # idle never sets the max
        t_k[:, busy] = rng.gamma(shape=loads[busy],
                                 scale=1.0 / het.lambdas[busy],
                                 size=(trials, int(busy.sum())))
        if spare is None:
            return (t_k.max(axis=1), np.zeros(trials), loads, spare, strag,
                    None, None)
        t_spare = rng.gamma(shape=loads[strag],
                            scale=1.0 / het.lambdas[spare], size=trials)
        t_eff = t_k.copy()
        t_eff[:, strag] = np.minimum(t_k[:, strag], t_spare)
        t_comp = t_eff.max(axis=1)          # spare's column is -inf
        n_comm = np.full(trials, float(loads[strag]))
        return t_comp, n_comm, loads, spare, strag, t_k[:, strag], t_spare

    def simulate(self, het: HetSpec, N: int,
                 rng: np.random.Generator) -> RunStats:
        t_comp, n_comm, loads, spare, strag, t_strag, t_spare = \
            self._finish_times(het, N, 1, rng)
        n_done = loads.copy()
        if spare is not None and float(t_spare[0]) < float(t_strag[0]):
            # the spare's replica finished first: credit it, not the
            # straggler (exactly one replica counts -- work conserved)
            n_done[spare] = loads[strag]
            n_done[strag] = 0
        return RunStats(t_comp=float(t_comp[0]), iterations=1,
                        n_comm=float(n_comm[0]), n_done=n_done)

    def mc(self, het: HetSpec, N: int, trials: int,
           rng: np.random.Generator, keep_trials: bool = False,
           backend: Optional[str] = None) -> MCReport:
        validate_backend(backend)
        t_comp, n_comm, _, spare, strag, _, _ = \
            self._finish_times(het, N, trials, rng)
        extra = {} if spare is None else {"spare": float(spare),
                                          "straggler": float(strag)}
        return _report(self.name, t_comp, np.ones(trials), n_comm,
                       keep_trials, extra=extra)


__all__ = [
    "MCReport", "Scheme", "SCHEME_REGISTRY", "register_scheme", "get_scheme",
    "list_schemes", "simulate_work_exchange_scalar",
    "work_exchange_mc_batched", "mc_grid_panel", "mds_sweep",
    "mds_sweep_batched", "mds_time_samples",
    "OracleScheme", "FixedScheme", "UniformScheme", "MDSScheme",
    "WorkExchangeScheme", "WorkExchangeUnknownScheme", "HetMDSScheme",
    "TraceReplayScheme", "GradientCodedScheme", "HedgedScheme",
]
