"""Exact order statistics of non-identically distributed Erlang variables.

Implements the Abdelkader (2004) recursion the paper uses in Section 3
(eqs. 4-5) to evaluate the mean completion time of the (K, L) MDS-coded
scheme:  E[T^MDS(L)] = mu_(L, m) at m = N/L, where mu_(l, m) is the mean
of the l-th order statistic of K independent Erlang(m, lambda_k) variables.

    mu_(l,m) = mu_(l-1,m) + sum_{j=1}^{l} (-1)^{j-1} C(K-l+j, j-1) P^m_{K-l+j}

    P^m_s    = sum over subsets S of size s of
               (1/lam_S) * sum_{0<=n_i<m} multinomial(sum n; n) prod (lam_i/lam_S)^{n_i}

The inner truncated-multinomial sum is evaluated through generating
polynomials: it equals  sum_t t! [x^t] prod_{i in S} E_m(p_i x)  with
E_m(y) = sum_{n<m} y^n/n!.  Exact in float64 for the small (K, m) regime;
paper-scale (m ~ 2e4) uses the Monte-Carlo simulator instead.
"""
from __future__ import annotations

import itertools
import math
from functools import lru_cache

import numpy as np

from .types import HetSpec


def _truncated_exp_poly(p: float, m: int) -> np.ndarray:
    """Coefficients of E_m(p x) = sum_{n=0}^{m-1} p^n x^n / n!  (length m)."""
    coeffs = np.empty(m, dtype=np.float64)
    c = 1.0
    for n in range(m):
        coeffs[n] = c
        c *= p / (n + 1)
    return coeffs


def _subset_term(lams: np.ndarray, m: int) -> float:
    """Inner sum of eq. (5) for one subset with rates ``lams``."""
    lam_s = float(lams.sum())
    p = lams / lam_s
    # polynomial product of truncated exponentials
    poly = np.array([1.0])
    for pi in p:
        poly = np.convolve(poly, _truncated_exp_poly(float(pi), m))
    # sum_t t! * coeff[t]
    total = 0.0
    fact = 1.0
    for t, c in enumerate(poly):
        if t > 0:
            fact *= t
        total += fact * float(c)
    return total / lam_s


def p_j_m(het: HetSpec, j: int, m: int) -> float:
    """P^m_j of eq. (5): sum over all subsets of size j."""
    lam = het.lambdas
    K = het.K
    return float(sum(_subset_term(lam[list(S)], m)
                     for S in itertools.combinations(range(K), j)))


def erlang_order_stat_means(het: HetSpec, m: int, L: int | None = None
                            ) -> np.ndarray:
    """mu_(l, m) for l = 1..L via the recursion (4). Returns array length L."""
    K = het.K
    L = K if L is None else L
    if not 1 <= L <= K:
        raise ValueError("L must be in [1, K]")
    # precompute P^m_s for s = 1..K (only sizes >= K-L+1 are needed)
    needed = sorted({K - ell + j for ell in range(1, L + 1)
                     for j in range(1, ell + 1)})
    P = {s: p_j_m(het, s, m) for s in needed}
    mus = np.zeros(L, dtype=np.float64)
    prev = 0.0
    for ell in range(1, L + 1):
        delta = 0.0
        for j in range(1, ell + 1):
            s = K - ell + j
            delta += (-1.0) ** (j - 1) * math.comb(s, j - 1) * P[s]
        prev = prev + delta
        mus[ell - 1] = prev
    return mus


def erlang_order_stat_mean(het: HetSpec, m: int, ell: int) -> float:
    """Mean of the ell-th order statistic of Erlang(m, lambda_k), k=1..K."""
    return float(erlang_order_stat_means(het, m, ell)[-1])


def erlang_order_stat_mean_mc(het: HetSpec, m: int, ell: int, trials: int,
                              rng: np.random.Generator) -> float:
    """Monte-Carlo cross-check for the recursion."""
    samples = rng.gamma(shape=m, scale=1.0 / het.lambdas,
                        size=(trials, het.K))
    ordered = np.sort(samples, axis=1)
    return float(ordered[:, ell - 1].mean())
