"""Exact, vectorized event-driven simulator of the paper's protocols.

No per-point loops: for an assignment of n_k units to worker k (service
rate lambda_k), the completion time is T_k ~ Gamma(n_k, lambda_k).  The
master stops everyone at T* = min_k T_k (first completion flag).  For a
non-finishing worker, conditioned on its n_k-th arrival being at T_k, the
earlier n_k - 1 arrival epochs are i.i.d. uniform order statistics on
(0, T_k)  (Poisson-process conditioning), hence

    N_done_k | T_k  ~  Binomial(n_k - 1, T*/T_k)        [exact]

This makes one work-exchange iteration O(K) per Monte-Carlo trial and the
whole simulation exact in distribution -- the same trick is used for all
schemes (fixed, MDS, oracle, work exchange known/unknown).

All routines are vectorized across ``trials`` with numpy; the paper's
N = 1e6, K = 50 configuration costs microseconds per trial.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np

from .assignment import (capped_proportional_assignment, largest_remainder_round,
                         proportional_assignment, uniform_assignment)
from .types import ExchangeConfig, HetSpec, RunStats


# ---------------------------------------------------------------------------
# single-trial primitives
# ---------------------------------------------------------------------------

def _iteration_outcome(assign: np.ndarray, lambdas: np.ndarray,
                       rng: np.random.Generator):
    """One work-exchange iteration: returns (t_star, done) exactly."""
    K = assign.size
    t_k = np.full(K, np.inf)
    busy = assign > 0
    t_k[busy] = rng.gamma(shape=assign[busy], scale=1.0 / lambdas[busy])
    finisher = int(np.argmin(t_k))
    t_star = float(t_k[finisher])
    done = np.zeros(K, dtype=np.int64)
    done[finisher] = assign[finisher]
    others = busy.copy()
    others[finisher] = False
    if others.any():
        n = assign[others] - 1
        p = np.clip(t_star / t_k[others], 0.0, 1.0)
        done[others] = rng.binomial(np.maximum(n, 0), p)
    return t_star, done


def _final_phase(assign: np.ndarray, lambdas: np.ndarray,
                 rng: np.random.Generator) -> float:
    """Below the cutting threshold: assign and wait for ALL workers (max)."""
    busy = assign > 0
    if not busy.any():
        return 0.0
    t_k = rng.gamma(shape=assign[busy], scale=1.0 / lambdas[busy])
    return float(t_k.max())


# ---------------------------------------------------------------------------
# schemes
# ---------------------------------------------------------------------------

def simulate_fixed(het: HetSpec, N: int, rng: np.random.Generator) -> RunStats:
    """Section 5.1: heterogeneity-aware fixed assignment; wait for the max."""
    assign = proportional_assignment(het.lambdas, N)
    t = _final_phase(assign, het.lambdas, rng)
    return RunStats(t_comp=t, iterations=1, n_comm=0.0, n_done=assign)


def simulate_work_exchange(het: HetSpec, N: int, cfg: ExchangeConfig,
                           rng: np.random.Generator,
                           capped_mode: Literal["carry", "waterfill"] = "carry",
                           ) -> RunStats:
    """Algorithms 1 (known het) and 3 (unknown het), single trial."""
    lam = het.lambdas
    K = het.K
    threshold = cfg.threshold_frac * N / K
    cap = (np.inf if cfg.storage_cap_frac is None or cfg.known_heterogeneity
           else int(np.ceil(cfg.storage_cap_frac * N / K)))

    # estimator state (paper eq. 23)
    est_done = np.zeros(K, dtype=np.float64)
    est_time = 0.0
    lam_hat = np.ones(K, dtype=np.float64)

    n_rem = N                       # unassigned + leftover units
    n_left_prev = np.zeros(K, dtype=np.int64)   # leftover held by workers
    n_done = np.zeros(K, dtype=np.int64)
    t_comp = 0.0
    n_comm = 0.0
    iters = 0
    t_iter = []

    while n_rem > threshold and iters < cfg.max_iterations:
        rates = lam if cfg.known_heterogeneity else lam_hat
        if np.isinf(cap):
            assign = proportional_assignment(rates, n_rem)
        elif capped_mode == "waterfill":
            assign = capped_proportional_assignment(rates, n_rem, cap)
        else:  # paper-faithful: plain min(cap, share), carry the remainder
            share = largest_remainder_round(rates, n_rem)
            assign = np.minimum(share, cap).astype(np.int64)
        carried = n_rem - int(assign.sum())    # Algorithm 3 carry-over
        if assign.sum() == 0:   # degenerate rounding for tiny n_rem
            break
        # communication overhead, eq. (1): only units beyond the leftover
        if iters > 0:
            n_comm += float(np.maximum(assign - n_left_prev, 0).sum())
        t_star, done = _iteration_outcome(assign, lam, rng)
        iters += 1
        t_iter.append(t_star)
        t_comp += t_star
        n_done += done
        n_left_prev = assign - done
        n_rem = carried + int(n_left_prev.sum())
        # online estimate, eq. (23)
        est_done += done
        est_time += t_star
        if est_time > 0:
            lam_hat = np.where(est_done > 0, est_done / est_time, 1.0)

    if n_rem > 0:
        rates = lam if cfg.known_heterogeneity else lam_hat
        assign = proportional_assignment(rates, n_rem)
        if iters > 0:
            n_comm += float(np.maximum(assign - n_left_prev, 0).sum())
        t_comp += _final_phase(assign, lam, rng)
        n_done += assign
        iters += 1
        t_iter.append(t_iter[-1] if t_iter else t_comp)

    stats = RunStats(t_comp=t_comp, iterations=iters, n_comm=n_comm,
                     n_done=n_done, t_iter=np.asarray(t_iter))
    stats.check_work_conserved(N)
    return stats


def simulate_mds(het: HetSpec, N: int, L: int,
                 rng: np.random.Generator) -> float:
    """Section 3: (K, L) MDS-coded run; completion = L-th order statistic of
    Erlang(ceil(N/L), lambda_k). Returns T_comp for one trial."""
    m = int(np.ceil(N / L))
    t_k = rng.gamma(shape=m, scale=1.0 / het.lambdas)
    return float(np.sort(t_k)[L - 1])


def simulate_oracle(het: HetSpec, N: int, rng: np.random.Generator) -> float:
    """Theorem 1: merged-process identity, T ~ Gamma(N, lambda_sum)."""
    return float(rng.gamma(shape=N, scale=1.0 / het.lambda_sum))


# ---------------------------------------------------------------------------
# Monte-Carlo means (vectorized over trials where the scheme allows)
# ---------------------------------------------------------------------------

def mds_mean_time(het: HetSpec, N: int, L: int, trials: int,
                  rng: np.random.Generator) -> float:
    m = int(np.ceil(N / L))
    t = rng.gamma(shape=m, scale=1.0 / het.lambdas, size=(trials, het.K))
    t.sort(axis=1)
    return float(t[:, L - 1].mean())


def mds_optimize(het: HetSpec, N: int, trials: int,
                 rng: np.random.Generator) -> tuple[int, float]:
    """Eq. (6): optimize L over [1, K] by Monte Carlo. Returns (L*, E[T])."""
    best = (1, np.inf)
    for L in range(1, het.K + 1):
        mean_t = mds_mean_time(het, N, L, trials, rng)
        if mean_t < best[1]:
            best = (L, mean_t)
    return best


def fixed_mean_time(het: HetSpec, N: int, trials: int,
                    rng: np.random.Generator) -> float:
    assign = proportional_assignment(het.lambdas, N)
    busy = assign > 0
    t = rng.gamma(shape=assign[busy], scale=1.0 / het.lambdas[busy],
                  size=(trials, int(busy.sum())))
    return float(t.max(axis=1).mean())


def oracle_mean_time_mc(het: HetSpec, N: int, trials: int,
                        rng: np.random.Generator) -> float:
    return float(rng.gamma(shape=N, scale=1.0 / het.lambda_sum,
                           size=trials).mean())


@dataclasses.dataclass
class ExchangeMC:
    t_comp: float
    iterations: float
    n_comm: float
    t_std: float
    i_std: float
    c_std: float


def work_exchange_mc(het: HetSpec, N: int, cfg: ExchangeConfig, trials: int,
                     rng: np.random.Generator,
                     capped_mode: Literal["carry", "waterfill"] = "carry",
                     ) -> ExchangeMC:
    ts, its, cs = np.empty(trials), np.empty(trials), np.empty(trials)
    for i in range(trials):
        s = simulate_work_exchange(het, N, cfg, rng, capped_mode)
        ts[i], its[i], cs[i] = s.t_comp, s.iterations, s.n_comm
    return ExchangeMC(float(ts.mean()), float(its.mean()), float(cs.mean()),
                      float(ts.std()), float(its.std()), float(cs.std()))
