"""DEPRECATED free-function surface over the Monte-Carlo engine.

Everything here is a thin shim over ``repro.core.schemes`` -- the unified
registry-driven Scheme API (``get_scheme(name).mc(het, N, trials, rng)``).
New code should go through the registry; these wrappers keep the original
per-scheme entry points importable and (for the scalar single-trial paths)
numerically identical to the pre-registry implementations.

``work_exchange_mc`` now runs the trial-vectorized engine (batched
Gamma/argmin/Binomial across trials) -- same distribution, ~100x faster at
the paper's K=50 / trials=1000 scale; pass ``engine="loop"`` for the old
per-trial loop.
"""
from __future__ import annotations

import warnings
from typing import Literal

import numpy as np

from . import schemes
from .schemes import MCReport as ExchangeMC    # legacy name; same fields +
from .types import ExchangeConfig, HetSpec, RunStats   # t_std/i_std/c_std


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.core.simulator.{name} is deprecated; use "
        f"repro.core.schemes.get_scheme(...) instead",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# single-trial paths (exact pre-registry numerics at fixed seed)
# ---------------------------------------------------------------------------

def simulate_work_exchange(het: HetSpec, N: int, cfg: ExchangeConfig,
                           rng: np.random.Generator,
                           capped_mode: Literal["carry", "waterfill"] = "carry",
                           ) -> RunStats:
    """Algorithms 1/3, one trial.  Use get_scheme("work_exchange")."""
    _deprecated("simulate_work_exchange")
    return schemes.simulate_work_exchange_scalar(het, N, cfg, rng, capped_mode)


def simulate_oracle(het: HetSpec, N: int, rng: np.random.Generator) -> float:
    """Theorem 1 sample, one trial.  Use get_scheme("oracle")."""
    _deprecated("simulate_oracle")
    return float(rng.gamma(shape=N, scale=1.0 / het.lambda_sum))


# ---------------------------------------------------------------------------
# Monte-Carlo means
# ---------------------------------------------------------------------------

def mds_mean_time(het: HetSpec, N: int, L: int, trials: int,
                  rng: np.random.Generator) -> float:
    _deprecated("mds_mean_time")
    return float(schemes.mds_time_samples(het, N, L, trials, rng).mean())


def mds_optimize(het: HetSpec, N: int, trials: int,
                 rng: np.random.Generator) -> tuple[int, float]:
    """Eq. (6) L sweep.  Use get_scheme("mds").mc(...) (extra["L"])."""
    _deprecated("mds_optimize")
    L, mean_t, _ = schemes.mds_sweep(het, N, trials, rng)
    return L, mean_t


def fixed_mean_time(het: HetSpec, N: int, trials: int,
                    rng: np.random.Generator) -> float:
    _deprecated("fixed_mean_time")
    return schemes.FixedScheme().mc(het, N, trials, rng).t_comp


def oracle_mean_time_mc(het: HetSpec, N: int, trials: int,
                        rng: np.random.Generator) -> float:
    _deprecated("oracle_mean_time_mc")
    return schemes.OracleScheme().mc(het, N, trials, rng).t_comp


def work_exchange_mc(het: HetSpec, N: int, cfg: ExchangeConfig, trials: int,
                     rng: np.random.Generator,
                     capped_mode: Literal["carry", "waterfill"] = "carry",
                     engine: Literal["vectorized", "loop"] = "vectorized",
                     ) -> ExchangeMC:
    """Work-exchange MC.  Use get_scheme("work_exchange[_unknown]").mc."""
    _deprecated("work_exchange_mc")
    if engine == "loop":
        ts, its, cs = np.empty(trials), np.empty(trials), np.empty(trials)
        for i in range(trials):
            s = schemes.simulate_work_exchange_scalar(het, N, cfg, rng,
                                                      capped_mode)
            ts[i], its[i], cs[i] = s.t_comp, s.iterations, s.n_comm
        return schemes._report("work_exchange", ts, its, cs)
    return schemes.work_exchange_mc_batched(het, N, cfg, trials, rng,
                                            capped_mode)
