"""Online heterogeneity (service-rate) estimators.

The paper's estimator (eq. 23) is the cumulative empirical rate
    lambda_hat_k = sum_j N_done^(k,j) / sum_j T_comp^(j).
We provide it verbatim plus two beyond-paper variants used by the
production scheduler:

* ``EMARateEstimator`` -- exponentially-weighted rate, tracks *drifting*
  heterogeneity (e.g. thermal throttling, co-tenancy changes) that the
  cumulative estimator averages away.
* ``GammaPosteriorEstimator`` -- conjugate Bayesian estimate: with
  exponential service times, the posterior over lambda_k after observing
  n events in time t (Gamma(a0 + n, b0 + t)) gives both a point estimate
  and a credible interval; the scheduler can assign by a pessimistic
  quantile to hedge against under-sampled workers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .registry import Registry


class RateEstimator:
    """Interface: observe per-iteration (done_counts, elapsed) and expose rates."""

    def __init__(self, K: int, prior_rate: float = 1.0):
        self.K = K
        self.prior_rate = float(prior_rate)

    def update(self, done: np.ndarray, elapsed: float) -> None:
        raise NotImplementedError

    def rates(self) -> np.ndarray:
        raise NotImplementedError


class CumulativeRateEstimator(RateEstimator):
    """Paper eq. (23). Initialized to lambda_hat = prior (paper uses 1)."""

    def __init__(self, K: int, prior_rate: float = 1.0):
        super().__init__(K, prior_rate)
        self.total_done = np.zeros(K, dtype=np.float64)
        self.total_time = 0.0

    def update(self, done: np.ndarray, elapsed: float) -> None:
        self.total_done += np.asarray(done, dtype=np.float64)
        self.total_time += float(elapsed)

    def rates(self) -> np.ndarray:
        if self.total_time <= 0:
            return np.full(self.K, self.prior_rate)
        r = self.total_done / self.total_time
        # a worker that has produced nothing yet keeps the prior so it is
        # still assigned work (otherwise it would starve forever)
        return np.where(self.total_done > 0, np.maximum(r, 1e-12),
                        self.prior_rate)


class EMARateEstimator(RateEstimator):
    """Beyond-paper: EMA over per-iteration empirical rates."""

    def __init__(self, K: int, prior_rate: float = 1.0, alpha: float = 0.4):
        super().__init__(K, prior_rate)
        self.alpha = float(alpha)
        self._rate = np.full(K, float(prior_rate))
        self._seen = np.zeros(K, dtype=bool)

    def update(self, done: np.ndarray, elapsed: float) -> None:
        if elapsed <= 0:
            return
        inst = np.asarray(done, dtype=np.float64) / float(elapsed)
        first = ~self._seen & (inst > 0)
        ema = (1 - self.alpha) * self._rate + self.alpha * inst
        # a worker with no observation yet holds the prior outright:
        # running its zero through the EMA would decay the prior toward
        # zero and starve a worker that simply hasn't reported (slow
        # start, long first shard) before it ever produces a unit
        self._rate = np.where(first, inst,
                              np.where(self._seen, ema, self._rate))
        self._seen |= inst > 0

    def rates(self) -> np.ndarray:
        return np.maximum(self._rate, 1e-12)


class GammaPosteriorEstimator(RateEstimator):
    """Beyond-paper: conjugate Gamma posterior over exponential service rates.

    posterior: lambda_k ~ Gamma(a0 + done_k, b0 + t_k). ``quantile`` < 0.5
    gives pessimistic assignment (hedges stragglers), 0.5 ~ median.
    """

    def __init__(self, K: int, prior_rate: float = 1.0,
                 a0: float = 1.0, quantile: float = 0.5):
        super().__init__(K, prior_rate)
        self.a0 = float(a0)
        self.b0 = self.a0 / max(prior_rate, 1e-12)
        self.quantile = float(quantile)
        self.done = np.zeros(K, dtype=np.float64)
        self.time = np.zeros(K, dtype=np.float64)

    def update(self, done: np.ndarray, elapsed: float) -> None:
        self.done += np.asarray(done, dtype=np.float64)
        self.time += float(elapsed)

    def rates(self) -> np.ndarray:
        a = self.a0 + self.done
        b = self.b0 + self.time
        if abs(self.quantile - 0.5) < 1e-9:
            return np.maximum(a / b, 1e-12)  # posterior mean ~ median for large a
        # Wilson-Hilferty approximation of the Gamma quantile
        from math import sqrt
        z = _norm_ppf(self.quantile)
        wh = a * (1 - 1 / (9 * a) + z / (3 * np.sqrt(a))) ** 3
        return np.maximum(wh / b, 1e-12)


def _norm_ppf(q: float) -> float:
    """Acklam's inverse-normal approximation (no scipy dependency)."""
    if not 0 < q < 1:
        raise ValueError("quantile in (0,1)")
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        ql = np.sqrt(-2 * np.log(q))
        return (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
               ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    if q > phigh:
        ql = np.sqrt(-2 * np.log(1 - q))
        return -(((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
               ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    ql = q - 0.5
    r = ql * ql
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * ql / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


ESTIMATOR_REGISTRY: Registry = Registry("estimator")
ESTIMATOR_REGISTRY.register("cumulative", CumulativeRateEstimator)
ESTIMATOR_REGISTRY.register("ema", EMARateEstimator)
ESTIMATOR_REGISTRY.register("bayes", GammaPosteriorEstimator)


def make_estimator(kind: str, K: int, prior_rate: float = 1.0,
                   **kw) -> RateEstimator:
    """Instantiate a registered estimator; unknown kinds raise the
    registry's uniform ``KeyError`` listing the registered names."""
    return ESTIMATOR_REGISTRY.get(kind)(K, prior_rate, **kw)
