"""One generic plugin registry behind every string-keyed surface.

The repo grew four copy-pasted registries -- ``SCHEME_REGISTRY``
(policies), ``SAMPLER_BACKENDS`` (draw engines), ``SCENARIO_REGISTRY``
(heterogeneity families), ``ARRIVAL_REGISTRY`` (serving demand) -- each
with the same ``register_*`` / ``get_*`` / ``list_*`` discipline and the
same fail-fast ``KeyError`` listing the registered keys.  ``Registry``
is that pattern once: the four become thin instantiations (public names
and error texts unchanged, pinned by tests), and the fifth surface --
``TRANSPORT_REGISTRY`` (``repro.control``) -- is born on it.

A ``Registry`` is a read-only ``Mapping`` over its *canonical* entries,
so existing idioms (``name in SCHEME_REGISTRY``, ``list(
SAMPLER_BACKENDS)``, ``sorted(SCENARIO_REGISTRY)``) keep working.
Aliases resolve in ``get``/``canonical`` but never appear in the
mapping view -- exactly the old schemes-registry behaviour.
"""
from __future__ import annotations

from typing import Dict, Generic, Iterator, List, Mapping, Optional, \
    Sequence, TypeVar

T = TypeVar("T")


class Registry(Generic[T], Mapping[str, T]):
    """String-keyed plugin registry with uniform fail-fast errors.

    ``kind`` names the noun in error messages (``"scheme"``,
    ``"sampler backend"``, ...); ``dup_label`` overrides the noun in the
    duplicate-registration error only (the historical schemes message
    says "scheme name ... already registered").

    Unknown keys raise ``KeyError("unknown <kind> <name>; have [...]")``
    with the alias list appended when the registry has aliases --
    byte-identical to the four hand-written predecessors.
    """

    def __init__(self, kind: str, *, dup_label: Optional[str] = None):
        self.kind = kind
        self.dup_label = dup_label if dup_label is not None else kind
        self._entries: Dict[str, T] = {}
        self._aliases: Dict[str, str] = {}

    # -- registration -------------------------------------------------------

    def register(self, name: str, obj: T,
                 aliases: Sequence[str] = ()) -> T:
        """Key ``obj`` under ``name`` (+ aliases); duplicates fail fast."""
        for key in (name, *aliases):
            if key in self._entries or key in self._aliases:
                raise ValueError(f"{self.dup_label} {key!r} already "
                                 f"registered")
        self._entries[name] = obj
        for a in aliases:
            self._aliases[a] = name
        return obj

    # -- lookup -------------------------------------------------------------

    def canonical(self, name: str) -> str:
        """Resolve an alias to its canonical name (identity otherwise)."""
        return self._aliases.get(name, name)

    def get(self, name: str) -> T:  # type: ignore[override]
        """The registered object for ``name`` (alias-aware), or KeyError
        listing every registered key."""
        key = self._aliases.get(name, name)
        if key not in self._entries:
            raise KeyError(self.unknown_message(name))
        return self._entries[key]

    def unknown_message(self, name: str) -> str:
        msg = f"unknown {self.kind} {name!r}; have {self.names()}"
        if self._aliases:
            msg += f" (aliases: {sorted(self._aliases)})"
        return msg

    def names(self, include_aliases: bool = False) -> List[str]:
        names = sorted(self._entries)
        if include_aliases:
            names += sorted(self._aliases)
        return names

    def aliases(self) -> Dict[str, str]:
        return dict(self._aliases)

    # -- Mapping view over canonical entries --------------------------------

    def __getitem__(self, name: str) -> T:
        if name not in self._entries:
            raise KeyError(self.unknown_message(name))
        return self._entries[name]

    def __delitem__(self, name: str) -> None:
        """Unregister a canonical entry (tests use this for cleanup);
        aliases pointing at it are removed with it."""
        if name not in self._entries:
            raise KeyError(self.unknown_message(name))
        del self._entries[name]
        for a in [a for a, c in self._aliases.items() if c == name]:
            del self._aliases[a]

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __repr__(self) -> str:
        return (f"Registry({self.kind!r}, {len(self._entries)} entries"
                + (f", {len(self._aliases)} aliases" if self._aliases
                   else "") + ")")


__all__ = ["Registry"]
