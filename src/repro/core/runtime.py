"""Semantic heterogeneous-cluster runtime: real JAX math, virtual clocks.

This container has one CPU device, so wall-clock heterogeneity cannot be
produced physically.  Instead we run the *actual* computation (per-unit
gradients, real optimizer updates -- full numerics) while the latency
dimension is driven by the paper's stochastic model (exponential service
times, the same Gamma/Binomial conditioning as ``simulator.py``).  This is
strictly stronger than a timing mock-up: every scheduling policy must also
produce bitwise-consistent learning (work conservation => the per-step
gradient sum is policy-independent), which the tests assert.

``VirtualWorkerPool`` can also replay *measured* service-time traces, so
the same runtime drives real-cluster traces when available.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .exchange import Assignment, MasterScheduler


@dataclasses.dataclass
class FailureEvent:
    worker: int
    iteration: int        # worker dies at the start of this epoch (0-based)


class VirtualWorkerPool:
    """K workers with true rates; executes one epoch of an Assignment.

    ``traces`` (optional, shape (K, E)) replays measured per-epoch service
    rates instead of the stationary ``rates``: epoch e runs at column
    ``e % E``, so a finite trace wraps around.  ``rates`` still names the
    nominal speeds the scheduler may be told about.
    """

    def __init__(self, rates: Sequence[float], seed: int = 0,
                 unit_cost: float = 1.0,
                 traces: Optional[np.ndarray] = None,
                 rng: Optional[np.random.Generator] = None):
        self.rates = np.asarray(rates, dtype=np.float64)
        self.K = self.rates.size
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self.unit_cost = float(unit_cost)   # scales service times uniformly
        self.traces = None
        if traces is not None:
            traces = np.asarray(traces, dtype=np.float64)
            if traces.ndim != 2 or traces.shape[0] != self.K:
                raise ValueError(f"traces must be (K={self.K}, E); "
                                 f"got {traces.shape}")
            if np.any(traces <= 0) or not np.all(np.isfinite(traces)):
                raise ValueError("trace rates must be finite and positive")
            self.traces = traces
        self.epoch = 0
        # per-worker finish/stop times of the last epoch (inf for idle or
        # dead workers) -- what straggler-wait accounting reads back
        self.last_t_k = np.full(self.K, np.inf)

    def rates_at(self, epoch: int) -> np.ndarray:
        """True service rates in effect during ``epoch``."""
        if self.traces is None:
            return self.rates
        return self.traces[:, epoch % self.traces.shape[1]]

    def finish_times(self, sizes: Sequence[int],
                     dead: Optional[np.ndarray] = None) -> np.ndarray:
        """Whole-queue finish times for one epoch: worker k completes its
        ``sizes[k]`` units at Gamma(sizes[k], rate_k) -- the cover-rule
        primitive (coded schemes race full replicated queues).  Advances
        the epoch counter like ``run_epoch``; idle/dead workers get inf."""
        rates = self.rates_at(self.epoch)
        self.epoch += 1
        sizes = np.asarray(sizes, dtype=np.int64)
        dead = np.zeros(self.K, bool) if dead is None else dead
        t_k = np.full(self.K, np.inf)
        busy = (sizes > 0) & ~dead
        if busy.any():
            t_k[busy] = self.rng.gamma(shape=sizes[busy],
                                       scale=self.unit_cost / rates[busy])
        self.last_t_k = t_k
        return t_k

    def run_epoch(self, assignment: Assignment,
                  dead: Optional[np.ndarray] = None
                  ) -> tuple[float, np.ndarray]:
        """Returns (elapsed, done_counts).  wait_all => run to completion;
        otherwise stop at the first completion flag (work-exchange epoch)."""
        rates = self.rates_at(self.epoch)
        self.epoch += 1
        sizes = assignment.sizes
        dead = np.zeros(self.K, bool) if dead is None else dead
        t_k = np.full(self.K, np.inf)
        busy = (sizes > 0) & ~dead
        self.last_t_k = t_k
        if not busy.any():
            return 0.0, np.zeros(self.K, dtype=np.int64)
        t_k[busy] = self.rng.gamma(shape=sizes[busy],
                                   scale=self.unit_cost / rates[busy])
        done = np.zeros(self.K, dtype=np.int64)
        if assignment.wait_all:
            done[busy] = sizes[busy]
            return float(np.max(t_k[busy])), done
        finisher = int(np.argmin(t_k))
        t_star = float(t_k[finisher])
        done[finisher] = sizes[finisher]
        others = busy.copy()
        others[finisher] = False
        if others.any():
            n = np.maximum(sizes[others] - 1, 0)
            p = np.clip(t_star / t_k[others], 0.0, 1.0)
            done[others] = self.rng.binomial(n, p)
        return t_star, done


@dataclasses.dataclass
class StepMetrics:
    loss: float
    t_comp: float
    iterations: int
    n_comm: int
    units: int
    failed_workers: List[int]


class HetTrainRuntime:
    """Drives a MasterScheduler over real per-unit gradient computations.

    ``grad_fn(params, unit_id) -> (loss, grads)`` must be pure; the runtime
    accumulates gradients in the order units complete (any order is valid
    by work conservation) and applies ``update_fn`` once per step.
    """

    def __init__(self, pool: VirtualWorkerPool,
                 grad_fn: Callable, update_fn: Callable,
                 scheduler_factory: Callable[[Sequence[int]], MasterScheduler],
                 failures: Sequence[FailureEvent] = ()):
        self.pool = pool
        self.grad_fn = grad_fn
        self.update_fn = update_fn
        self.scheduler_factory = scheduler_factory
        self.failures = list(failures)

    def step(self, params, opt_state, unit_ids: Sequence[int]):
        sched = self.scheduler_factory(unit_ids)
        dead = np.zeros(self.pool.K, dtype=bool)
        grads_sum = None
        loss_sum = 0.0
        processed: set[int] = set()
        failed: List[int] = []
        epoch = 0
        while not sched.finished:
            assignment = sched.next_assignment()
            if assignment is None:
                break
            for ev in self.failures:
                if ev.iteration == epoch and not dead[ev.worker]:
                    dead[ev.worker] = True
                    failed.append(ev.worker)
            elapsed, done = self.pool.run_epoch(assignment, dead)
            # real computation for exactly the processed prefix of each queue
            for k in range(self.pool.K):
                for u in assignment.queues[k][: int(done[k])]:
                    if u in processed:
                        raise AssertionError(f"unit {u} processed twice")
                    processed.add(u)
                    loss, g = self.grad_fn(params, u)
                    loss_sum += float(loss)
                    grads_sum = g if grads_sum is None else _tree_add(grads_sum, g)
            sched.report(done, elapsed)
            for k in np.nonzero(dead)[0]:
                sched.mark_failed(int(k))
            epoch += 1
        assert processed == set(unit_ids), "work conservation violated"
        n = len(unit_ids)
        grads_mean = _tree_scale(grads_sum, 1.0 / n)
        params, opt_state = self.update_fn(params, opt_state, grads_mean)
        return params, opt_state, StepMetrics(
            loss=loss_sum / n, t_comp=sched.t_comp,
            iterations=sched.iterations, n_comm=sched.n_comm,
            units=n, failed_workers=failed)


def _tree_add(a, b):
    import jax
    return jax.tree.map(lambda x, y: x + y, a, b)


def _tree_scale(a, s):
    import jax
    return jax.tree.map(lambda x: x * s, a)
