from . import sharding

__all__ = ["sharding"]
