"""Gradient compression for the worker->master hop (beyond-paper).

The paper trades computation time against coordination/communication; in
training, the dominant recurring payload is the gradient.  Two standard
compressors with ERROR FEEDBACK (the residual is re-added next round so
compression error does not bias the trajectory asymptotically):

  * Int8Compressor -- per-tensor symmetric int8 quantization (4x vs f32)
  * TopKCompressor -- magnitude top-k sparsification (k-fraction kept)

``roundtrip`` returns (decompressed_gradient, wire_bytes): the trainer
accumulates exactly what the master would reconstruct, so tests can
measure both the byte savings and the accuracy cost on a real model.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


class _ErrorFeedback:
    def __init__(self):
        self._residual: Dict[int, object] = {}

    def apply(self, worker: int, grads):
        res = self._residual.get(worker)
        if res is None:
            return grads
        return jax.tree.map(jnp.add, grads, res)

    def store(self, worker: int, residual):
        self._residual[worker] = residual


class Int8Compressor:
    """Symmetric per-tensor int8 with error feedback."""

    def __init__(self, error_feedback: bool = True):
        self.ef = _ErrorFeedback() if error_feedback else None

    def roundtrip(self, grads, worker: int):
        if self.ef is not None:
            grads = self.ef.apply(worker, grads)

        def comp(g):
            g32 = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq, g32 - deq, q.size + 4   # payload + scale

        leaves, treedef = jax.tree.flatten(grads)
        outs = [comp(g) for g in leaves]
        deq = jax.tree.unflatten(treedef, [o[0] for o in outs])
        if self.ef is not None:
            self.ef.store(worker, jax.tree.unflatten(
                treedef, [o[1] for o in outs]))
        nbytes = float(sum(o[2] for o in outs))
        return deq, nbytes


class TopKCompressor:
    """Keep the top-k fraction by magnitude; error feedback on the rest."""

    def __init__(self, frac: float = 0.1, error_feedback: bool = True):
        self.frac = float(frac)
        self.ef = _ErrorFeedback() if error_feedback else None

    def roundtrip(self, grads, worker: int):
        if self.ef is not None:
            grads = self.ef.apply(worker, grads)

        def comp(g):
            g32 = g.astype(jnp.float32)
            flat = g32.reshape(-1)
            k = max(1, int(self.frac * flat.size))
            thresh = jnp.sort(jnp.abs(flat))[-k]
            mask = jnp.abs(g32) >= thresh
            kept = jnp.where(mask, g32, 0.0)
            # wire: k values + k int32 indices
            return kept, g32 - kept, 8 * k

        leaves, treedef = jax.tree.flatten(grads)
        outs = [comp(g) for g in leaves]
        kept = jax.tree.unflatten(treedef, [o[0] for o in outs])
        if self.ef is not None:
            self.ef.store(worker, jax.tree.unflatten(
                treedef, [o[1] for o in outs]))
        nbytes = float(sum(o[2] for o in outs))
        return kept, nbytes
