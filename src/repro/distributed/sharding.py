"""Per-architecture PartitionSpec rules (params, optimizer state, caches).

Name+path+rank-based rules mirror the init structure; stacked layer dims
(body/enc/dec leading axes) are detected by rank and padded with None.

Conventions (DESIGN §5.4-5.5):
  * 'model' = tensor parallel (+ expert parallel when E % tp == 0)
  * 'data'  = batch + FSDP: every weight's non-TP matrix dim is sharded
              over 'data' in train mode; serve mode replicates over 'data'
  * 'pod'   = pure DP (gradient reduction only) and the work-exchange domain
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# weights whose (in, out) trailing dims shard (FSDP, model)
_IN_OUT = {"wq", "wk", "wv", "wi_gate", "wi_up", "wi", "w_in", "w_gate",
           "w_a", "w_x", "wq_a", "wq_b", "wk_b", "wv_b", "w_up", "w",
           "vis_proj", "lm_head"}
# weights whose trailing dims shard (model, FSDP)
_OUT_IN = {"wo", "w_out", "w_down"}
# weights replicated on the model axis (small / shared outputs)
_FS_ONLY = {"router", "wkv_a", "w_if"}
_NORM_1D = re.compile(r"^(ln\w*|.*_norm|b|bias)$")


def _leaf_name(path) -> str:
    return str(path[-1].key if hasattr(path[-1], "key") else path[-1])


def _path_strs(path) -> list[str]:
    return [str(p.key if hasattr(p, "key") else p) for p in path]


def _pad(spec: tuple, ndim: int) -> P:
    extra = ndim - len(spec)
    assert extra >= 0, f"rank mismatch: spec {spec} for ndim {ndim}"
    return P(*((None,) * extra + spec))


def param_spec(path, leaf, cfg, tp: int = 16, fsdp: bool = True) -> P:
    """Sharding for one parameter leaf."""
    name = _leaf_name(path)
    parts = _path_strs(path)
    nd = leaf.ndim
    fs = "data" if fsdp else None
    moe = cfg.is_moe and "mlp" in parts and name in ("wi_gate", "wi_up", "wo")
    if moe:
        ep = cfg.n_experts % tp == 0
        if name in ("wi_gate", "wi_up"):      # (E, D, F)
            spec = ("model", fs, None) if ep else (None, fs, "model")
        else:                                  # wo (E, F, D)
            spec = ("model", None, fs) if ep else (None, "model", fs)
        return _pad(spec, nd)
    if name == "embed":
        return _pad(("model", fs), nd)
    if name == "r":                            # slstm recurrent (H, 4, dh, dh)
        h = leaf.shape[-4]
        return _pad(("model" if h % tp == 0 else None, None, None, None), nd)
    if name == "conv_w":                       # (W, R)
        width = leaf.shape[-1]
        return _pad((None, "model" if width % tp == 0 else None), nd)
    if name in ("lambda", "skip_scale"):
        return _pad(("model" if leaf.shape[-1] % tp == 0 else None,), nd)
    if _NORM_1D.match(name) or nd - _stack_extra(parts, nd, 1) == 1:
        return _pad((None,), nd) if nd <= 1 else P(*((None,) * nd))
    if name in _FS_ONLY:
        return _pad((fs, None), nd)
    if name in _IN_OUT:
        out_dim = leaf.shape[-1]
        return _pad((fs, "model" if out_dim % tp == 0 else None), nd)
    if name in _OUT_IN:
        in_dim = leaf.shape[-2]
        return _pad(("model" if in_dim % tp == 0 else None, fs), nd)
    # default: replicate
    return P(*((None,) * nd))


def _stack_extra(parts, nd, base) -> int:
    return 1 if any(p in ("body", "enc", "dec") for p in parts) else 0


def param_specs(cfg, params_shape, tp: int = 16, fsdp: bool = True):
    """Spec tree matching a (possibly eval_shape'd) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, cfg, tp, fsdp),
        params_shape)


def opt_specs(cfg, opt_state_shape, pspecs):
    """AdamWState(step, mu, nu, master): moments/master mirror params."""
    from repro.optim import AdamWState
    return AdamWState(step=P(), mu=pspecs, nu=pspecs, master=pspecs)


# ---------------------------------------------------------------------------
# cache / batch specs
# ---------------------------------------------------------------------------

def cache_spec(path, leaf, cfg, dp, tp: int = 16,
               batch_shardable: bool = True) -> P:
    name = _leaf_name(path)
    parts = _path_strs(path)
    nd = leaf.ndim
    # stacked caches have a leading layer dim under body keys; UNSTACKED
    # (serving-layout) caches interpose a list index ("[j]") and have none
    has_body = any(p.startswith("b") and p[1:].isdigit() for p in parts)
    has_list = any(p.startswith("[") for p in parts)
    extra = 1 if ((has_body and not has_list)
                  or any(p in ("self", "cross") for p in parts)) else 0
    dps = dp if batch_shardable else None
    if name == "pos":
        return P()
    if name in ("k", "v"):                     # (B, S, Hkv, hd)
        hkv = leaf.shape[-2]
        head_ax = "model" if hkv % tp == 0 else None
        seq_ax = "data" if not batch_shardable else None
        return _pad((dps, seq_ax, head_ax, None), nd)
    if name in ("latent", "k_rope"):           # (B, S, r)
        # MLA latent is shared across heads (never head-shardable); store it
        # sequence-sharded over 'model' -- the per-step gather for the
        # absorbed attention is tiny vs 16x cache storage (§Perf decode)
        seq_ax = "data" if not batch_shardable else "model"
        return _pad((dps, seq_ax, None), nd)
    if name == "conv":                         # (B, W-1, R)
        r = leaf.shape[-1]
        return _pad((dps, None, "model" if r % tp == 0 else None), nd)
    if name == "C":                            # mlstm (B, H, dh, dh)
        dh = leaf.shape[-1]
        d_ax = "data" if not batch_shardable and dh % tp == 0 else None
        return _pad((dps, "model", d_ax, None), nd)
    if name in ("n", "m", "c", "h"):
        core = nd - extra
        if core == 2:                          # (B, X): rglru h / mlstm m
            x = leaf.shape[-1]
            return _pad((dps, "model" if x % tp == 0 else None), nd)
        return _pad((dps, "model") + (None,) * (core - 2), nd)
    return P(*((None,) * nd))


def cache_specs(cfg, cache_shape, dp, tp: int = 16,
                batch_shardable: bool = True):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, cfg, dp, tp,
                                      batch_shardable),
        cache_shape)


def batch_specs(batch_shape, dp, batch_shardable: bool = True):
    dps = dp if batch_shardable else None
    return jax.tree.map(lambda leaf: P(*((dps,) + (None,) * (leaf.ndim - 1))),
                        batch_shape)


def maybe_shard(x, *spec):
    """Activation sharding constraint, robust to the ambient mesh.

    Axes absent from the current (abstract) mesh are dropped; axes that do
    not divide the corresponding dim are dropped too (e.g. batch=1 decode).
    No-op outside a mesh context so model code stays runnable on 1 CPU.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or mesh.empty:
        return x
    sizes = dict(mesh.shape)
    out, nontrivial = [], False
    for dim, s in zip(x.shape, spec):
        elems = s if isinstance(s, tuple) else ((s,) if s else ())
        keep, prod = [], 1
        for a in elems:
            if a in sizes and dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        if keep:
            nontrivial = True
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    if not nontrivial:
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


# ---------------------------------------------------------------------------
# Monte-Carlo grid sharding (the experiment engine's device axis)
# ---------------------------------------------------------------------------

GRID_AXIS = "grid"


def grid_mesh(devices: Optional[int] = None) -> jax.sharding.Mesh:
    """1-D device mesh over the ``'grid'`` axis for batch-sharded
    Monte-Carlo grids (``repro.core.samplers.grid_sharding`` /
    ``repro.experiments``).

    The scenario x trials batch of a grid dispatch is embarrassingly
    parallel, so the executor shards its leading axis over this mesh with
    ``shard_map`` -- no collectives, one independent round pipeline per
    device.  ``devices=None`` takes every available device; an int is
    clamped to what the host offers, so a spec requesting 4 devices still
    runs (on fewer) on a single-device host.
    """
    devs = jax.devices()
    n = (len(devs) if devices is None
         else max(1, min(int(devices), len(devs))))
    return jax.sharding.Mesh(np.asarray(devs[:n]), (GRID_AXIS,))


BATCH_AXES = ("pod", "data")

# Megatron-style sequence parallelism for layer-boundary activations:
# residuals (and their remat-saved stacks) are sharded over 'model' along
# the sequence axis, cutting saved-activation memory by the TP degree.
# XLA inserts the all-gather before attention / reduce-scatter after --
# the SP collective pattern.  Toggled off by the perf harness to measure
# its contribution (EXPERIMENTS §Perf).
SEQ_SHARD_ACTIVATIONS = True


def shard_activations(h):
    """Seed batch sharding on (B, S, D) activations (DESIGN §5.4): XLA's
    propagation cannot infer it through the vocab-sharded embedding gather."""
    return maybe_shard(h, BATCH_AXES, None, None)


def shard_residual(h):
    """Layer-boundary activation constraint (between transformer blocks)."""
    if SEQ_SHARD_ACTIVATIONS:
        return maybe_shard(h, BATCH_AXES, "model", None)
    return maybe_shard(h, BATCH_AXES, None, None)
