"""Heterogeneity-aware training drivers: the paper's schemes as policies.

The unit of work is a microbatch; the K workers are DP rank groups / pods
(DESIGN §3).  Policies:

  equal_static        -- uniform split, wait for all (the naive baseline)
  het_static          -- Section 5.1: proportional split, wait for all
  work_exchange       -- Section 5.2: known rates, iterative reassignment
  work_exchange_online-- Section 6: rates estimated online (+ estimator
                         variants: cumulative / EMA / Bayesian)
  gradient_coded      -- Section 3 baseline translated to training:
                         fractional-repetition gradient coding, any K-s
                         replies recover the exact batch gradient

All policies run REAL gradients through the same jitted per-unit step and
MUST produce the same parameter trajectory (work conservation) -- asserted
in tests.  Time is virtual (exponential service model or traces).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coded import GradientCoding
from repro.core.estimator import make_estimator
from repro.core.exchange import MasterScheduler
from repro.core.runtime import VirtualWorkerPool
from repro.core.schemes import get_scheme
from repro.data.pipeline import HetShardedLoader, UnitStore
from repro.optim import AdamW
from repro.train.loop import make_grad_step

# Training policy names are scheme-registry names/aliases (equal_static ->
# uniform, het_static -> fixed, work_exchange_online -> unknown-het work
# exchange); gradient_coded replaces the exchange protocol with coded
# redundancy and keeps its dedicated step path below.
POLICIES = ("equal_static", "het_static", "work_exchange",
            "work_exchange_online", "gradient_coded")


@dataclasses.dataclass
class StepReport:
    step: int
    loss: float
    t_virtual: float
    iterations: int
    n_comm_units: int
    refetch_tokens: int
    grad_bytes: float


class HetTrainer:
    """Drives one of the paper's policies over real JAX training."""

    def __init__(self, model, opt: AdamW, rates: Sequence[float],
                 store: UnitStore, policy: str = "work_exchange",
                 units_per_step: int = 32, seed: int = 0,
                 estimator_kind: str = "cumulative",
                 coded_stragglers: int = 1,
                 threshold_frac: float = 0.05,
                 compressor=None,
                 traces: Optional[np.ndarray] = None,
                 trace_corpus: Optional[str] = None):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.model = model
        self.opt = opt
        self.rates = np.asarray(rates, dtype=np.float64)
        self.K = self.rates.size
        self.policy = policy
        self.units_per_step = units_per_step
        self.store = store
        self.loader = HetShardedLoader(store, self.K)
        # trace-driven pool: realized epochs run at measured per-epoch
        # rates (a literal (K, E) matrix, or a results/traces corpus by
        # name) while every policy keeps scheduling by the nominal
        # ``rates`` -- the same scheduler-sees-nominal split as the
        # trace_corpus scenario family
        if trace_corpus is not None:
            if traces is not None:
                raise ValueError("give either traces= or trace_corpus=, "
                                 "not both")
            from repro.scenarios.traces import load_corpus
            traces = load_corpus(trace_corpus).window(self.K)
        self.pool = VirtualWorkerPool(self.rates, seed=seed, traces=traces)
        self.estimator_kind = estimator_kind
        self.coded_stragglers = coded_stragglers
        self.threshold_frac = threshold_frac
        self.compressor = compressor
        self._grad_fn = jax.jit(make_grad_step(model, mode="scan"))
        self._update_fn = jax.jit(self.opt.update)
        self._persistent_estimator = None
        self._next_unit = 0

    # -- scheduler construction per policy ---------------------------------

    def _make_scheduler(self, unit_ids) -> MasterScheduler:
        """Resolve the policy through SCHEME_REGISTRY and let the scheme
        build its executable master protocol."""
        if self.policy == "work_exchange_online":
            if self._persistent_estimator is None:
                self._persistent_estimator = make_estimator(
                    self.estimator_kind, self.K)
        scheme = get_scheme(self.policy)
        return scheme.make_scheduler(unit_ids, rates=self.rates,
                                     estimator=self._persistent_estimator,
                                     threshold_frac=self.threshold_frac)

    # -- one optimizer step --------------------------------------------------

    def step(self, params, opt_state, step_idx: int,
             failures: Sequence[int] = ()) -> tuple:
        unit_ids = list(range(self._next_unit,
                              self._next_unit + self.units_per_step))
        self._next_unit += self.units_per_step
        if self.policy == "gradient_coded":
            return self._coded_step(params, opt_state, step_idx, unit_ids)

        sched = self._make_scheduler(unit_ids)
        # initial placement follows the first assignment (free prefetch)
        grads_sum = None
        loss_sum = 0.0
        grad_bytes = 0.0
        processed = set()
        dead = np.zeros(self.K, dtype=bool)
        epoch = 0
        refetch0 = self.loader.refetched_tokens
        while not sched.finished:
            assignment = sched.next_assignment()
            if assignment is None:
                break
            if epoch == 0:
                for k in range(self.K):
                    self.loader.prefetch(k, assignment.queues[k])
            for w in failures:
                if not dead[w]:
                    dead[w] = True
            elapsed, done = self.pool.run_epoch(assignment, dead)
            for k in range(self.K):
                todo = assignment.queues[k][: int(done[k])]
                if todo:
                    batches = self.loader.assign(k, todo)
                for j, u in enumerate(todo):
                    assert u not in processed, f"unit {u} done twice"
                    processed.add(u)
                    loss, g = self._grad_fn(params, batches[j])
                    loss_sum += float(loss)
                    g, nbytes = self._ship(g, k)
                    grad_bytes += nbytes
                    grads_sum = g if grads_sum is None else jax.tree.map(
                        jnp.add, grads_sum, g)
            sched.report(done, elapsed)
            for w in np.nonzero(dead)[0]:
                sched.mark_failed(int(w))
            epoch += 1
        assert processed == set(unit_ids), "work conservation violated"
        grads = jax.tree.map(lambda g: g / len(unit_ids), grads_sum)
        params, opt_state = self._update_fn(grads, opt_state, params)
        report = StepReport(
            step=step_idx, loss=loss_sum / len(unit_ids),
            t_virtual=sched.t_comp, iterations=sched.iterations,
            n_comm_units=sched.n_comm,
            refetch_tokens=self.loader.refetched_tokens - refetch0,
            grad_bytes=grad_bytes)
        return params, opt_state, report

    def _ship(self, grads, worker: int):
        """Optionally compress the per-unit gradient for 'transmission'."""
        if self.compressor is None:
            nbytes = sum(g.size * g.dtype.itemsize
                         for g in jax.tree.leaves(grads))
            return grads, float(nbytes)
        return self.compressor.roundtrip(grads, worker)

    # -- gradient-coded baseline ---------------------------------------------

    def _coded_step(self, params, opt_state, step_idx, unit_ids):
        gc = GradientCoding(self.K, self.coded_stragglers)
        owners = gc.assignment(len(unit_ids))   # per-worker local unit idx
        sizes = np.array([len(o) for o in owners])
        # completion: worker k finishes its whole queue at Gamma(|q|, rate);
        # master stops at the earliest time the union of done-prefixes
        # covers every unit (redundancy => no work exchange needed).
        t_k = self.pool.rng.gamma(shape=np.maximum(sizes, 1),
                                  scale=1.0 / self.rates)
        order = np.argsort(t_k)
        covered: set = set()
        t_done = float(t_k[order[-1]])
        used_workers: List[int] = []
        for w in order:
            used_workers.append(int(w))
            covered |= set(owners[w])
            if len(covered) == len(unit_ids):
                t_done = float(t_k[w])
                break
        # real gradients: one replica per unit, from the covering workers
        grads_sum = None
        loss_sum = 0.0
        grad_bytes = 0.0
        done_units: set = set()
        compute_units = 0
        for w in used_workers:
            for li in owners[w]:
                compute_units += 1          # redundant compute happens anyway
                if li in done_units:
                    continue
                done_units.add(li)
                batch = self.store.fetch(unit_ids[li])
                loss, g = self._grad_fn(params, batch)
                loss_sum += float(loss)
                g, nbytes = self._ship(g, w)
                grad_bytes += nbytes
                grads_sum = g if grads_sum is None else jax.tree.map(
                    jnp.add, grads_sum, g)
        grads = jax.tree.map(lambda g: g / len(unit_ids), grads_sum)
        params, opt_state = self._update_fn(grads, opt_state, params)
        report = StepReport(step=step_idx, loss=loss_sum / len(unit_ids),
                            t_virtual=t_done, iterations=1,
                            n_comm_units=0, refetch_tokens=0,
                            grad_bytes=grad_bytes)
        return params, opt_state, report

    # -- loop -----------------------------------------------------------------

    def train(self, params, steps: int,
              failures: Optional[Dict[int, Sequence[int]]] = None):
        opt_state = self.opt.init(params)
        history: List[StepReport] = []
        for s in range(steps):
            fail = (failures or {}).get(s, ())
            params, opt_state, rep = self.step(params, opt_state, s, fail)
            history.append(rep)
        return params, opt_state, history
