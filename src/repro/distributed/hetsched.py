"""Heterogeneity-aware training drivers: the paper's schemes as policies.

The unit of work is a microbatch; the K workers are DP rank groups / pods
(DESIGN §3).  Policies:

  equal_static        -- uniform split, wait for all (the naive baseline)
  het_static          -- Section 5.1: proportional split, wait for all
  work_exchange       -- Section 5.2: known rates, iterative reassignment
  work_exchange_online-- Section 6: rates estimated online (+ estimator
                         variants: cumulative / EMA / Bayesian)
  gradient_coded      -- Section 3 baseline translated to training:
                         fractional-repetition gradient coding, any K-s
                         replies recover the exact batch gradient

Every policy resolves through ``SCHEME_REGISTRY`` to an executable
scheduler -- exchange protocols to ``MasterScheduler``, gradient coding
to ``CoverScheduler`` -- and the shared virtual-step executor
(``repro.hettrain.policies.run_virtual_step``) drives it over the pool's
virtual clocks.  Gradients run through the batched ``lax.scan`` engine
(``repro.hettrain.engine``): ONE canonical-order fused dispatch per
optimizer step (pow2 unit-count bucketing shares compiles across
epochs), so the parameter trajectory is *bit-identical* across policies
by work conservation -- asserted in tests.  The old per-unit jitted loop
(one device round trip per microbatch, one recompile per distinct queue
shape) is gone.  Time is virtual (exponential service model or traces).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import make_estimator
from repro.core.runtime import VirtualWorkerPool
from repro.core.schemes import get_scheme
from repro.data.pipeline import HetShardedLoader, UnitStore
from repro.hettrain.engine import ScanGradEngine, tree_bytes
from repro.hettrain.policies import run_virtual_step
from repro.optim import AdamW

# Training policy names are scheme-registry names/aliases (equal_static ->
# uniform, het_static -> fixed, work_exchange_online -> unknown-het work
# exchange, gradient_coded -> the CoverScheduler path).
POLICIES = ("equal_static", "het_static", "work_exchange",
            "work_exchange_online", "gradient_coded")


@dataclasses.dataclass
class StepReport:
    step: int
    loss: float
    t_virtual: float
    iterations: int
    n_comm_units: int
    refetch_tokens: int
    grad_bytes: float


class HetTrainer:
    """Drives one of the paper's policies over real JAX training."""

    def __init__(self, model, opt: AdamW, rates: Sequence[float],
                 store: UnitStore, policy: str = "work_exchange",
                 units_per_step: int = 32, seed: int = 0,
                 estimator_kind: str = "cumulative",
                 coded_stragglers: int = 1,
                 threshold_frac: float = 0.05,
                 compressor=None,
                 traces: Optional[np.ndarray] = None,
                 trace_corpus: Optional[str] = None):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.model = model
        self.opt = opt
        self.rates = np.asarray(rates, dtype=np.float64)
        self.K = self.rates.size
        self.policy = policy
        self.units_per_step = units_per_step
        self.store = store
        self.loader = HetShardedLoader(store, self.K)
        # trace-driven pool: realized epochs run at measured per-epoch
        # rates (a literal (K, E) matrix, or a results/traces corpus by
        # name) while every policy keeps scheduling by the nominal
        # ``rates`` -- the same scheduler-sees-nominal split as the
        # trace_corpus scenario family
        if trace_corpus is not None:
            if traces is not None:
                raise ValueError("give either traces= or trace_corpus=, "
                                 "not both")
            from repro.scenarios.traces import load_corpus
            traces = load_corpus(trace_corpus).window(self.K)
        self.pool = VirtualWorkerPool(self.rates, seed=seed, traces=traces)
        self.estimator_kind = estimator_kind
        self.coded_stragglers = coded_stragglers
        self.threshold_frac = threshold_frac
        self.compressor = compressor
        self.engine = ScanGradEngine(model, store)
        self._update_fn = jax.jit(self.opt.update)
        self._persistent_estimator = None
        self._next_unit = 0

    # -- scheduler construction per policy ---------------------------------

    def _make_scheduler(self, unit_ids):
        """Resolve the policy through SCHEME_REGISTRY and let the scheme
        build its executable master protocol (exchange or cover)."""
        if self.policy == "work_exchange_online":
            if self._persistent_estimator is None:
                self._persistent_estimator = make_estimator(
                    self.estimator_kind, self.K)
        params = ({"s": self.coded_stragglers}
                  if self.policy == "gradient_coded" else {})
        scheme = get_scheme(self.policy, **params)
        return scheme.make_scheduler(unit_ids, rates=self.rates,
                                     estimator=self._persistent_estimator,
                                     threshold_frac=self.threshold_frac)

    # -- one optimizer step --------------------------------------------------

    def step(self, params, opt_state, step_idx: int,
             failures: Sequence[int] = ()) -> tuple:
        unit_ids = list(range(self._next_unit,
                              self._next_unit + self.units_per_step))
        self._next_unit += self.units_per_step
        sched = self._make_scheduler(unit_ids)
        refetch0 = self.loader.refetched_tokens
        stats = run_virtual_step(sched, self.pool, unit_ids,
                                 failures=failures, loader=self.loader)
        n = len(unit_ids)
        if self.compressor is None:
            # canonical path: ONE fused dispatch over the full sorted
            # step -- the gradient sum is policy-independent bitwise
            grads_sum, losses = self.engine.grad_sum(params, unit_ids)
            loss_sum = float(losses.sum())
            grad_bytes = n * tree_bytes(params)
        else:
            # lossy path: the compressor quantizes each worker group's
            # partial sum before "transmission", so dispatch follows the
            # realized (worker, units) groups instead
            grads_sum = None
            loss_sum = 0.0
            grad_bytes = 0.0
            for worker, us in stats.groups:
                g, losses = self.engine.grad_sum(params, us)
                loss_sum += float(losses.sum())
                g, nbytes = self.compressor.roundtrip(g, worker)
                grad_bytes += nbytes
                grads_sum = (g if grads_sum is None
                             else jax.tree.map(jnp.add, grads_sum, g))
        grads = jax.tree.map(lambda g: g / n, grads_sum)
        params, opt_state = self._update_fn(grads, opt_state, params)
        report = StepReport(
            step=step_idx, loss=loss_sum / n,
            t_virtual=stats.t_comp, iterations=stats.iterations,
            n_comm_units=stats.n_comm,
            refetch_tokens=self.loader.refetched_tokens - refetch0,
            grad_bytes=grad_bytes)
        return params, opt_state, report

    # -- loop -----------------------------------------------------------------

    def train(self, params, steps: int,
              failures: Optional[Dict[int, Sequence[int]]] = None):
        opt_state = self.opt.init(params)
        history: List[StepReport] = []
        for s in range(steps):
            fail = (failures or {}).get(s, ())
            params, opt_state, rep = self.step(params, opt_state, s, fail)
            history.append(rep)
        return params, opt_state, history
