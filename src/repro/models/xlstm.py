"""xLSTM blocks (arXiv:2405.04517): chunkwise-parallel mLSTM + sequential sLSTM.

mLSTM (matrix memory):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (per head, dh x dh memory)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))
with exponential input gates stabilized by the running log-max m_t.  We
implement the CHUNKWISE form: quadratic within a chunk (like attention
with a decay mask), linear recurrence on (C, n, m) across chunks -- the
TPU-efficient formulation (MXU-friendly within-chunk matmuls).

sLSTM (scalar memory, block-diagonal recurrence R per head): strictly
sequential lax.scan over time with exponential-gate stabilization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import Params, dense_init
from .recurrent import _causal_conv

MLSTM_CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    Din = int(cfg.proj_factor_mlstm * D)
    H = cfg.heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], D, 2 * Din, dt),       # path + output gate z
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, Din), jnp.float32)
                   / np.sqrt(cfg.conv_width)).astype(dt),
        "wq": dense_init(ks[2], Din, Din, dt),
        "wk": dense_init(ks[3], Din, Din, dt),
        "wv": dense_init(ks[4], Din, Din, dt),
        "w_if": dense_init(ks[5], Din, 2 * H, jnp.float32),  # i,f gates/head
        "skip_scale": jnp.ones((Din,), dt),
        "w_down": dense_init(ks[6], Din, D, dt),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f):
    """Chunkwise-parallel mLSTM core.

    q,k,v: (B, H, S, dh); log_i/log_f: (B, H, S) fp32.
    Returns h: (B, H, S, dh).
    """
    B, H, S, dh = q.shape
    L = min(MLSTM_CHUNK, S)
    assert S % L == 0, "sequence must be a multiple of the mLSTM chunk"
    nc = S // L
    shape_c = (B, H, nc, L)
    qc = q.reshape(B, H, nc, L, dh)
    kc = k.reshape(B, H, nc, L, dh)
    vc = v.reshape(B, H, nc, L, dh)
    li = log_i.reshape(shape_c)
    lf = log_f.reshape(shape_c)
    csum_f = jnp.cumsum(lf, axis=-1)                      # within-chunk
    total_f = csum_f[..., -1]                             # (B,H,nc)
    tri = jnp.tril(jnp.ones((L, L), bool))

    def step(carry, xs):
        C, n, m = carry                                   # (B,H,dh,dh),(B,H,dh),(B,H)
        qi, ki, vi, lii, lfi, csf, totf = xs
        qi = qi.astype(jnp.float32)                       # keep xs in model
        ki = ki.astype(jnp.float32)                       # dtype; upcast and
        vi = vi.astype(jnp.float32)                       # build the decay
        # matrix INSIDE the step so only one chunk's (L, L) lives at a time
        # (materializing (B,H,nc,L,L) f32 outside the scan dominated the
        # training peak memory -- EXPERIMENTS §Perf xlstm iteration 1)
        dm = (csf[..., :, None] - csf[..., None, :]) + lii[..., None, :]
        dm = jnp.where(tri, dm, -jnp.inf)
        # decay from carry-in state to each position s: g[s] = csum_f[s]
        g = csf                                           # (B,H,L)
        m_intra = jnp.max(dm, axis=-1)                    # (B,H,L)
        m_new = jnp.maximum(g + m[..., None], m_intra)    # (B,H,L)
        # inter-chunk contribution
        scale_in = jnp.exp(g + m[..., None] - m_new)      # (B,H,L)
        h_inter = jnp.einsum("bhld,bhde->bhle", qi, C) * scale_in[..., None]
        n_inter = jnp.einsum("bhld,bhd->bhl", qi, n) * scale_in
        # intra-chunk contribution
        w = jnp.exp(dm - m_new[..., None])                # (B,H,L,L)
        scores = jnp.einsum("bhld,bhtd->bhlt", qi, ki) * (dh ** -0.5)
        aw = w * scores
        h_intra = jnp.einsum("bhlt,bhtd->bhld", aw.astype(vi.dtype), vi)
        n_intra = jnp.sum(aw, axis=-1)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra),
                            jnp.exp(-m_new)) + 1e-6
        h = (h_inter + h_intra) / denom[..., None]
        # state update to end of chunk: position t contributes with decay
        # sum_{u=t+1..L} lf[u] + li[t] = (totf - csf[t]) + li[t]
        decay_to_end = totf[..., None] - csf + lii        # (B,H,L)
        m_next = jnp.maximum(totf + m, jnp.max(decay_to_end, axis=-1))
        sc_old = jnp.exp(totf + m - m_next)               # (B,H)
        sc_new = jnp.exp(decay_to_end - m_next[..., None])  # (B,H,L)
        kw = ki * sc_new[..., None].astype(ki.dtype)
        C2 = C * sc_old[..., None, None] + jnp.einsum("bhld,bhle->bhde",
                                                      kw, vi) * (dh ** -0.5)
        n2 = n * sc_old[..., None] + jnp.sum(kw, axis=2) * (dh ** -0.5)
        return (C2, n2, m_next), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    from repro.distributed.sharding import BATCH_AXES, maybe_shard

    def shard_x(t):      # keep heads on 'model' through the scan stack
        spec = (None, BATCH_AXES, "model") + (None,) * (t.ndim - 3)
        return maybe_shard(t, *spec)

    xs = (shard_x(qc.transpose(2, 0, 1, 3, 4)),
          shard_x(kc.transpose(2, 0, 1, 3, 4)),
          shard_x(vc.transpose(2, 0, 1, 3, 4)),
          shard_x(li.transpose(2, 0, 1, 3)),
          shard_x(lf.transpose(2, 0, 1, 3)),
          shard_x(csum_f.transpose(2, 0, 1, 3)),
          shard_x(total_f.transpose(2, 0, 1)))
    # checkpoint the chunk body: the scan's VJP otherwise stacks every
    # chunk's (L, L) decay/attention intermediates across the sequence
    # (EXPERIMENTS §Perf xlstm iteration 3)
    final_state, hs = jax.lax.scan(jax.checkpoint(step), (C0, n0, m0), xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
    return h, final_state


def mlstm_forward(p: Params, cfg, x: jnp.ndarray,
                  return_state: bool = False):
    B, S, D = x.shape
    H = cfg.heads
    Din = p["wq"].shape[0]
    dh = Din // H
    up = x @ p["w_up"]
    path, z = jnp.split(up, 2, axis=-1)
    path, _ = _causal_conv(path, p["conv_w"])
    path_act = jax.nn.silu(path)
    q = (path_act @ p["wq"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (path_act @ p["wk"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    v = (path @ p["wv"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    gates = (path_act @ p["w_if"]).astype(jnp.float32)    # (B,S,2H)
    log_i, f_pre = jnp.split(gates.transpose(0, 2, 1).reshape(B, 2, H, S),
                             2, axis=1)
    log_i = log_i[:, 0]
    log_f = jax.nn.log_sigmoid(f_pre[:, 0])
    h, (Cf, nf, mf) = _mlstm_chunk_scan(q, k, v, log_i, log_f)  # (B,H,S,dh)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, Din).astype(x.dtype)
    h = h + path_act * p["skip_scale"]
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    if return_state:
        W = p["conv_w"].shape[0]
        path_pre = x @ p["w_up"][:, :Din]
        conv_state = path_pre[:, -(W - 1):] if S >= W - 1 else jnp.pad(
            path_pre, ((0, 0), (W - 1 - S, 0), (0, 0)))
        return out, {"C": Cf, "n": nf, "m": mf, "conv": conv_state}
    return out


def mlstm_cache_init(cfg, batch: int, dtype) -> Params:
    H = cfg.heads
    Din = int(cfg.proj_factor_mlstm * cfg.d_model)
    dh = Din // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, Din), dtype)}


def mlstm_decode(p: Params, cfg, x: jnp.ndarray, cache: Params):
    """Single-token recurrent update. x: (B, 1, D)."""
    B = x.shape[0]
    H = cfg.heads
    Din = p["wq"].shape[0]
    dh = Din // H
    up = x @ p["w_up"]
    path, z = jnp.split(up, 2, axis=-1)
    path, conv_state = _causal_conv(path, p["conv_w"], cache["conv"])
    path_act = jax.nn.silu(path)
    q = (path_act @ p["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = (path_act @ p["wk"]).reshape(B, H, dh).astype(jnp.float32)
    v = (path @ p["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gates = (path_act @ p["w_if"]).astype(jnp.float32)[:, 0]   # (B,2H)
    log_i, f_pre = gates[:, :H], gates[:, H:]
    log_f = jax.nn.log_sigmoid(f_pre)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    sc_old = jnp.exp(log_f + m - m_new)
    sc_new = jnp.exp(log_i - m_new)
    kw = k * sc_new[..., None] * (dh ** -0.5)
    C2 = C * sc_old[..., None, None] + kw[..., :, None] * v[..., None, :]
    n2 = n * sc_old[..., None] + kw
    num = jnp.einsum("bhd,bhde->bhe", q, C2)
    den = jnp.maximum(jnp.abs(jnp.sum(q * n2, -1)), jnp.exp(-m_new)) + 1e-6
    h = (num / den[..., None]).reshape(B, 1, Din).astype(x.dtype)
    h = h + path_act * p["skip_scale"]
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    return out, {"C": C2, "n": n2, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D, H = cfg.d_model, cfg.heads
    dh = D // H
    ks = jax.random.split(key, 4)
    # round the FFN width to a TP-friendly multiple of 64
    Dff = -(-int(cfg.proj_factor_slstm * D) // 64) * 64
    # HEAD-MAJOR layouts throughout: the pre-activation projection emits
    # (..., H, 4, dh) so the TP shard boundary of the flattened output dim
    # lands exactly on head boundaries -- otherwise GSPMD reshards every
    # time step of the recurrence (EXPERIMENTS §Perf xlstm iteration 2).
    r = (jax.random.normal(ks[1], (H, 4, dh, dh), jnp.float32)
         / np.sqrt(dh)).astype(jnp.float32)
    return {
        "w": dense_init(ks[0], D, 4 * D, jnp.float32),    # -> (H, 4, dh)
        "r": r,                                           # recurrent (block-diag)
        "b": jnp.zeros((H, 4, dh), jnp.float32),
        "w_up": dense_init(ks[2], D, 2 * Dff, dt),        # GLU-style FFN
        "w_down": dense_init(ks[3], Dff, D, dt),
    }


def _slstm_cell(p, cfg, x_pre, state):
    """One time step. x_pre: (B, H, 4, dh) pre-activations from input."""
    c, n, h, m = state
    H = cfg.heads
    # recurrent contribution: per-gate block-diag matmul on h (head-local)
    rec = jnp.einsum("bhd,hgde->bhge", h, p["r"])         # (B,H,4,dh)
    pre = x_pre + rec + p["b"][None]
    i_pre, f_pre, z_pre, o_pre = (pre[:, :, 0], pre[:, :, 1],
                                  pre[:, :, 2], pre[:, :, 3])
    log_i = i_pre
    log_f = jax.nn.log_sigmoid(f_pre)
    m2 = jnp.maximum(log_f + m, log_i)
    i_g = jnp.exp(log_i - m2)
    f_g = jnp.exp(log_f + m - m2)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c2 = f_g * c + i_g * z
    n2 = f_g * n + i_g
    h2 = o * c2 / jnp.maximum(n2, 1e-6)
    return (c2, n2, h2, m2)


def slstm_forward(p: Params, cfg, x: jnp.ndarray,
                  return_state: bool = False):
    """Sequential scan over time. x: (B, S, D)."""
    B, S, D = x.shape
    H = cfg.heads
    dh = D // H
    x_pre = (x @ p["w"].astype(x.dtype)).astype(jnp.float32)
    x_pre = x_pre.reshape(B, S, H, 4, dh)

    def step(state, xp):
        s2 = _slstm_cell(p, cfg, xp, state)
        return s2, s2[2]

    z = jnp.zeros((B, H, dh), jnp.float32)
    state0 = (z, z, z, jnp.full((B, H, dh), -1e30, jnp.float32))
    final, hs = jax.lax.scan(step, state0, x_pre.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    up = h @ p["w_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ p["w_down"]
    if return_state:
        c, n, hh, m = final
        return out, {"c": c, "n": n, "h": hh, "m": m}
    return out


def slstm_cache_init(cfg, batch: int, dtype) -> Params:
    H = cfg.heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}


def slstm_decode(p: Params, cfg, x: jnp.ndarray, cache: Params):
    B = x.shape[0]
    H = cfg.heads
    dh = cfg.d_model // H
    x_pre = (x[:, 0] @ p["w"].astype(x.dtype)).astype(jnp.float32)
    x_pre = x_pre.reshape(B, H, 4, dh)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c2, n2, h2, m2 = _slstm_cell(p, cfg, x_pre, state)
    h = h2.reshape(B, 1, cfg.d_model).astype(x.dtype)
    up = h @ p["w_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ p["w_down"]
    return out, {"c": c2, "n": n2, "h": h2, "m": m2}
