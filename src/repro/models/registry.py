"""Model facade: uniform init / loss / prefill / decode across families.

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input
of an (arch x shape) cell -- weak-type-correct, shardable, no device
allocation -- consumed by the multi-pod dry-run and the benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec as encdec_mod
from . import transformer as tf_mod

# decoder prefix length used when prefilling an encoder-decoder model
ENCDEC_PREFILL_TGT = 1024


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------

    def init(self, key) -> Any:
        if self.cfg.family == "encdec":
            return encdec_mod.init_encdec_params(self.cfg, key)
        return tf_mod.init_lm_params(self.cfg, key)

    def param_specs(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # -- train --------------------------------------------------------------

    def loss(self, params, batch, mode: str = "scan", remat: bool = False):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_loss(params, self.cfg, batch, mode, remat)
        return tf_mod.lm_loss(params, self.cfg, batch, mode, remat)

    # -- serve ---------------------------------------------------------------

    def init_cache(self, batch: int, s_max: int, stacked: bool = True):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_cache_init(self.cfg, batch, s_max)
        return tf_mod.init_cache(self.cfg, batch, s_max, stacked)

    def cache_specs(self, batch: int, s_max: int, stacked: bool = True):
        return jax.eval_shape(lambda: self.init_cache(batch, s_max, stacked))

    def prefill(self, params, batch, cache, mode: str = "unroll"):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_prefill(params, self.cfg, batch, cache,
                                             mode)
        return tf_mod.lm_prefill(params, self.cfg, batch, cache, mode)

    def decode_step(self, params, cache, tokens, mode: str = "unroll"):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_decode_step(params, self.cfg, cache,
                                                 tokens, mode)
        return tf_mod.lm_decode_step(params, self.cfg, cache, tokens, mode)

    # -- dry-run input specs --------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        emb = functools.partial(jax.ShapeDtypeStruct,
                                dtype=jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            if shape.kind == "train":
                s = S // 2
                return {"frame_embeds": emb((B, s, cfg.d_model)),
                        "tokens": i32((B, s)), "labels": i32((B, s))}
            if shape.kind == "prefill":
                return {"frame_embeds": emb((B, S, cfg.d_model)),
                        "tokens": i32((B, ENCDEC_PREFILL_TGT))}
            return {"tokens": i32((B, 1))}
        if cfg.frontend == "vision":
            F = cfg.n_frontend_tokens
            if shape.kind == "train":
                return {"tokens": i32((B, S - F)), "labels": i32((B, S - F)),
                        "image_embeds": emb((B, F, cfg.d_model))}
            if shape.kind == "prefill":
                return {"tokens": i32((B, S - F)),
                        "image_embeds": emb((B, F, cfg.d_model))}
            return {"tokens": i32((B, 1))}
        if shape.kind == "train":
            return {"tokens": i32((B, S)), "labels": i32((B, S))}
        if shape.kind == "prefill":
            return {"tokens": i32((B, S))}
        return {"tokens": i32((B, 1))}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
