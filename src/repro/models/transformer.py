"""Decoder-LM assembly for all LM-family architectures.

Layers are grouped into repeating "periods" (the block pattern of hybrid
archs; period 1 for homogeneous stacks).  Parameters are stored as
  params["body"] = {"b<i>": block-params stacked over n_periods}   (scanned)
  params["tail"] = {"t<i>": block-params}                          (unrolled)
so the SAME pytree serves both execution modes:
  * mode="scan"   -- lax.scan over periods (production: fast compiles,
                     remat-friendly);
  * mode="unroll" -- Python loop (dry-run: exact per-op cost accounting,
                     cf. DESIGN §5.3).
Caches for prefill/decode mirror the same layout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec_mod
from . import xlstm as xlstm_mod
from .common import (Params, dense_init, embed_init, rmsnorm, softmax_xent,
                     swiglu, swiglu_init, tree_index)


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg):
    kinds = cfg.layer_kinds()
    if cfg.block_pattern:
        p = len(cfg.block_pattern)
    elif cfg.slstm_every:
        p = cfg.slstm_every
    else:
        p = 1
    full = cfg.n_layers // p
    tail = kinds[full * p:]
    return kinds[:p], full, tail


# ---------------------------------------------------------------------------
# block init / apply by kind
# ---------------------------------------------------------------------------

def _block_init(key, cfg, kind: str) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "attn":
        p = {"ln1": jnp.zeros((D,), dt), "attn": attn.gqa_init(k1, cfg),
             "ln2": jnp.zeros((D,), dt)}
        p["mlp"] = (moe_mod.moe_init(k2, cfg) if cfg.is_moe
                    else swiglu_init(k2, D, cfg.d_ff, dt))
        return p
    if kind == "mla":
        return {"ln1": jnp.zeros((D,), dt), "attn": attn.mla_init(k1, cfg),
                "ln2": jnp.zeros((D,), dt),
                "mlp": swiglu_init(k2, D, cfg.d_ff, dt)}
    if kind == "rec":
        return {"ln1": jnp.zeros((D,), dt), "rec": rec_mod.rglru_init(k1, cfg),
                "ln2": jnp.zeros((D,), dt),
                "mlp": swiglu_init(k2, D, cfg.d_ff, dt)}
    if kind == "mlstm":
        return {"ln": jnp.zeros((D,), dt), "cell": xlstm_mod.mlstm_init(k1, cfg)}
    if kind == "slstm":
        return {"ln": jnp.zeros((D,), dt), "cell": xlstm_mod.slstm_init(k1, cfg)}
    raise ValueError(kind)


def _resolve_kind(cfg, kind: str) -> str:
    if kind == "attn" and cfg.attn_kind == "mla":
        return "mla"
    return kind


def _sp_gather(x):
    """Megatron-SP boundary: gather the sequence axis on the bf16 normed
    activation right before temporal mixing (attention / recurrence).
    Placing the constraint HERE (post-norm, model dtype) keeps the
    all-gather at bf16 width instead of GSPMD hoisting it into the norm's
    f32 interior (EXPERIMENTS §Perf internvl2 iteration 1)."""
    from repro.distributed.sharding import BATCH_AXES, maybe_shard
    return maybe_shard(x, BATCH_AXES, None, None)


def _sp_scatter(y):
    """Re-shard the temporal-mix output back to sequence-parallel: the o-proj
    partial sums become a reduce-scatter instead of a full all-reduce."""
    from repro.distributed.sharding import shard_residual
    return shard_residual(y)


def _block_fwd(p: Params, cfg, kind: str, h: jnp.ndarray, aux: jnp.ndarray):
    """Training / no-cache forward of one block.

    The residual stream h stays sequence-sharded (SP); only the temporal
    mix gathers.  The MLP is position-wise and runs entirely seq-local.
    """
    if kind in ("attn", "mla"):
        window = cfg.window if cfg.attn_kind == "swa" or cfg.block_pattern \
            else 0
        x = _sp_gather(rmsnorm(h, p["ln1"], cfg.norm_eps))
        if kind == "mla":
            y = attn.mla_forward(p["attn"], cfg, x)
        else:
            y = attn.gqa_forward(p["attn"], cfg, x, window=window)
        h = h + _sp_scatter(y)
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, a = moe_mod.moe_apply(p["mlp"], cfg, x)
            aux = aux + a
        else:
            y = swiglu(p["mlp"], x)
        return h + _sp_scatter(y), aux
    if kind == "rec":
        x = _sp_gather(rmsnorm(h, p["ln1"], cfg.norm_eps))
        h = h + _sp_scatter(rec_mod.rglru_forward(p["rec"], cfg, x))
        y = swiglu(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
        return h + _sp_scatter(y), aux
    if kind == "mlstm":
        x = _sp_gather(rmsnorm(h, p["ln"], cfg.norm_eps))
        return h + _sp_scatter(
            xlstm_mod.mlstm_forward(p["cell"], cfg, x)), aux
    if kind == "slstm":
        x = _sp_gather(rmsnorm(h, p["ln"], cfg.norm_eps))
        return h + _sp_scatter(
            xlstm_mod.slstm_forward(p["cell"], cfg, x)), aux
    raise ValueError(kind)


# -- cache-aware paths -------------------------------------------------------

def _block_cache_init(cfg, kind: str, batch: int, s_max: int, dtype):
    if kind == "attn":
        window = cfg.window if cfg.attn_kind == "swa" or cfg.block_pattern \
            else 0
        return attn.gqa_cache_init(cfg, batch, s_max, window, dtype)
    if kind == "mla":
        return attn.mla_cache_init(cfg, batch, s_max, dtype)
    if kind == "rec":
        return rec_mod.rglru_cache_init(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.mlstm_cache_init(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm_mod.slstm_cache_init(cfg, batch, dtype)
    raise ValueError(kind)


def _block_prefill(p, cfg, kind, h, cache, aux):
    if kind in ("attn", "mla"):
        window = cfg.window if cfg.attn_kind == "swa" or cfg.block_pattern \
            else 0
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        if kind == "mla":
            y, cache = attn.mla_prefill(p["attn"], cfg, x, cache)
        else:
            y, cache = attn.gqa_prefill(p["attn"], cfg, x, cache, window)
        h = h + y
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, a = moe_mod.moe_apply(p["mlp"], cfg, x)
            aux = aux + a
        else:
            y = swiglu(p["mlp"], x)
        return h + y, cache, aux
    if kind == "rec":
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        # prefill == forward + exact final-state capture
        u_in = x @ p["rec"]["w_in"]
        u, _ = rec_mod._causal_conv(u_in, p["rec"]["conv_w"])
        a, b = rec_mod._rglru_coeffs(p["rec"], cfg, u)
        hseq = rec_mod.linear_recurrence(a, b)
        gate = jax.nn.gelu(x @ p["rec"]["w_gate"])
        y = (gate * hseq.astype(x.dtype)) @ p["rec"]["w_out"]
        W = cfg.conv_width
        S = u_in.shape[1]
        conv_state = (u_in[:, -(W - 1):] if S >= W - 1 else
                      jnp.pad(u_in, ((0, 0), (W - 1 - S, 0), (0, 0))))
        cache = {"h": hseq[:, -1], "conv": conv_state}
        h = h + y
        return (h + swiglu(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps)),
                cache, aux)
    if kind in ("mlstm", "slstm"):
        x = rmsnorm(h, p["ln"], cfg.norm_eps)
        fwd = (xlstm_mod.mlstm_forward if kind == "mlstm"
               else xlstm_mod.slstm_forward)
        y, state = fwd(p["cell"], cfg, x, return_state=True)
        return h + y, state, aux
    raise ValueError(kind)


def _block_decode(p, cfg, kind, h, cache, pos):
    if kind in ("attn", "mla"):
        window = cfg.window if cfg.attn_kind == "swa" or cfg.block_pattern \
            else 0
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        if kind == "mla":
            y, cache = attn.mla_decode(p["attn"], cfg, x, cache, pos)
        else:
            y, cache = attn.gqa_decode(p["attn"], cfg, x, cache, pos, window)
        h = h + y
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_mod.moe_apply(p["mlp"], cfg, x)
        else:
            y = swiglu(p["mlp"], x)
        return h + y, cache
    if kind == "rec":
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        y, cache = rec_mod.rglru_decode(p["rec"], cfg, x, cache)
        h = h + y
        return h + swiglu(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps)), cache
    if kind == "mlstm":
        x = rmsnorm(h, p["ln"], cfg.norm_eps)
        y, cache = xlstm_mod.mlstm_decode(p["cell"], cfg, x, cache)
        return h + y, cache
    if kind == "slstm":
        x = rmsnorm(h, p["ln"], cfg.norm_eps)
        y, cache = xlstm_mod.slstm_decode(p["cell"], cfg, x, cache)
        return h + y, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full-model init
# ---------------------------------------------------------------------------

def init_lm_params(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    period, full, tail = layer_plan(cfg)
    keys = jax.random.split(key, 4 + len(period) * full + len(tail))
    params: Params = {
        "embed": embed_init(keys[0], cfg.vocab_padded, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab_padded, dt),
    }
    if cfg.frontend == "vision":
        params["vis_proj"] = dense_init(keys[2], cfg.d_model, cfg.d_model, dt)
    kidx = 4
    body = {}
    for i, kind in enumerate(period):
        rkind = _resolve_kind(cfg, kind)
        stack = []
        for j in range(full):
            stack.append(_block_init(keys[kidx], cfg, rkind))
            kidx += 1
        body[f"b{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stack) \
            if full > 1 else jax.tree.map(lambda x: x[None], stack[0])
    params["body"] = body
    tail_p = {}
    for i, kind in enumerate(tail):
        tail_p[f"t{i}"] = _block_init(keys[kidx], cfg, _resolve_kind(cfg, kind))
        kidx += 1
    params["tail"] = tail_p
    return params


def init_cache(cfg, batch: int, s_max: int, stacked: bool = True) -> Params:
    """stacked=True: per-kind caches stacked over layers (scan execution).
    stacked=False: one SEPARATE buffer per layer (list) -- the serving
    layout: each decode step updates small per-layer tensors in place and
    donation aliases them, instead of re-materializing the whole
    (n_layers, ...) stack every step."""
    dt = jnp.dtype(cfg.dtype)
    period, full, tail = layer_plan(cfg)
    body = {}
    for i, kind in enumerate(period):
        rkind = _resolve_kind(cfg, kind)
        one = _block_cache_init(cfg, rkind, batch, s_max, dt)
        if stacked:
            body[f"b{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None],
                                           (full, *x.shape)).copy(), one)
        else:
            body[f"b{i}"] = [jax.tree.map(jnp.copy, one)
                             for _ in range(full)]
    tail_c = {}
    for i, kind in enumerate(tail):
        tail_c[f"t{i}"] = _block_cache_init(cfg, _resolve_kind(cfg, kind),
                                            batch, s_max, dt)
    return {"body": body, "tail": tail_c, "pos": jnp.zeros((), jnp.int32)}


def _body_cache_slices(cache_body, full: int):
    """Per-layer cache views for unrolled execution (stacked or list)."""
    sample = next(iter(cache_body.values()))
    if isinstance(sample, list):
        return [{k: cache_body[k][j] for k in cache_body}
                for j in range(full)], False
    return [jax.tree.map(lambda x: x[j], cache_body)
            for j in range(full)], True


def _rebuild_body_cache(outs, was_stacked: bool, keys):
    if was_stacked:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs) \
            if len(outs) > 1 else jax.tree.map(lambda x: x[None], outs[0])
    return {k: [o[k] for o in outs] for k in keys}


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg, batch) -> jnp.ndarray:
    from repro.distributed.sharding import shard_activations
    h = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision" and "image_embeds" in batch:
        vis = batch["image_embeds"].astype(h.dtype) @ params["vis_proj"]
        h = jnp.concatenate([vis, h], axis=1)
    return shard_activations(h)


def forward_hidden(params, cfg, h, mode: str = "scan",
                   remat: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Runs the full block stack; returns (h, moe_aux)."""
    period, full, tail = layer_plan(cfg)
    rkinds = [_resolve_kind(cfg, k) for k in period]
    aux = jnp.zeros((), jnp.float32)

    from repro.distributed.sharding import shard_residual

    def superblock(carry, pslice):
        h, aux = carry
        h = shard_residual(h)
        for i, rk in enumerate(rkinds):
            h, aux = _block_fwd(pslice[f"b{i}"], cfg, rk, h, aux)
        return (shard_residual(h), aux), None

    # NOTE (EXPERIMENTS §Perf qwen3 it4): saving the tagged MoE capacity
    # buffers (policy save_only_these_names("moe_buf","moe_out")) removes
    # the remat re-gather + re-all-to-all (-37% collective bytes) but the
    # top-8 capacity buffers are ~8x the token count, so peak memory blew
    # 14.1 -> 44.8 GiB: net refuted at this batch size; full remat stays.
    sb = jax.checkpoint(superblock) if remat else superblock
    if mode == "scan":
        (h, aux), _ = jax.lax.scan(sb, (h, aux), params["body"])
    else:
        for j in range(full):
            pslice = jax.tree.map(lambda x: x[j], params["body"])
            (h, aux), _ = sb((h, aux), pslice)
    for i, kind in enumerate(tail):
        h, aux = _block_fwd(params["tail"][f"t{i}"], cfg,
                            _resolve_kind(cfg, kind), h, aux)
    return h, aux


def _mask_padded_vocab(cfg, logits):
    if cfg.vocab_padded == cfg.vocab_size:
        return logits
    mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
    return jnp.where(mask, logits, jnp.finfo(jnp.float32).min)


def lm_loss(params, cfg, batch, mode: str = "scan", remat: bool = False,
            aux_weight: float = 0.01):
    h = _embed_tokens(params, cfg, batch)
    h, aux = forward_hidden(params, cfg, h, mode, remat)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "vision" and "image_embeds" in batch:
        h = h[:, batch["image_embeds"].shape[1]:]     # loss on text positions
    logits = _mask_padded_vocab(cfg, h @ params["lm_head"])
    loss = softmax_xent(logits, batch["labels"])
    if cfg.is_moe:
        loss = loss + aux_weight * aux
    return loss, {"xent": loss, "moe_aux": aux}


def lm_prefill(params, cfg, batch, cache, mode: str = "unroll"):
    """Prefill: returns (last-position logits, populated cache)."""
    h = _embed_tokens(params, cfg, batch)
    period, full, tail = layer_plan(cfg)
    rkinds = [_resolve_kind(cfg, k) for k in period]
    aux = jnp.zeros((), jnp.float32)

    def superblock(carry, xs):
        h, aux = carry
        pslice, cslice = xs
        new_c = {}
        for i, rk in enumerate(rkinds):
            h, c, aux = _block_prefill(pslice[f"b{i}"], cfg, rk, h,
                                       cslice[f"b{i}"], aux)
            new_c[f"b{i}"] = c
        return (h, aux), new_c

    if mode == "scan":
        (h, aux), body_c = jax.lax.scan(superblock, (h, aux),
                                        (params["body"], cache["body"]))
    else:
        cache_layers, was_stacked = _body_cache_slices(cache["body"], full)
        outs = []
        for j in range(full):
            pslice = jax.tree.map(lambda x: x[j], params["body"])
            (h, aux), nc = superblock((h, aux), (pslice, cache_layers[j]))
            outs.append(nc)
        body_c = _rebuild_body_cache(outs, was_stacked,
                                     list(cache["body"].keys()))
    tail_c = {}
    for i, kind in enumerate(tail):
        h, c, aux = _block_prefill(params["tail"][f"t{i}"], cfg,
                                   _resolve_kind(cfg, kind), h,
                                   cache["tail"][f"t{i}"], aux)
        tail_c[f"t{i}"] = c
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _mask_padded_vocab(cfg, h[:, -1:] @ params["lm_head"])
    new_cache = {"body": body_c, "tail": tail_c,
                 "pos": jnp.asarray(h.shape[1], jnp.int32)}
    return logits, new_cache


def lm_decode_step(params, cfg, cache, tokens, mode: str = "unroll"):
    """One-token decode. tokens: (B, 1). Returns (logits, cache)."""
    pos = cache["pos"]
    from repro.distributed.sharding import shard_activations
    h = shard_activations(params["embed"][tokens])
    period, full, tail = layer_plan(cfg)
    rkinds = [_resolve_kind(cfg, k) for k in period]

    def superblock(h, xs):
        pslice, cslice = xs
        new_c = {}
        for i, rk in enumerate(rkinds):
            h, c = _block_decode(pslice[f"b{i}"], cfg, rk, h,
                                 cslice[f"b{i}"], pos)
            new_c[f"b{i}"] = c
        return h, new_c

    if mode == "scan":
        h, body_c = jax.lax.scan(superblock, h,
                                 (params["body"], cache["body"]))
    else:
        cache_layers, was_stacked = _body_cache_slices(cache["body"], full)
        outs = []
        for j in range(full):
            pslice = jax.tree.map(lambda x: x[j], params["body"])
            h, nc = superblock(h, (pslice, cache_layers[j]))
            outs.append(nc)
        body_c = _rebuild_body_cache(outs, was_stacked,
                                     list(cache["body"].keys()))
    tail_c = {}
    for i, kind in enumerate(tail):
        h, c = _block_decode(params["tail"][f"t{i}"], cfg,
                             _resolve_kind(cfg, kind), h,
                             cache["tail"][f"t{i}"], pos)
        tail_c[f"t{i}"] = c
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _mask_padded_vocab(cfg, h @ params["lm_head"])
    new_cache = {"body": body_c, "tail": tail_c, "pos": pos + 1}
    return logits, new_cache
