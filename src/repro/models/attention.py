"""Attention variants: GQA (full / sliding-window / local) and MLA.

Three compute paths share one interface:
  * direct   -- materialized scores; short sequences / decode.
  * chunked  -- online-softmax over query chunks (jnp flash reference);
               bounds live memory at long context.  This is also the oracle
               for the Pallas flash kernel (kernels/flash_attention).
  * pallas   -- TPU kernel (selected by ops-level flag; not used on CPU).

Decode uses explicit caches: full attention keeps (B, S_max, kvH, hd) with a
write cursor; SWA/local keep a ring buffer of the window; MLA caches the
shared latent + rope key (absorbed-matmul decode path).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import Params, apply_rope, causal_mask, dense_init, rmsnorm

CHUNK_Q = 1024     # query chunk for the flash reference path
DIRECT_MAX_S = 2048
# Dry-run sets this so chunk loops unroll into the HLO (exact cost
# accounting); production keeps the lax.map rolled form.
UNROLL_CHUNKS = False


# ---------------------------------------------------------------------------
# core softmax-attention on (possibly grouped) heads
# ---------------------------------------------------------------------------

def _scores_mask(bias_mask: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    neg = jnp.finfo(scores.dtype).min
    return jnp.where(bias_mask, scores, neg)


def grouped_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      mask: jnp.ndarray, scale: float) -> jnp.ndarray:
    """q: (B,Sq,Hq,dq)  k: (B,Sk,Hkv,dq)  v: (B,Sk,Hkv,dv); GQA grouping.

    mask: broadcastable to (B, Hkv, g, Sq, Sk) from (Sq, Sk).
    """
    B, Sq, Hq, dq = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dq)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = _scores_mask(mask, scores * scale)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return ctx.reshape(B, Sq, Hq, v.shape[-1])


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      scale: float, q_offset: int = 0,
                      window: int = 0, chunk: int = CHUNK_Q,
                      causal: bool = True) -> jnp.ndarray:
    """Flash-style online softmax over query chunks (pure jnp).

    Causal (+ optional sliding window) masking; memory O(chunk * Sk).
    """
    B, Sq, Hq, dq = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk
    qc = q.reshape(B, nq, chunk, Hkv, g, dq).transpose(1, 0, 2, 3, 4, 5)

    k_pos = jnp.arange(Sk)

    def one_chunk(ci, qi, k_blk=None, v_blk=None, k_lo=0):
        k_blk = k if k_blk is None else k_blk
        v_blk = v if v_blk is None else v_blk
        kp = k_lo + jnp.arange(k_blk.shape[1])
        q_pos = ci * chunk + jnp.arange(chunk) + q_offset
        if causal:
            mask = kp[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kp[None, :] > q_pos[:, None] - window
        else:
            mask = jnp.ones((chunk, k_blk.shape[1]), bool)
        scores = jnp.einsum("bskgd,btkd->bkgst", qi,
                            k_blk).astype(jnp.float32)
        scores = _scores_mask(mask, scores * scale)
        m = jnp.max(scores, axis=-1, keepdims=True)
        m = jnp.maximum(m, -1e30)
        p = jnp.exp(scores - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        ctx = jnp.einsum("bkgst,btkd->bskgd",
                         (p / jnp.maximum(l, 1e-30)).astype(v_blk.dtype),
                         v_blk)
        return ctx

    if UNROLL_CHUNKS:
        # STATIC causal block skipping: q-chunk ci only attends k-chunks
        # whose positions can be <= its own (and within the window) -- the
        # per-chunk k slice bounds are Python ints, so the wasted
        # upper-triangle (and out-of-window prefix) work is never emitted
        # into the HLO.  Matches the Pallas kernel's skip on the XLA path
        # (EXPERIMENTS §Perf prefill iteration).
        outs = []
        for ci in range(nq):
            if causal:
                hi = min(Sk, (ci + 1) * chunk + q_offset)
                lo = 0
                if window > 0:
                    lo = max(0, (ci * chunk + q_offset - window + 1)
                             // chunk * chunk)
            else:
                lo, hi = 0, Sk
            outs.append(one_chunk(ci, qc[ci], k[:, lo:hi], v[:, lo:hi], lo))
        ctx = jnp.stack(outs)
    else:
        ctx = jax.lax.map(lambda args: one_chunk(*args),
                          (jnp.arange(nq), qc))
    ctx = ctx.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * chunk, Hq,
                                                  v.shape[-1])
    return ctx[:, :Sq]


def attention_ctx(q, k, v, scale, q_offset=0, window=0, force_direct=False):
    """Dispatch direct vs chunked on sequence length."""
    Sq, Sk = q.shape[1], k.shape[1]
    if force_direct or max(Sq, Sk) <= DIRECT_MAX_S:
        mask = causal_mask(Sq, Sk, q_offset, window)
        return grouped_attention(q, k, v, mask, scale)
    return chunked_attention(q, k, v, scale, q_offset, window)


# ---------------------------------------------------------------------------
# GQA projection block (full / swa / local)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg) -> Params:
    import numpy as np
    dt = jnp.dtype(cfg.dtype)
    H, Hkv, hd, D = cfg.heads, cfg.kv_heads, cfg.hd, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], D, H * hd, dt),
         "wk": dense_init(ks[1], D, Hkv * hd, dt),
         "wv": dense_init(ks[2], D, Hkv * hd, dt),
         "wo": dense_init(ks[3], H * hd, D, dt)}
    if getattr(cfg, "qk_norm", False) or cfg.name.startswith("qwen3"):
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _project_qkv(p: Params, cfg, x: jnp.ndarray, positions):
    B, S, D = x.shape
    H, Hkv, hd = cfg.heads, cfg.kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p: Params, cfg, x: jnp.ndarray,
                window: int = 0) -> jnp.ndarray:
    """Training / prefill-without-cache forward."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    ctx = attention_ctx(q, k, v, cfg.hd ** -0.5, window=window)
    return ctx.reshape(B, S, -1) @ p["wo"]


def gqa_cache_init(cfg, batch: int, s_max: int, window: int, dtype) -> Params:
    Hkv, hd = cfg.kv_heads, cfg.hd
    s_buf = min(window, s_max) if window else s_max
    shape = (batch, s_buf, Hkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_prefill(p: Params, cfg, x: jnp.ndarray, cache: Params,
                window: int = 0):
    """Prefill: forward + populate the cache; returns (out, cache)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    ctx = attention_ctx(q, k, v, cfg.hd ** -0.5, window=window)
    s_buf = cache["k"].shape[1]
    if S >= s_buf:       # keep last s_buf entries (ring semantics)
        cache = {"k": k[:, -s_buf:], "v": v[:, -s_buf:]}
    else:
        cache = {"k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                 "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)}
    return ctx.reshape(B, S, -1) @ p["wo"], cache


def gqa_decode(p: Params, cfg, x: jnp.ndarray, cache: Params,
               pos: jnp.ndarray, window: int = 0):
    """One-token decode with KV cache. x: (B, 1, D); pos: scalar int32."""
    B = x.shape[0]
    positions = pos[None, None]
    q, k, v = _project_qkv(p, cfg, x, positions)
    s_buf = cache["k"].shape[1]
    slot = jnp.mod(pos, s_buf) if window else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
    # positions of cache entries: ring for window, prefix for full
    idx = jnp.arange(s_buf)
    if window:
        entry_pos = jnp.where(idx <= slot, pos - slot + idx,
                              pos - slot + idx - s_buf)
        valid = entry_pos >= jnp.maximum(0, pos - window + 1)
        valid &= entry_pos >= 0
    else:
        valid = idx <= pos
    Hkv, hd = cfg.kv_heads, cfg.hd
    H = cfg.heads
    g = H // Hkv
    qg = q.reshape(B, 1, Hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, ck).astype(jnp.float32)
    scores = _scores_mask(valid[None, None, None, None, :],
                          scores * cfg.hd ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, cv).reshape(B, 1, H * hd)
    return ctx @ p["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D, H = cfg.d_model, cfg.heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], D, qr, dt),
        "q_norm": jnp.zeros((qr,), dt),
        "wq_b": dense_init(ks[1], qr, H * (dn + dr), dt),
        "wkv_a": dense_init(ks[2], D, kvr + dr, dt),
        "kv_norm": jnp.zeros((kvr,), dt),
        "wk_b": dense_init(ks[3], kvr, H * dn, dt),   # latent -> k_nope
        "wv_b": dense_init(ks[4], kvr, H * dv, dt),   # latent -> v
        "wo": dense_init(ks[5], H * dv, D, dt),
    }


def _mla_q(p, cfg, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    B, S, _ = x.shape
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = x @ p["wkv_a"]
    latent = rmsnorm(kv[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., kvr:].reshape(B, S, 1, dr), positions,
                        cfg.rope_theta)
    return latent, k_rope


def mla_forward(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Train/prefill: expand per-head K/V from the latent (standard form)."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    latent, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = (latent @ p["wk_b"]).reshape(B, S, H, dn)
    v = (latent @ p["wv_b"]).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], -1)
    ctx = attention_ctx(q, k, v, (dn + dr) ** -0.5)
    return ctx.reshape(B, S, -1) @ p["wo"]


def mla_cache_init(cfg, batch: int, s_max: int, dtype) -> Params:
    return {"latent": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype)}


def mla_prefill(p: Params, cfg, x: jnp.ndarray, cache: Params):
    B, S, _ = x.shape
    out = mla_forward(p, cfg, x)
    positions = jnp.arange(S)[None, :]
    latent, k_rope = _mla_latent(p, cfg, x, positions)
    cache = {"latent": jax.lax.dynamic_update_slice_in_dim(
                 cache["latent"], latent, 0, 1),
             "k_rope": jax.lax.dynamic_update_slice_in_dim(
                 cache["k_rope"], k_rope[:, :, 0, :], 0, 1)}
    return out, cache


def mla_decode(p: Params, cfg, x: jnp.ndarray, cache: Params,
               pos: jnp.ndarray):
    """Absorbed decode: scores/context computed in latent space.

    cache: latent (B, S, kvr), k_rope (B, S, dr).
    """
    B = x.shape[0]
    H, dn, dr, dv = cfg.heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    positions = pos[None, None]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)       # (B,1,H,dn),(B,1,H,dr)
    latent_new, k_rope_new = _mla_latent(p, cfg, x, positions)
    cache = {"latent": jax.lax.dynamic_update_slice_in_dim(
                 cache["latent"], latent_new, pos, 1),
             "k_rope": jax.lax.dynamic_update_slice_in_dim(
                 cache["k_rope"], k_rope_new[:, :, 0, :], pos, 1)}
    latent, k_rope = cache["latent"], cache["k_rope"]
    S = latent.shape[1]
    # absorb wk_b into q:  q_eff (B,H,kvr)
    wk = p["wk_b"].reshape(kvr, H, dn)
    q_eff = jnp.einsum("bhd,khd->bhk", q_nope[:, 0], wk)
    scores = (jnp.einsum("bhk,bsk->bhs", q_eff, latent) +
              jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], k_rope)
              ).astype(jnp.float32)
    scores *= (dn + dr) ** -0.5
    valid = jnp.arange(S)[None, None, :] <= pos
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(latent.dtype)
    ctx_latent = jnp.einsum("bhs,bsk->bhk", probs, latent)   # (B,H,kvr)
    wv = p["wv_b"].reshape(kvr, H, dv)
    ctx = jnp.einsum("bhk,khd->bhd", ctx_latent, wv)
    out = ctx.reshape(B, 1, H * dv) @ p["wo"]
    return out, cache
