"""Shared model building blocks (pure-functional, dict-of-arrays params)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype,
               scale: float | None = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6
            ) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + gamma.astype(jnp.float32)) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi_gate": dense_init(k1, d_model, d_ff, dtype),
            "wi_up": dense_init(k2, d_model, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d_model, dtype)}


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ p["wi_gate"])
    return (gate * (x @ p["wi_up"])) @ p["wo"]


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d_model, d_ff, dtype),
            "wo": dense_init(k2, d_ff, d_model, dtype)}


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 weights: jnp.ndarray | None = None,
                 z_loss: float = 0.0) -> jnp.ndarray:
    """Mean cross entropy in fp32; optional z-loss regularizer.

    The label log-prob is extracted with a one-hot contraction rather than
    take_along_axis: a gather along a vocab-sharded logits axis would force
    GSPMD to all-gather the full logits; the contraction keeps the vocab
    axis sharded and reduces with a cheap psum.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    if weights is None:
        return jnp.mean(loss)
    w = weights.astype(jnp.float32)
    return jnp.sum(loss * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_index(tree, i):
    """Select index i along the leading (stacked-layer) axis of every leaf."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_stack_shape(tree):
    return jax.tree.map(lambda x: x.shape, tree)


def causal_mask(s_q: int, s_k: int, q_offset: int = 0,
                window: int = 0) -> jnp.ndarray:
    """(s_q, s_k) boolean mask. window>0 => sliding window attention."""
    q_pos = jnp.arange(s_q)[:, None] + q_offset
    k_pos = jnp.arange(s_k)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    return mask
