"""Model zoo: layers, families, and the registry facade."""
from .registry import Model, build_model

__all__ = ["Model", "build_model"]
