"""Encoder-decoder model (seamless-m4t-medium backbone).

The speech frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d_model) from ``input_specs()``.
Encoder: bidirectional self-attention.  Decoder: causal self-attention +
cross-attention into the encoder output.  train_4k splits the assigned
seq_len as S_src = S_tgt = seq_len/2 (documented in DESIGN §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import (Params, dense_init, gelu_mlp, gelu_mlp_init, layernorm,
                     rmsnorm, softmax_xent, swiglu, swiglu_init, tree_index)


def _xattn_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    H, hd, D = cfg.heads, cfg.hd, cfg.d_model
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], D, H * hd, dt),
            "wk": dense_init(ks[1], D, H * hd, dt),
            "wv": dense_init(ks[2], D, H * hd, dt),
            "wo": dense_init(ks[3], H * hd, D, dt)}


def _enc_layer_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((D,), dt), "attn": attn.gqa_init(k1, cfg),
            "ln2": jnp.zeros((D,), dt),
            "mlp": gelu_mlp_init(k2, D, cfg.d_ff, dt)}


def _dec_layer_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((D,), dt), "self": attn.gqa_init(k1, cfg),
            "ln_x": jnp.zeros((D,), dt), "cross": _xattn_init(k2, cfg),
            "ln2": jnp.zeros((D,), dt),
            "mlp": gelu_mlp_init(k3, D, cfg.d_ff, dt)}


def init_encdec_params(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3 + cfg.n_enc_layers + cfg.n_dec_layers)
    enc = [_enc_layer_init(ks[3 + i], cfg) for i in range(cfg.n_enc_layers)]
    dec = [_dec_layer_init(ks[3 + cfg.n_enc_layers + i], cfg)
           for i in range(cfg.n_dec_layers)]
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": dense_init(ks[1], cfg.d_model, cfg.vocab_padded, dt),
    }


def _bidir_attn(p, cfg, x):
    """Non-causal full self-attention for the encoder."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.heads, cfg.kv_heads, cfg.hd
    positions = jnp.arange(S)[None, :]
    from .common import apply_rope
    q = apply_rope((x @ p["wq"]).reshape(B, S, H, hd), positions,
                   cfg.rope_theta)
    k = apply_rope((x @ p["wk"]).reshape(B, S, Hkv, hd), positions,
                   cfg.rope_theta)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    mask = jnp.ones((S, S), bool)
    ctx = attn.grouped_attention(q, k, v, mask, hd ** -0.5) \
        if S <= attn.DIRECT_MAX_S \
        else attn.chunked_attention(q, k, v, hd ** -0.5, causal=False)
    return ctx.reshape(B, S, -1) @ p["wo"]


def _cross_attn(p, cfg, x, enc_out):
    B, S, D = x.shape
    H, hd = cfg.heads, cfg.hd
    Sk = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"]).reshape(B, Sk, H, hd)
    v = (enc_out @ p["wv"]).reshape(B, Sk, H, hd)
    mask = jnp.ones((S, Sk), bool)
    ctx = attn.grouped_attention(q, k, v, mask, hd ** -0.5) \
        if max(S, Sk) <= attn.DIRECT_MAX_S \
        else attn.chunked_attention(q, k, v, hd ** -0.5, causal=False)
    return ctx.reshape(B, S, -1) @ p["wo"]


def _cross_attn_cached(p, cfg, x, kv_cache):
    """Decode-time cross attention against precomputed enc K/V."""
    B, S, D = x.shape
    H, hd = cfg.heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k, v = kv_cache["k"], kv_cache["v"]
    mask = jnp.ones((S, k.shape[1]), bool)
    ctx = attn.grouped_attention(q, k, v, mask, hd ** -0.5)
    return ctx.reshape(B, S, -1) @ p["wo"]


def encode(params, cfg, frame_embeds, mode: str = "scan") -> jnp.ndarray:
    from repro.distributed.sharding import shard_activations
    h = shard_activations(frame_embeds.astype(jnp.dtype(cfg.dtype)))

    def layer(h, p):
        from repro.distributed.sharding import shard_residual
        h = shard_residual(h)
        h = h + _bidir_attn(p["attn"], cfg, rmsnorm(h, p["ln1"], cfg.norm_eps))
        h = h + gelu_mlp(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
        return h, None

    if mode == "scan":
        h, _ = jax.lax.scan(layer, h, params["enc"])
    else:
        for i in range(cfg.n_enc_layers):
            h, _ = layer(h, tree_index(params["enc"], i))
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def encdec_loss(params, cfg, batch, mode: str = "scan", remat: bool = False):
    enc_out = encode(params, cfg, batch["frame_embeds"], mode)
    from repro.distributed.sharding import shard_activations
    h = shard_activations(params["embed"][batch["tokens"]])

    def layer(h, p):
        from repro.distributed.sharding import shard_residual
        h = shard_residual(h)
        h = h + attn.gqa_forward(p["self"], cfg,
                                 rmsnorm(h, p["ln1"], cfg.norm_eps))
        h = h + _cross_attn(p["cross"], cfg,
                            rmsnorm(h, p["ln_x"], cfg.norm_eps), enc_out)
        h = h + gelu_mlp(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
        return h, None

    lyr = jax.checkpoint(layer) if remat else layer
    if mode == "scan":
        h, _ = jax.lax.scan(lyr, h, params["dec"])
    else:
        for i in range(cfg.n_dec_layers):
            h, _ = lyr(h, tree_index(params["dec"], i))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    from .transformer import _mask_padded_vocab
    logits = _mask_padded_vocab(cfg, h @ params["lm_head"])
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"xent": loss}


def encdec_cache_init(cfg, batch: int, s_max: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    n, H, hd = cfg.n_dec_layers, cfg.heads, cfg.hd
    self_c = {"k": jnp.zeros((n, batch, s_max, cfg.kv_heads, hd), dt),
              "v": jnp.zeros((n, batch, s_max, cfg.kv_heads, hd), dt)}
    cross_c = {"k": jnp.zeros((n, batch, s_max, H, hd), dt),
               "v": jnp.zeros((n, batch, s_max, H, hd), dt)}
    return {"self": self_c, "cross": cross_c,
            "pos": jnp.zeros((), jnp.int32)}


def encdec_prefill(params, cfg, batch, cache, mode: str = "unroll"):
    """Encode source; precompute per-layer cross K/V; prefill decoder self-KV
    with the (short) target prefix; return (last logits, cache)."""
    enc_out = encode(params, cfg, batch["frame_embeds"], mode)
    from repro.distributed.sharding import shard_activations
    h = shard_activations(params["embed"][batch["tokens"]])
    B, S_t, _ = h.shape
    self_ks, self_vs, cross_ks, cross_vs = [], [], [], []
    for i in range(cfg.n_dec_layers):
        p = tree_index(params["dec"], i)
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        y, c = attn.gqa_prefill(p["self"], cfg, x,
                                {"k": cache["self"]["k"][i],
                                 "v": cache["self"]["v"][i]}, 0)
        h = h + y
        self_ks.append(c["k"])
        self_vs.append(c["v"])
        Sk = enc_out.shape[1]
        H, hd = cfg.heads, cfg.hd
        ck = (enc_out @ p["cross"]["wk"]).reshape(B, Sk, H, hd)
        cv = (enc_out @ p["cross"]["wv"]).reshape(B, Sk, H, hd)
        cross_ks.append(ck)
        cross_vs.append(cv)
        h = h + _cross_attn_cached(p["cross"], cfg,
                                   rmsnorm(h, p["ln_x"], cfg.norm_eps),
                                   {"k": ck, "v": cv})
        h = h + gelu_mlp(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    from .transformer import _mask_padded_vocab
    logits = _mask_padded_vocab(cfg, h[:, -1:] @ params["lm_head"])
    new_cache = {"self": {"k": jnp.stack(self_ks), "v": jnp.stack(self_vs)},
                 "cross": {"k": jnp.stack(cross_ks), "v": jnp.stack(cross_vs)},
                 "pos": jnp.asarray(S_t, jnp.int32)}
    return logits, new_cache


def encdec_decode_step(params, cfg, cache, tokens, mode: str = "unroll"):
    pos = cache["pos"]
    from repro.distributed.sharding import shard_activations
    h = shard_activations(params["embed"][tokens])
    self_ks, self_vs = [], []
    for i in range(cfg.n_dec_layers):
        p = tree_index(params["dec"], i)
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        y, c = attn.gqa_decode(p["self"], cfg, x,
                               {"k": cache["self"]["k"][i],
                                "v": cache["self"]["v"][i]}, pos, 0)
        h = h + y
        self_ks.append(c["k"])
        self_vs.append(c["v"])
        h = h + _cross_attn_cached(p["cross"], cfg,
                                   rmsnorm(h, p["ln_x"], cfg.norm_eps),
                                   {"k": cache["cross"]["k"][i],
                                    "v": cache["cross"]["v"][i]})
        h = h + gelu_mlp(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    from .transformer import _mask_padded_vocab
    logits = _mask_padded_vocab(cfg, h @ params["lm_head"])
    new_cache = {"self": {"k": jnp.stack(self_ks), "v": jnp.stack(self_vs)},
                 "cross": cache["cross"], "pos": pos + 1}
    return logits, new_cache
