"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is sort-based (production JAX style): tokens' (token, expert)
pairs are bucketed into per-expert capacity slots via a stable sort +
within-expert ranking, scattered into an (E, C, D) buffer, processed by a
batched expert matmul (einsum 'ecd,edf->ecf'), and combined back weighted
by router probabilities.  Per-expert compute therefore equals
active-tokens x capacity_factor -- the honest MoE cost (no dense-E
overcompute).  Under expert parallelism, the scatter/gather across the
token-sharded -> expert-sharded boundary is where GSPMD inserts the
all-to-all (visible in the dry-run HLO; see EXPERIMENTS §Roofline).

The Pallas grouped-matmul kernel (kernels/moe_gmm) covers the
sorted-ragged path on TPU; this module is its semantic reference at the
model level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name as _ckpt_name

from .common import Params, dense_init


# Dispatch implementation: 'auto' picks the shard_map all-to-all EP path
# under a multi-device mesh (train/prefill), falling back to the global
# scatter path (decode / single device).  The perf harness pins 'scatter'
# to measure the baseline (EXPERIMENTS §Perf).
MOE_IMPL = "auto"


def moe_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    import numpy as np
    scale = 1.0 / np.sqrt(D)
    return {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "wi_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                    * scale).astype(dt),
        "wi_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                  * scale).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
               * (1.0 / np.sqrt(F))).astype(dt),
    }


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.n_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8


def moe_apply(p: Params, cfg, x: jnp.ndarray):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Dispatches to the shard_map expert-parallel path when profitable.
    """
    if MOE_IMPL != "scatter":
        try:
            mesh = jax.sharding.get_abstract_mesh()
        except Exception:
            mesh = None
        if (mesh is not None and not mesh.empty
                and "model" in mesh.axis_names
                and dict(mesh.shape)["model"] > 1
                and x.shape[1] % dict(mesh.shape)["model"] == 0
                and x.shape[1] > 1):
            return moe_apply_a2a(p, cfg, x, mesh)
    return moe_apply_scatter(p, cfg, x)


def moe_apply_scatter(p: Params, cfg, x: jnp.ndarray):
    """Global sort + scatter dispatch (baseline; also the decode path)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, cfg)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- slotting: rank of each (token, k) within its expert --------------
    flat_e = top_e.reshape(-1)                               # (T*K,)
    order = jnp.argsort(flat_e, stable=True)                 # sorted by expert
    # rank within expert for the sorted order
    sorted_e = flat_e[order]
    seg_starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(jnp.bincount(sorted_e, length=E).astype(jnp.int32))[:-1]])
    ranks_sorted = jnp.arange(T * K, dtype=jnp.int32) - seg_starts[sorted_e]
    ranks = jnp.zeros(T * K, jnp.int32).at[order].set(ranks_sorted)
    keep = ranks < C                                         # overflow drops
    slot = jnp.where(keep, flat_e * C + ranks, E * C)        # E*C = trash row

    # --- dispatch ----------------------------------------------------------
    from repro.distributed.sharding import BATCH_AXES, maybe_shard
    ep = "model"                 # expert-parallel axis when E divides
    src = jnp.repeat(xf, K, axis=0)                          # (T*K, D)
    src = maybe_shard(src, BATCH_AXES, None)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].add(src)
    buf = buf[: E * C].reshape(E, C, D)
    # expert-major buffer: E over 'model' (EP) when divisible, else TP stays
    # inside each expert's FFN dims; capacity over the batch axes.
    buf = maybe_shard(buf, ep, BATCH_AXES, None)

    # --- expert FFN (SwiGLU), batched over experts -------------------------
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, p["wo"])
    out_buf = maybe_shard(out_buf, ep, BATCH_AXES, None)

    # --- combine -----------------------------------------------------------
    out_flat = out_buf.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(slot, E * C - 1)], 0.0)
    weighted = gathered * top_p.reshape(-1, 1).astype(x.dtype)
    out = weighted.reshape(T, K, D).sum(axis=1)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (production path)
# ---------------------------------------------------------------------------

def _local_rank_in_expert(flat_e: jnp.ndarray, E: int):
    """Rank of each (token,k) entry within its expert, computed locally."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(jnp.bincount(sorted_e, length=E).astype(jnp.int32))[:-1]])
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - seg_starts[sorted_e]
    return jnp.zeros(n, jnp.int32).at[order].set(ranks_sorted)


def moe_apply_a2a(p: Params, cfg, x: jnp.ndarray, mesh):
    """Expert parallelism over 'model' via shard_map + all_to_all.

    Tokens stay on their (pod, data, model-seq) shard; each device routes
    its local tokens, packs per-expert send buffers with LOCAL capacity,
    exchanges them with one all_to_all over the model axis (each model
    rank owns E/tp experts, padded to divisibility with -inf-routed dummy
    experts), runs the expert FFN with FSDP-gathered weights, and reverses
    the exchange.  This replaces the GSPMD-inferred resharding of the
    scatter path with the minimal collective pattern (EXPERIMENTS §Perf).
    """
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.distributed.sharding import BATCH_AXES

    sizes = dict(mesh.shape)
    tp = sizes["model"]
    dpb = tuple(a for a in BATCH_AXES if a in sizes and sizes[a] > 1) or None
    all_axes = tuple(a for a in ("pod", "data", "model") if a in sizes)
    E, K, D = cfg.n_experts, cfg.experts_per_token, cfg.d_model
    E_pad = -(-E // tp) * tp
    E_loc = E_pad // tp
    ep = E % tp == 0           # expert weights sharded over model?
    wspec_i = P("model", "data", None) if ep else P(None, "data", "model")
    wspec_o = P("model", None, "data") if ep else P(None, "model", "data")
    batch_ok = dpb is not None and x.shape[0] % math_prod(
        [sizes[a] for a in (dpb if isinstance(dpb, tuple) else (dpb,))]) == 0
    xspec = P(dpb if batch_ok else None, "model", None)

    def local(x_loc, router, wi_g, wi_u, wo):
        b, s, _ = x_loc.shape
        t = b * s
        xf = x_loc.reshape(t, D)
        logits = xf.astype(jnp.float32) @ router          # (t, E)
        if E_pad > E:
            logits = jnp.pad(logits, ((0, 0), (0, E_pad - E)),
                             constant_values=-1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        # globally-exact load-balance loss: average the per-expert vectors
        # across shards BEFORE the product (== the unsharded computation)
        me = jax.lax.pmean(jnp.mean(probs[:, :E], axis=0), all_axes)
        ce = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), 0),
            all_axes)
        aux = E * jnp.sum(me * ce)

        c_send = max(4, -(-int(t * K * cfg.capacity_factor / E_pad) // 4) * 4)
        flat_e = top_e.reshape(-1)
        ranks = _local_rank_in_expert(flat_e, E_pad)
        keep = ranks < c_send
        slot = jnp.where(keep, flat_e * c_send + ranks, E_pad * c_send)
        src = jnp.repeat(xf, K, axis=0)
        send = jnp.zeros((E_pad * c_send + 1, D), x.dtype).at[slot].add(src)
        send = send[:-1].reshape(tp, E_loc * c_send, D)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv[j] = my experts' tokens from model-peer j
        buf = recv.reshape(tp, E_loc, c_send, D).transpose(1, 0, 2, 3)
        buf = buf.reshape(E_loc, tp * c_send, D)
        buf = _ckpt_name(buf, "moe_buf")
        # FSDP gather of this rank's expert weights over 'data'
        wg = jax.lax.all_gather(wi_g, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wi_u, "data", axis=1, tiled=True)
        wod = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        if not ep:
            # E not divisible by tp: weights arrive with F sharded over
            # 'model'; gather F, pad E -> E_pad with zero (dummy) experts,
            # then slice this rank's E_loc experts by axis index.
            m_idx = jax.lax.axis_index("model")
            wg = jax.lax.all_gather(wg, "model", axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, "model", axis=2, tiled=True)
            wod = jax.lax.all_gather(wod, "model", axis=1, tiled=True)
            padE = E_pad - E
            wg = jnp.pad(wg, ((0, padE), (0, 0), (0, 0)))
            wu = jnp.pad(wu, ((0, padE), (0, 0), (0, 0)))
            wod = jnp.pad(wod, ((0, padE), (0, 0), (0, 0)))
            wg = jax.lax.dynamic_slice_in_dim(wg, m_idx * E_loc, E_loc, 0)
            wu = jax.lax.dynamic_slice_in_dim(wu, m_idx * E_loc, E_loc, 0)
            wod = jax.lax.dynamic_slice_in_dim(wod, m_idx * E_loc, E_loc, 0)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        up = jnp.einsum("ecd,edf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", gate * up, wod)
        out_buf = _ckpt_name(out_buf, "moe_out")
        back = out_buf.reshape(E_loc, tp, c_send, D).transpose(1, 0, 2, 3)
        back = back.reshape(tp, E_loc * c_send, D)
        out_send = jax.lax.all_to_all(back, "model", split_axis=0,
                                      concat_axis=0, tiled=False)
        out_flat = out_send.reshape(E_pad * c_send, D)
        gathered = jnp.where(keep[:, None],
                             out_flat[jnp.minimum(slot, E_pad * c_send - 1)],
                             0.0)
        weighted = gathered * top_p.reshape(-1, 1).astype(x.dtype)
        out = weighted.reshape(t, K, D).sum(axis=1)
        return out.reshape(b, s, D), aux

    router = p["router"]
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(xspec, P(None, None), wspec_i, wspec_i, wspec_o),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, router, p["wi_gate"], p["wi_up"], p["wo"])
    return out, aux


def math_prod(xs):
    out = 1
    for v in xs:
        out *= v
    return out


def moe_apply_dense_ref(p: Params, cfg, x: jnp.ndarray):
    """O(E * T) reference: every expert processes every token, masked.

    Used only in tests to validate the dispatch path (no capacity drops
    when capacity_factor is large enough).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(-1, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    gate = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["wi_gate"]))
    up = jnp.einsum("td,edf->tef", xf, p["wi_up"])
    every = jnp.einsum("tef,efd->ted", gate * up, p["wo"])   # (T, E, D)
    mask = jnp.zeros((xf.shape[0], E), jnp.float32)
    mask = jax.vmap(lambda m, e, pr: m.at[e].add(pr))(mask, top_e, top_p)
    out = jnp.einsum("ted,te->td", every, mask.astype(x.dtype))
    return out.reshape(B, S, D)
