"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Temporal mixing:  y = W_out( GeLU(W_gate x) * RGLRU(conv1d(W_in x)) )
RG-LRU cell:      r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
                  a_t = exp(-c * softplus(Lambda) * r_t)          (c = 8)
                  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence runs as a jax.lax.associative_scan (log-depth on
TPU); the Pallas chunked kernel (kernels/linear_scan) implements the same
a/b recurrence for the hot path and is validated against this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import Params, dense_init

RGLRU_C = 8.0


def rglru_init(key, cfg) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D, R, W = cfg.d_model, cfg.lru_width, cfg.conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a in [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (R,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * RGLRU_C)))   # softplus^-1
    return {
        "w_in": dense_init(ks[1], D, R, dt),
        "w_gate": dense_init(ks[2], D, R, dt),
        "conv_w": (jax.random.normal(ks[3], (W, R), jnp.float32)
                   / np.sqrt(W)).astype(dt),
        "w_a": dense_init(ks[4], R, R, dt),
        "w_x": dense_init(ks[5], R, R, dt),
        "lambda": lam,                       # (R,) fp32
        "w_out": dense_init(jax.random.fold_in(key, 7), R, D, dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv over time. x: (B,S,R), w: (W,R).

    state: (B, W-1, R) previous inputs for decode; returns (y, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)             # (B, S+W-1, R)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(pad)
    return y, new_state


def _rglru_coeffs(p: Params, cfg, u: jnp.ndarray):
    """u: conv output (B,S,R) -> per-step (a, b) of h = a*h + b."""
    r = jax.nn.sigmoid((u @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_x"]).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return a, b


def linear_recurrence(a: jnp.ndarray, b: jnp.ndarray,
                      h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t along axis 1 via associative scan (fp32)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_forward(p: Params, cfg, x: jnp.ndarray,
                  use_kernel: bool = False) -> jnp.ndarray:
    """Full temporal-mix branch for train/prefill. x: (B, S, D)."""
    u = x @ p["w_in"]
    u, _ = _causal_conv(u, p["conv_w"])
    a, b = _rglru_coeffs(p, cfg, u)
    if use_kernel:
        from repro.kernels.linear_scan import ops as ls_ops
        h = ls_ops.linear_scan(a, b)
    else:
        h = linear_recurrence(a, b)
    h = h.astype(x.dtype)
    gate = jax.nn.gelu(x @ p["w_gate"])
    return (gate * h) @ p["w_out"]


def rglru_cache_init(cfg, batch: int, dtype) -> Params:
    R, W = cfg.lru_width, cfg.conv_width
    return {"h": jnp.zeros((batch, R), jnp.float32),
            "conv": jnp.zeros((batch, W - 1, R), dtype)}


def rglru_decode(p: Params, cfg, x: jnp.ndarray, cache: Params):
    """Single-step decode. x: (B, 1, D)."""
    u = x @ p["w_in"]
    u, conv_state = _causal_conv(u, p["conv_w"], cache["conv"])
    a, b = _rglru_coeffs(p, cfg, u)                     # (B,1,R)
    h = a[:, 0] * cache["h"] + b[:, 0]
    gate = jax.nn.gelu(x @ p["w_gate"])
    out = (gate * h[:, None].astype(x.dtype)) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}
