"""The single experiment entry point: spec in, reports out.

``run_experiment`` compiles the spec (``repro.experiments.plan``),
consults the content-addressed store, and -- on a miss or ``force`` --
executes every scheme task through ``Scheme.mc_grid`` on the resolved
sampler backend.  Multi-device specs (``devices > 1`` on the jax /
pallas backends) run under ``repro.core.samplers.grid_sharding``: the
scenario x trials batch rows are split across a 1-D device mesh with
``shard_map``, one independent round pipeline per device.  The numpy
backend always runs single-device: it is the bit-exact oracle every
other configuration is validated against.

Seed discipline: each task draws from its own fresh
``default_rng(task.seed)``, so per-task numbers are independent of task
order and of which other tasks the spec carries -- exactly the figure
drivers' historical behaviour, which is what makes the fig5/6/7 rewrite
seed-for-seed bit-identical on numpy.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import platform
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.samplers import grid_sharding
from repro.core.schemes import MCReport, get_scheme, mc_grid_panel

from .plan import Plan, compile_plan
from .spec import ExperimentSpec
from .store import ResultsStore

RESULT_VERSION = 1

# opt-in persistent jax compilation cache: point this env var at a
# directory and every jit trace is written through to disk, so the
# second process (CI rerun, warm benchmark) skips XLA compilation
JAX_CACHE_ENV = "REPRO_JAX_CACHE_DIR"


def _maybe_enable_jax_compilation_cache() -> Optional[str]:
    """Enable jax's persistent compilation cache when ``REPRO_JAX_CACHE_DIR``
    is set (idempotent; returns the directory, or None when off).  Only
    touches jax config -- never imports jax when the knob is unset."""
    cache_dir = os.environ.get(JAX_CACHE_ENV)
    if not cache_dir:
        return None
    import jax
    if jax.config.jax_compilation_cache_dir != cache_dir:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # cache every trace, however small/fast-to-compile
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    return cache_dir


@dataclasses.dataclass
class ExperimentResult:
    """Everything one experiment run produced, serializable as stored."""

    spec: ExperimentSpec              # resolved: backend/devices concrete
    spec_hash: str
    reports: Dict[str, List[MCReport]]    # task key -> one row per point
    env: Dict[str, Any]
    wall_s: float
    cache_hit: bool = False           # set by run_experiment on a store hit

    def report(self, key: str) -> List[MCReport]:
        return self.reports[key]

    def keys(self) -> List[str]:
        return list(self.reports)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": RESULT_VERSION,
            "spec": self.spec.to_dict(),
            "spec_hash": self.spec_hash,
            "reports": {k: [r.to_dict() for r in rows]
                        for k, rows in self.reports.items()},
            "env": dict(self.env),
            "wall_s": round(float(self.wall_s), 4),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentResult":
        return cls(spec=ExperimentSpec.from_dict(d["spec"]),
                   spec_hash=d["spec_hash"],
                   reports={k: [MCReport.from_dict(r) for r in rows]
                            for k, rows in d["reports"].items()},
                   env=dict(d.get("env", {})),
                   wall_s=float(d.get("wall_s", 0.0)))


def _environment(plan: Plan) -> Dict[str, Any]:
    env: Dict[str, Any] = {
        "numpy": np.__version__,
        "python": platform.python_version(),
    }
    if plan.backend in ("jax", "pallas"):
        import jax
        env["jax"] = jax.__version__
        env["jax_devices"] = len(jax.devices())
        env["jax_platform"] = jax.default_backend()
        if os.environ.get(JAX_CACHE_ENV):
            env["jax_compilation_cache"] = os.environ[JAX_CACHE_ENV]
    return env


def _execute_serving(plan: Plan) -> Dict[str, List[MCReport]]:
    """Serving specs: every scheme task becomes a dispatch policy run
    through the slotted queueing engine -- one report row per (grid
    point x offered load) instead of per grid point.  The engine is the
    plan's resolved serving backend (``SERVING_BACKENDS``): the numpy
    oracle loop runs single-device; the jax scan engine stacks the
    (load x trial) rows and, at ``devices > 1``, splits them over the
    1-D grid mesh exactly like the batch MC executor does."""
    from repro.serving import run_serving_grid
    shard = (grid_sharding(plan.devices) if plan.devices > 1
             else contextlib.nullcontext())
    reports: Dict[str, List[MCReport]] = {}
    with shard:
        for task in plan.tasks:
            reports[task.key] = run_serving_grid(
                task.scheme, task.params_dict, plan.het_specs,
                plan.spec.serving, plan.spec.N, plan.spec.trials,
                task.seed, rate_schedules=plan.rate_schedules)
    return reports


def _execute_live(plan: Plan) -> Dict[str, List[MCReport]]:
    """Live specs: every scheme task executes through the asyncio
    control plane (``repro.control``) -- real transport round-trips,
    real matmul shards, ``trials`` episodes per grid point, measured
    ``T_comp`` plus the telemetry timeline in each report's
    ``extra["control_plane"]``."""
    from repro.control import run_live_grid
    reports: Dict[str, List[MCReport]] = {}
    for task in plan.tasks:
        reports[task.key] = run_live_grid(
            task.scheme, task.params_dict, plan.het_specs,
            plan.spec.N, plan.spec.live, plan.spec.trials, task.seed,
            rate_schedules=plan.rate_schedules)
    return reports


def _execute_training(plan: Plan) -> Dict[str, List[MCReport]]:
    """Training specs: every scheme task becomes an epoch-assignment
    policy over real gradients (``repro.hettrain``) -- the batched scan
    engine computes one shared optimizer trajectory, each policy's
    scheduler moves virtual wall-clock, one report row per grid point
    with the loss curve in ``extra["training"]``."""
    from repro.hettrain.runner import run_training_grid
    reports: Dict[str, List[MCReport]] = {}
    for task in plan.tasks:
        reports[task.key] = run_training_grid(
            task.scheme, task.params_dict, plan.het_specs,
            plan.spec.training, plan.spec.N, plan.spec.trials, task.seed,
            rate_schedules=plan.rate_schedules)
    return reports


def execute_plan(plan: Plan) -> ExperimentResult:
    """Run a compiled plan (no store interaction)."""
    spec = plan.spec
    t0 = time.perf_counter()
    if plan.backend in ("jax", "pallas"):
        _maybe_enable_jax_compilation_cache()
    if spec.execution == "live":
        reports = _execute_live(plan)
        return ExperimentResult(spec=spec, spec_hash=plan.spec_hash,
                                reports=reports, env=_environment(plan),
                                wall_s=time.perf_counter() - t0)
    if spec.serving is not None:
        reports = _execute_serving(plan)
        return ExperimentResult(spec=spec, spec_hash=plan.spec_hash,
                                reports=reports, env=_environment(plan),
                                wall_s=time.perf_counter() - t0)
    if spec.training is not None:
        reports = _execute_training(plan)
        return ExperimentResult(spec=spec, spec_hash=plan.spec_hash,
                                reports=reports, env=_environment(plan),
                                wall_s=time.perf_counter() - t0)
    reports: Dict[str, List[MCReport]] = {}
    if spec.panel == "fused":
        # fused whole-panel dispatch: the WE known/unknown pair becomes
        # ONE engine call; every other task keeps its own per-task
        # stream (the rng mapping), bit-identical to per_scheme
        schemes = {t.key: get_scheme(t.scheme, **t.params_dict)
                   for t in plan.tasks}
        rngs = {t.key: np.random.default_rng(t.seed) for t in plan.tasks}
        shard = (grid_sharding(plan.devices) if plan.devices > 1
                 else contextlib.nullcontext())
        with shard:
            reports = mc_grid_panel(schemes, plan.het_specs, spec.N,
                                    spec.trials, rngs,
                                    backend=plan.backend,
                                    rate_schedule=plan.rate_schedules)
        if plan.rate_schedules is not None:
            for key, sch in schemes.items():
                if not sch.supports_rate_schedule:
                    for rep in reports[key]:
                        rep.extra["nominal_rates_only"] = 1
        return ExperimentResult(spec=spec, spec_hash=plan.spec_hash,
                                reports=reports, env=_environment(plan),
                                wall_s=time.perf_counter() - t0)
    shard = (grid_sharding(plan.devices) if plan.devices > 1
             else contextlib.nullcontext())
    with shard:
        for task in plan.tasks:
            scheme = get_scheme(task.scheme, **task.params_dict)
            kwargs = {}
            if (plan.rate_schedules is not None
                    and scheme.supports_rate_schedule):
                # drifting / trace-corpus scenarios: the exchange-round
                # engines follow the schedule; single-shot schemes run
                # at the nominal (round-0 / window-mean) rates
                kwargs["rate_schedule"] = plan.rate_schedules
            reports[task.key] = scheme.mc_grid(
                plan.het_specs, spec.N, trials=spec.trials,
                rng=np.random.default_rng(task.seed),
                backend=plan.backend, **kwargs)
            if plan.rate_schedules is not None and not kwargs:
                # the grid drifts but this scheme cannot follow it:
                # stamp the rows so stored results (and the CLI table)
                # never read as if the scheme ran under the drift
                for rep in reports[task.key]:
                    rep.extra["nominal_rates_only"] = 1
    return ExperimentResult(spec=spec, spec_hash=plan.spec_hash,
                            reports=reports, env=_environment(plan),
                            wall_s=time.perf_counter() - t0)


def run_experiment(spec: ExperimentSpec,
                   store: Optional[ResultsStore] = None,
                   force: bool = False) -> ExperimentResult:
    """Compile, consult the store, execute on miss, persist.

    ``force=True`` recomputes even on a hit and refreshes the stored
    entry -- what the benchmark harness uses so claim validation always
    reflects fresh numbers while still writing through the store.
    """
    plan = compile_plan(spec)
    if store is not None and not force:
        cached = store.get(plan.spec)
        if cached is not None:
            cached.cache_hit = True
            return cached
    result = execute_plan(plan)
    if store is not None:
        store.put(result)
    return result


__all__ = ["RESULT_VERSION", "JAX_CACHE_ENV", "ExperimentResult",
           "execute_plan", "run_experiment"]
