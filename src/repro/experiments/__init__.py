"""Declarative experiment API: spec -> plan -> engine -> store.

The one-stop surface for the paper's (and related work's) study shape --
schemes x scenario grid x N x trials -- with multi-device sharded
execution and a content-addressed results store:

    from repro.experiments import (ExperimentSpec, ScenarioGrid,
                                   scheme_spec, run_experiment,
                                   default_store)

    spec = ExperimentSpec(
        name="demo",
        grid=ScenarioGrid(K=50, points=[(mu, mu * mu / 6, int(mu))
                                        for mu in (10.0, 50.0)]),
        schemes=(scheme_spec("work_exchange"), scheme_spec("hedged")),
        N=1_000_000, trials=100, seed=1234,
        backend="jax", devices="auto")

    result = run_experiment(spec, store=default_store())
    result.report("work_exchange")[0].t_comp

Module map:
    spec.py    -- ExperimentSpec / ScenarioGrid / SchemeSpec (JSON + hash)
    plan.py    -- compile_plan: resolve backend/devices, validate tasks
    engine.py  -- run_experiment / execute_plan (sharded mc_grid dispatch)
    store.py   -- ResultsStore: results/store/<spec-hash>.json
    __main__   -- CLI: python -m repro.experiments [spec.json | --demo |
                  ls | compare <hash-a> <hash-b>]

The scenario axis (``grid=``) is pluggable: any family registered in
``repro.scenarios.SCENARIO_REGISTRY`` (uniform_random / explicit /
trace_corpus / drifting / hcmm_sweep) -- ``ScenarioGrid`` remains the
PR-4 constructor facade for the first two.

The arrival axis is pluggable too: ``ExperimentSpec(serving=
ServingConfig(loads=(0.5, 0.8, 0.95)))`` sweeps offered load through the
streaming-arrival engine (``repro.serving``), one report row per
(grid point x load) with latency percentiles in ``extra``.
"""
from repro.scenarios import (SCENARIO_REGISTRY, ScenarioFamily, get_family,
                             list_families)
from repro.serving import ServingConfig

from .engine import (JAX_CACHE_ENV, ExperimentResult, execute_plan,
                     run_experiment)
from .plan import Plan, SHARDED_BACKENDS, Task, compile_plan
from .spec import (SPEC_VERSION, ExperimentSpec, ScenarioGrid, SchemeSpec,
                   scheme_spec)
from .store import DEFAULT_STORE_ROOT, ResultsStore, default_store

__all__ = [
    "SPEC_VERSION", "ExperimentSpec", "ScenarioGrid", "SchemeSpec",
    "scheme_spec", "ServingConfig",
    "SCENARIO_REGISTRY", "ScenarioFamily", "get_family", "list_families",
    "Plan", "Task", "SHARDED_BACKENDS", "compile_plan",
    "ExperimentResult", "execute_plan", "run_experiment", "JAX_CACHE_ENV",
    "DEFAULT_STORE_ROOT", "ResultsStore", "default_store",
]
