"""CLI for the declarative experiment API.

    # run a spec file end-to-end through the store
    python -m repro.experiments path/to/spec.json

    # built-in quick demo spec (what the experiments-smoke CI job runs)
    python -m repro.experiments --demo quick

    # sharded execution on the jax backend over 4 devices
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.experiments --demo quick --backend jax --devices 4

    # prove the cache: second run must be a content-address hit
    python -m repro.experiments --demo quick --check-cache

Exit codes: 0 ok, 1 bad spec / failed --check-cache.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .engine import ExperimentResult, run_experiment
from .spec import ExperimentSpec, ScenarioGrid, scheme_spec
from .store import ResultsStore, default_store


def demo_spec(kind: str) -> ExperimentSpec:
    if kind != "quick":
        raise SystemExit(f"unknown demo {kind!r}; have: quick")
    return ExperimentSpec(
        name="demo-quick",
        grid=ScenarioGrid(K=16, points=[(mu, mu * mu / 6, int(mu))
                                        for mu in (10.0, 30.0)]),
        schemes=(scheme_spec("work_exchange"),
                 scheme_spec("work_exchange_unknown"),
                 scheme_spec("hedged"),
                 scheme_spec("mds", opt_trials=16)),
        N=20_000, trials=64, seed=1234)


def show(result: ExperimentResult, store: ResultsStore) -> None:
    spec = result.spec
    status = "cache HIT" if result.cache_hit else "computed"
    print(f"experiment {spec.name!r}: backend={spec.backend} "
          f"devices={spec.devices} N={spec.N} trials={spec.trials} "
          f"grid={len(spec.grid)} points")
    print(f"  spec hash {result.spec_hash}")
    print(f"  {status} in {result.wall_s:.3f}s -> "
          f"{store.path_for(result.spec_hash)}")
    for key, rows in result.reports.items():
        for g, rep in enumerate(rows):
            extra = "".join(f" {k}={v:g}" for k, v in rep.extra.items()
                            if isinstance(v, (int, float)))
            print(f"  {key:24s} point {g}: T_comp={rep.t_comp:10.4f} "
                  f"+- {rep.t_comp_std:8.4f}  I={rep.iterations:6.2f}  "
                  f"N_comm={rep.n_comm:10.1f}{extra}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="run a declarative experiment spec through the "
                    "content-addressed results store")
    ap.add_argument("spec", nargs="?", help="path to an ExperimentSpec "
                                            "JSON file")
    ap.add_argument("--demo", help="built-in demo spec (quick)")
    ap.add_argument("--backend", help="override the sampler backend")
    ap.add_argument("--devices", help="override the device count "
                                      "(int or 'auto')")
    ap.add_argument("--trials", type=int, help="override the trial budget")
    ap.add_argument("--n", type=int, help="override N (work units)")
    ap.add_argument("--store", default=None,
                    help="store root (default results/store)")
    ap.add_argument("--force", action="store_true",
                    help="recompute even on a store hit")
    ap.add_argument("--check-cache", action="store_true",
                    help="run twice; fail unless the second run is a "
                         "content-address hit")
    args = ap.parse_args(argv)

    if bool(args.spec) == bool(args.demo):
        ap.error("give exactly one of: a spec file, or --demo")
    if args.spec:
        spec = ExperimentSpec.from_json(Path(args.spec).read_text())
    else:
        spec = demo_spec(args.demo)

    overrides = {}
    if args.backend:
        overrides["backend"] = args.backend
    if args.devices:
        overrides["devices"] = (args.devices if args.devices == "auto"
                                else int(args.devices))
    if args.trials:
        overrides["trials"] = args.trials
    if args.n:
        overrides["N"] = args.n
    if overrides:
        spec = spec.replace(**overrides)

    store = ResultsStore(args.store) if args.store else default_store()
    result = run_experiment(spec, store=store, force=args.force)
    show(result, store)

    if args.check_cache:
        again = run_experiment(spec, store=store)
        if not again.cache_hit:
            print("check-cache: FAILED -- second run was not a store hit",
                  file=sys.stderr)
            return 1
        if again.to_dict()["reports"] != result.to_dict()["reports"]:
            print("check-cache: FAILED -- stored reports differ from the "
                  "computed run", file=sys.stderr)
            return 1
        print("check-cache: OK (second run was a content-address hit with "
              "identical reports)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
