"""CLI for the declarative experiment API.

    # run a spec file end-to-end through the store
    python -m repro.experiments path/to/spec.json

    # built-in demo specs (quick is what the experiments-smoke CI job
    # runs; trace / drifting are the scenarios-smoke job's specs)
    python -m repro.experiments --demo quick
    python -m repro.experiments --demo drifting --backend jax
    python -m repro.experiments --demo trace
    python -m repro.experiments --demo hcmm

    # sharded execution on the jax backend over 4 devices
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.experiments --demo quick --backend jax --devices 4

    # prove the cache: second run must be a content-address hit
    python -m repro.experiments --demo quick --check-cache

    # query the store: one line per entry (hash, name, family, schemes,
    # backend, devices, wall)
    python -m repro.experiments ls

    # compare two stored results: per-scheme T_comp deltas in combined
    # standard errors (hash prefixes resolve when unambiguous)
    python -m repro.experiments compare 825d75a6 07eaead1

Exit codes: 0 ok, 1 bad spec / failed --check-cache / unknown hash.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

from repro.scenarios import (DriftingScenario, ExplicitScenario,
                             HCMMSweepScenario)
from repro.scenarios.traces import DEFAULT_CORPUS, TraceCorpusScenario

from repro.control import LiveConfig
from repro.serving import ServingConfig

from .engine import ExperimentResult, run_experiment
from .spec import ExperimentSpec, ScenarioGrid, scheme_spec
from .store import ResultsStore, default_store

DEMOS = ("quick", "drifting", "trace", "hcmm", "serving", "serving-trace",
         "live", "live-fault", "train")


def demo_spec(kind: str) -> ExperimentSpec:
    if kind == "quick":
        return ExperimentSpec(
            name="demo-quick",
            grid=ScenarioGrid(K=16, points=[(mu, mu * mu / 6, int(mu))
                                            for mu in (10.0, 30.0)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     scheme_spec("hedged"),
                     scheme_spec("mds", opt_trials=16)),
            N=20_000, trials=64, seed=1234)
    if kind == "drifting":
        # rates move underneath the online estimator: the claim the
        # drifting family exists to stress
        return ExperimentSpec(
            name="demo-drifting",
            grid=DriftingScenario(K=16,
                                  points=[(20.0, 20.0 ** 2 / 6, 1),
                                          (50.0, 50.0 ** 2 / 6, 2)],
                                  kind="ar1", rounds=24),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     scheme_spec("hedged")),
            N=20_000, trials=64, seed=1234)
    if kind == "trace":
        grid = TraceCorpusScenario(corpus=DEFAULT_CORPUS, K=16,
                                   windows=((0, 0), (24, 16)), epochs=12)
        return ExperimentSpec(
            name="demo-trace",
            grid=grid,
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     # replay window 0's exact trace through the
                     # id-aware master protocol
                     scheme_spec("trace_replay", key="trace_replay@w0",
                                 **grid.trace_replay_params(0))),
            N=8_000, trials=8, seed=1234)
    if kind == "serving":
        # streaming arrivals: the same schemes as dispatch policies,
        # swept over offered load (the serving-smoke CI spec)
        return ExperimentSpec(
            name="demo-serving",
            grid=ScenarioGrid(K=8, points=[(20.0, 20.0 ** 2 / 6, 5)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("fixed"),
                     scheme_spec("het_mds")),
            N=100, trials=8, seed=1234,
            serving=ServingConfig(loads=(0.6, 0.9), slots=600,
                                  deadline_slo=4.0))
    if kind == "serving-trace":
        # measured rates AND measured demand: the trace corpus drives
        # both the per-slot service rates (scenario schedule) and the
        # arrival intensity (trace arrival process)
        grid = TraceCorpusScenario(corpus=DEFAULT_CORPUS, K=16,
                                   windows=((0, 0),), epochs=12)
        return ExperimentSpec(
            name="demo-serving-trace",
            grid=grid,
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown")),
            N=100, trials=8, seed=1234,
            serving=ServingConfig(loads=(0.7,), arrival="trace",
                                  arrival_params={"epochs": 12},
                                  slots=600))
    if kind == "live":
        # the same schemes EXECUTED through the asyncio control plane:
        # real transport messages, real matmul shards, measured T_comp
        # (the control-smoke CI spec; mds pins L so ceil(N/m) == L and
        # the live size-cover rule equals the L-th order statistic)
        return ExperimentSpec(
            name="demo-live",
            grid=ScenarioGrid(K=4, points=[(4.0, 4.0 ** 2 / 6, 4)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     scheme_spec("fixed"),
                     scheme_spec("mds", L=3),
                     scheme_spec("hedged")),
            N=2_000, trials=4, seed=1234,
            execution="live", live=LiveConfig(target_wall_s=0.5))
    if kind == "live-fault":
        # kill worker 0 a quarter of the way in: the episode must
        # complete degraded (leftovers reassigned), not hang
        return ExperimentSpec(
            name="demo-live-fault",
            grid=ScenarioGrid(K=4, points=[(4.0, 4.0 ** 2 / 6, 4)]),
            schemes=(scheme_spec("work_exchange"),),
            N=2_000, trials=2, seed=1234,
            execution="live",
            live=LiveConfig(target_wall_s=0.5, timeout_s=0.1, retries=1,
                            kill_worker=0, kill_after_frac=0.25))
    if kind == "train":
        # every scheme as an epoch-assignment policy over real
        # gradients: one shared trajectory (bit-identical loss curves),
        # per-policy virtual wall-clock (the hettrain-smoke CI spec)
        from repro.hettrain import TrainConfig
        return ExperimentSpec(
            name="demo-train",
            grid=ScenarioGrid(K=4, points=[(4.0, 4.0 ** 2 / 6, 11)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     scheme_spec("uniform"),
                     scheme_spec("fixed"),
                     scheme_spec("gradient_coded")),
            N=16, trials=3, seed=1234,
            training=TrainConfig(steps=6))
    raise SystemExit(f"unknown demo {kind!r}; have: {', '.join(DEMOS)}")


def hcmm_demo_specs():
    """The hcmm sweep as one experiment PER operating point: the axis
    of the family is per-worker load, so each point must run at its own
    ``point_N(g)`` -- the N its redundancy was optimized for.  (A single
    ExperimentSpec carries one N, which would flatten the load axis.)
    """
    grid = HCMMSweepScenario(K=16, mu=30.0, sigma2=30.0 ** 2 / 6,
                             seed=3, loads=(4, 32, 256), opt_trials=96)
    specs = []
    for g, (het, n_g, r_star) in enumerate(grid.operating_points()):
        specs.append(ExperimentSpec(
            name=f"demo-hcmm-load{grid.loads[g]}",
            grid=ExplicitScenario(explicit=(het,)),
            schemes=(scheme_spec("fixed"),
                     scheme_spec("work_exchange"),
                     scheme_spec("het_mds", key=f"het_mds@r{r_star:g}",
                                 redundancy=r_star)),
            N=n_g, trials=256, seed=1234))
    return specs


def show(result: ExperimentResult, store: ResultsStore) -> None:
    spec = result.spec
    status = "cache HIT" if result.cache_hit else "computed"
    print(f"experiment {spec.name!r}: backend={spec.backend} "
          f"devices={spec.devices} N={spec.N} trials={spec.trials} "
          f"grid={len(spec.grid)} points ({spec.grid.family})")
    print(f"  spec hash {result.spec_hash}")
    print(f"  {status} in {result.wall_s:.3f}s -> "
          f"{store.path_for(result.spec_hash)}")
    for key, rows in result.reports.items():
        for g, rep in enumerate(rows):
            if rep.extra.get("serving"):
                # serving rows: the latency surface, not batch T_comp
                slo = (f" slo_miss={rep.extra['slo_miss_rate']:.3f}"
                       if "slo_miss_rate" in rep.extra else "")
                if rep.extra.get("latency_censored"):
                    # zero completions: percentiles are horizon bounds
                    slo += "  [CENSORED: latency >= horizon]"
                elif rep.extra.get("censored_frac"):
                    slo += (f"  [censored_frac="
                            f"{rep.extra['censored_frac']:.2f}]")
                print(f"  {key:24s} pt {rep.extra.get('grid_point', 0):g} "
                      f"load {rep.extra['offered_load']:g}: "
                      f"sojourn={rep.t_comp:8.4f} "
                      f"p50={rep.extra['p50']:.4f} "
                      f"p99={rep.extra['p99']:.4f} "
                      f"thru={rep.extra['throughput_jobs']:.2f}/s "
                      f"reject={rep.extra['reject_rate']:.3f}{slo}")
                continue
            tr = rep.extra.get("training")
            if tr:
                tgt = ("" if "wall_to_target" not in tr else
                       (f" wall_to_target={tr['wall_to_target']:.3f}"
                        f"@{tr['steps_to_target']} steps"
                        if tr["steps_to_target"] > 0
                        else " target NOT reached"))
                nom = (" [nominal rates]"
                       if rep.extra.get("nominal_rates_only") else "")
                print(f"  {key:24s} point {g}: wall={rep.t_comp:10.4f} "
                      f"+- {rep.t_comp_std:8.4f}  "
                      f"loss {tr['loss_curve'][0]:.4f}->"
                      f"{tr['final_loss']:.4f} in {tr['steps']} steps  "
                      f"wait={tr['straggler_wait_frac']:.1%}"
                      f"{tgt}{nom}")
                continue
            cp = rep.extra.get("control_plane")
            if cp:
                lost = (f" lost={cp['workers_lost']}"
                        if cp["workers_lost"] else "")
                print(f"  {key:24s} point {g}: T_comp={rep.t_comp:10.4f} "
                      f"+- {rep.t_comp_std:8.4f}  I={rep.iterations:6.2f}  "
                      f"N_comm={rep.n_comm:10.1f}  "
                      f"live[{cp['transport']}] "
                      f"wall={cp['episode_wall_s']:.3f}s "
                      f"coord={cp['coordination_frac']:.1%}{lost}")
                continue
            extra = "".join(f" {k}={v:g}" for k, v in rep.extra.items()
                            if isinstance(v, (int, float)))
            print(f"  {key:24s} point {g}: T_comp={rep.t_comp:10.4f} "
                  f"+- {rep.t_comp_std:8.4f}  I={rep.iterations:6.2f}  "
                  f"N_comm={rep.n_comm:10.1f}{extra}")


# ---------------------------------------------------------------------------
# store query commands (ls / compare)
# ---------------------------------------------------------------------------

def _store_arg(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--store", default=None,
                    help="store root (default results/store)")


def _open_store(args) -> ResultsStore:
    return ResultsStore(args.store) if args.store else default_store()


def _resolve_hash(store: ResultsStore, prefix: str) -> str:
    """Resolve a (possibly shortened) spec hash against the store."""
    matches = [h for h in store.entries() if h.startswith(prefix)]
    if len(matches) == 1:
        return matches[0]
    if not matches:
        raise SystemExit(f"no store entry matches {prefix!r} under "
                         f"{store.root} ({len(store.entries())} entries; "
                         f"try 'ls')")
    raise SystemExit(f"ambiguous hash prefix {prefix!r}: "
                     f"{[m[:16] for m in matches]}")


def cmd_ls(argv) -> int:
    """One line per store entry: the spec's identity at a glance."""
    ap = argparse.ArgumentParser(prog="python -m repro.experiments ls",
                                 description="list results-store entries")
    _store_arg(ap)
    args = ap.parse_args(argv)
    store = _open_store(args)
    entries = store.entries()
    if not entries:
        print(f"(no entries under {store.root})")
        return 0
    print(f"{'hash':16s}  {'name':14s} {'family':14s} {'grid':>4s} "
          f"{'schemes':28s} {'backend':7s} {'dev':>3s} {'N':>9s} "
          f"{'trials':>6s} {'wall_s':>8s}")
    for h in entries:
        result = store.get(h)
        if result is None:
            print(f"{h[:16]}  (unreadable or mismatched entry)")
            continue
        spec = result.spec
        keys = list(result.reports)
        shown = ",".join(keys[:3]) + ("..." if len(keys) > 3 else "")
        print(f"{h[:16]}  {spec.name:14s} {spec.grid.family:14s} "
              f"{len(spec.grid):4d} {shown:28s} {str(spec.backend):7s} "
              f"{spec.devices!s:>3s} {spec.N:9d} {spec.trials:6d} "
              f"{result.wall_s:8.3f}")
        if spec.serving is not None:
            # serving entries: per-scheme p99 at the heaviest swept load
            top = max(spec.serving.loads)
            parts = []
            for key, rows in result.reports.items():
                vals = [r.extra["p99"] for r in rows
                        if "p99" in r.extra
                        and r.extra.get("offered_load") == top]
                if vals:
                    parts.append(f"{key}={sum(vals) / len(vals):.3g}")
            if parts:
                print(f"{'':18s}serving p99@load={top:g}: "
                      + "  ".join(parts))
        if spec.training is not None:
            # training entries: per-scheme final loss (identical across
            # schemes by work conservation) and mean total wall
            parts = [f"{key}={rows[0].t_comp:.3g}"
                     for key, rows in result.reports.items() if rows]
            fl = next((rows[0].extra["training"]["final_loss"]
                       for rows in result.reports.values()
                       if rows and "training" in rows[0].extra), None)
            if parts:
                tail = "" if fl is None else f"  final_loss={fl:.4f}"
                print(f"{'':18s}train wall: " + "  ".join(parts) + tail)
    return 0


def cmd_compare(argv) -> int:
    """Per-scheme T_comp deltas between two stored results, in combined
    standard errors -- the store-native answer to "did this change
    matter at Monte-Carlo tolerance?"."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments compare",
        description="compare two stored results (T_comp deltas in SE "
                    "units)")
    ap.add_argument("hash_a")
    ap.add_argument("hash_b")
    _store_arg(ap)
    args = ap.parse_args(argv)
    store = _open_store(args)
    results = {}
    for tag, prefix in (("a", args.hash_a), ("b", args.hash_b)):
        h = _resolve_hash(store, prefix)
        results[tag] = store.get(h)
        if results[tag] is None:
            raise SystemExit(f"entry {h[:16]} is unreadable or mismatched")
    a, b = results["a"], results["b"]
    print(f"a: {a.spec_hash[:16]}  {a.spec.name!r} "
          f"({a.spec.grid.family}, {len(a.spec.grid)} points, "
          f"N={a.spec.N}, trials={a.spec.trials}, {a.spec.backend})")
    print(f"b: {b.spec_hash[:16]}  {b.spec.name!r} "
          f"({b.spec.grid.family}, {len(b.spec.grid)} points, "
          f"N={b.spec.N}, trials={b.spec.trials}, {b.spec.backend})")
    shared = [k for k in a.reports if k in b.reports]
    for only, r in (("a", a), ("b", b)):
        extra = [k for k in r.reports if k not in shared]
        if extra:
            print(f"  (only in {only}: {', '.join(extra)})")
    if not shared:
        print("no shared scheme keys -- nothing to compare")
        return 0
    print(f"  {'scheme':24s} {'pt':>3s} {'T_comp a':>12s} {'T_comp b':>12s}"
          f" {'delta':>12s} {'delta/SE':>9s}")
    worst = 0.0
    zero_se_diffs = 0
    for key in shared:
        rows_a, rows_b = a.report(key), b.report(key)
        for g, (ra, rb) in enumerate(zip(rows_a, rows_b)):
            se = float(np.hypot(ra.t_comp_std / np.sqrt(max(ra.trials, 1)),
                                rb.t_comp_std / np.sqrt(max(rb.trials, 1))))
            delta = rb.t_comp - ra.t_comp
            if se > 0:
                in_se = abs(delta) / se
                worst = max(worst, in_se)
                label = f"{in_se:9.1f}"
                mark = "" if in_se < 6 else "  <-- >6 SE"
            elif delta == 0:
                label = f"{'exact':>9s}"
                mark = ""
            else:       # differing numbers with no spread to judge by
                zero_se_diffs += 1
                label = f"{'0-SE':>9s}"
                mark = "  <-- differs, no SE (trials too small)"
            print(f"  {key:24s} {g:3d} {ra.t_comp:12.4f} {rb.t_comp:12.4f}"
                  f" {delta:+12.4f} {label}{mark}")
            if ra.extra.get("serving") and rb.extra.get("serving"):
                # serving rows carry a latency surface: surface the
                # percentile / SLO deltas instead of dropping them
                cens = ("" if not (ra.extra.get("latency_censored")
                                   or rb.extra.get("latency_censored"))
                        else "  [censored: horizon bound, not measured]")
                for field in ("p50", "p99", "slo_miss_rate"):
                    if field in ra.extra and field in rb.extra:
                        va, vb = ra.extra[field], rb.extra[field]
                        print(f"    {field:>22s} {va:12.4f} {vb:12.4f}"
                              f" {vb - va:+12.4f}{cens}")
                        cens = ""
        if len(rows_a) != len(rows_b):
            print(f"  {key:24s} (grids differ: {len(rows_a)} vs "
                  f"{len(rows_b)} points; compared the overlap)")
    verdict = "within" if worst < 6 else "BEYOND"
    tail = (f"; {zero_se_diffs} row(s) differ with zero combined SE -- "
            f"no MC verdict possible for them" if zero_se_diffs else "")
    print(f"max |delta| = {worst:.1f} combined SE "
          f"({verdict} the 6-SE MC band{tail})")
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "ls":
        return cmd_ls(argv[1:])
    if argv and argv[0] == "compare":
        return cmd_compare(argv[1:])

    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="run a declarative experiment spec through the "
                    "content-addressed results store (subcommands: ls, "
                    "compare)")
    ap.add_argument("spec", nargs="?", help="path to an ExperimentSpec "
                                            "JSON file")
    ap.add_argument("--demo", help=f"built-in demo spec "
                                   f"({', '.join(DEMOS)})")
    ap.add_argument("--backend", help="override the sampler backend")
    ap.add_argument("--devices", help="override the device count "
                                      "(int or 'auto')")
    ap.add_argument("--trials", type=int, help="override the trial budget")
    ap.add_argument("--n", type=int, help="override N (work units)")
    ap.add_argument("--live", action="store_true",
                    help="execute the spec through the live control "
                         "plane (execution='live' with default "
                         "LiveConfig) instead of Monte Carlo")
    _store_arg(ap)
    ap.add_argument("--force", action="store_true",
                    help="recompute even on a store hit")
    ap.add_argument("--check-cache", action="store_true",
                    help="run twice; fail unless the second run is a "
                         "content-address hit")
    args = ap.parse_args(argv)

    if bool(args.spec) == bool(args.demo):
        ap.error("give exactly one of: a spec file, or --demo")
    if args.spec:
        specs = [ExperimentSpec.from_json(Path(args.spec).read_text())]
    elif args.demo == "hcmm":
        specs = hcmm_demo_specs()      # one experiment per load point
    else:
        specs = [demo_spec(args.demo)]

    overrides = {}
    if args.backend:
        overrides["backend"] = args.backend
    if args.devices:
        overrides["devices"] = (args.devices if args.devices == "auto"
                                else int(args.devices))
    if args.trials:
        overrides["trials"] = args.trials
    if args.n:
        overrides["N"] = args.n
    if args.live:
        # same spec, live execution (post-init fills the default
        # LiveConfig); a different spec_hash, so MC and live runs of
        # one study sit side by side in the store for `compare`
        overrides["execution"] = "live"
    if overrides:
        specs = [spec.replace(**overrides) for spec in specs]

    store = _open_store(args)
    for spec in specs:
        result = run_experiment(spec, store=store, force=args.force)
        show(result, store)

        if args.check_cache:
            again = run_experiment(spec, store=store)
            if not again.cache_hit:
                print("check-cache: FAILED -- second run was not a store "
                      "hit", file=sys.stderr)
                return 1
            if again.to_dict()["reports"] != result.to_dict()["reports"]:
                print("check-cache: FAILED -- stored reports differ from "
                      "the computed run", file=sys.stderr)
                return 1
            print("check-cache: OK (second run was a content-address hit "
                  "with identical reports)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
