"""Declarative experiment specs: the whole study as one value.

The paper's headline results are grid sweeps -- schemes x (mu, sigma^2)
scenario panels x N x trials -- and every related-work direction
(HCMM-style load optimization, per-worker coded allocation sweeps) has
the same shape.  ``ExperimentSpec`` captures that shape declaratively:

    spec = ExperimentSpec(
        name="fig5",
        grid=ScenarioGrid(K=50, points=[(mu, mu * mu / 6, int(mu))
                                        for mu in (10, 20, 50, 100)]),
        schemes=(scheme_spec("work_exchange"),
                 scheme_spec("mds", opt_trials=64)),
        N=1_000_000, trials=20, seed=1234)

Specs are plain values: serializable to/from JSON losslessly (floats
survive by shortest-repr round-trip), hashable via a canonical content
hash (``spec_hash``), and therefore able to key the content-addressed
results store (``repro.experiments.store``).  Execution knobs that
change the sampled numbers -- backend, device count, seeds -- are part
of the spec and hence of the hash: one hash, one set of numbers.

``repro.experiments.plan`` compiles a spec into an execution ``Plan``;
``repro.experiments.engine`` runs the plan.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.types import HetSpec

SPEC_VERSION = 1

ScenarioPoint = Tuple[float, float, int]        # (mu, sigma2, seed)


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """The scenario axis: one K-worker ``HetSpec`` per grid point.

    Two point sources, used exclusively:

    ``points``
        ``(mu, sigma2, seed)`` triples; each materializes as
        ``HetSpec.uniform_random(K, mu, sigma2, default_rng(seed))`` --
        the paper's Section-7 scenario family, with the heterogeneity
        draw pinned per point so the grid is a pure value.
    ``explicit``
        Literal ``HetSpec`` rate vectors (measured clusters, trace
        corpora, adversarial layouts).  ``K`` is inferred.
    """

    K: int = 0
    points: Tuple[ScenarioPoint, ...] = ()
    explicit: Tuple[HetSpec, ...] = ()

    def __post_init__(self):
        pts = tuple((float(mu), float(s2), int(seed))
                    for mu, s2, seed in self.points)
        exp = tuple(self.explicit)
        if bool(pts) == bool(exp):
            raise ValueError("ScenarioGrid needs exactly one of points= "
                             "or explicit=")
        for h in exp:
            if not isinstance(h, HetSpec):
                raise TypeError(f"explicit entries must be HetSpec; "
                                f"got {type(h).__name__}")
        K = int(self.K) if pts else exp[0].K
        if pts and K <= 0:
            raise ValueError("points grids need K > 0")
        if exp and any(h.K != K for h in exp):
            raise ValueError("explicit HetSpecs must share K")
        object.__setattr__(self, "points", pts)
        object.__setattr__(self, "explicit", exp)
        object.__setattr__(self, "K", K)

    def __len__(self) -> int:
        return len(self.points) or len(self.explicit)

    def specs(self) -> List[HetSpec]:
        """Materialize the grid, point order preserved."""
        if self.explicit:
            return list(self.explicit)
        return [HetSpec.uniform_random(self.K, mu, s2,
                                       np.random.default_rng(seed))
                for mu, s2, seed in self.points]

    def to_dict(self) -> Dict[str, Any]:
        if self.explicit:
            return {"explicit": [h.to_dict() for h in self.explicit]}
        return {"K": self.K, "points": [list(p) for p in self.points]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScenarioGrid":
        if "explicit" in d:
            return cls(explicit=tuple(HetSpec.from_dict(h)
                                      for h in d["explicit"]))
        return cls(K=int(d["K"]),
                   points=tuple(tuple(p) for p in d["points"]))


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One scheme task: registry name + constructor params + report key.

    ``params`` is stored as a sorted ``(key, value)`` tuple so the spec
    stays hashable; build instances with :func:`scheme_spec` to pass
    params as keyword arguments.  ``key`` names the task's row in the
    result (defaults to the scheme name -- give explicit keys when the
    same scheme appears twice with different params, e.g. a threshold
    sweep).  ``seed`` overrides the experiment seed for this task; every
    task draws from its own fresh ``default_rng(seed)``, so adding or
    reordering tasks never perturbs another task's numbers.
    """

    scheme: str
    params: Tuple[Tuple[str, Any], ...] = ()
    key: Optional[str] = None
    seed: Optional[int] = None

    def __post_init__(self):
        if isinstance(self.params, Mapping):
            items = self.params.items()
        else:
            items = tuple(self.params)
        object.__setattr__(self, "params",
                           tuple(sorted((str(k), v) for k, v in items)))

    @property
    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    @property
    def report_key(self) -> str:
        return self.key if self.key is not None else self.scheme

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"scheme": self.scheme}
        if self.params:
            d["params"] = self.params_dict
        if self.key is not None:
            d["key"] = self.key
        if self.seed is not None:
            d["seed"] = int(self.seed)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SchemeSpec":
        return cls(scheme=d["scheme"], params=tuple(d.get("params",
                                                          {}).items()),
                   key=d.get("key"), seed=d.get("seed"))


def scheme_spec(scheme: str, *, key: Optional[str] = None,
                seed: Optional[int] = None, **params) -> SchemeSpec:
    """Ergonomic ``SchemeSpec`` constructor: params as kwargs."""
    return SchemeSpec(scheme=scheme, params=tuple(params.items()), key=key,
                      seed=seed)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A complete declarative experiment.

    ``backend=None`` means "resolve ``REPRO_SAMPLER_BACKEND`` (default
    numpy) at compile time"; ``devices`` is ``1``, an int, or ``"auto"``
    (every available device) and only applies to the sharded backends
    (jax / pallas) -- compilation normalizes both into concrete values,
    and the *resolved* spec is what the store hashes.
    """

    name: str
    grid: ScenarioGrid
    schemes: Tuple[SchemeSpec, ...]
    N: int
    trials: int
    seed: int = 0
    backend: Optional[str] = None
    devices: Union[int, str] = 1
    version: int = SPEC_VERSION

    def __post_init__(self):
        object.__setattr__(self, "schemes", tuple(self.schemes))
        if not self.schemes:
            raise ValueError("ExperimentSpec needs at least one scheme")
        keys = [s.report_key for s in self.schemes]
        if len(set(keys)) != len(keys):
            dupes = sorted({k for k in keys if keys.count(k) > 1})
            raise ValueError(f"duplicate scheme report keys {dupes}; give "
                             f"distinct key= values")
        if isinstance(self.devices, str) and self.devices != "auto":
            raise ValueError(f"devices must be an int or 'auto'; "
                             f"got {self.devices!r}")
        if self.N <= 0 or self.trials <= 0:
            raise ValueError("N and trials must be positive")

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": int(self.version),
            "name": self.name,
            "grid": self.grid.to_dict(),
            "schemes": [s.to_dict() for s in self.schemes],
            "N": int(self.N),
            "trials": int(self.trials),
            "seed": int(self.seed),
            "backend": self.backend,
            "devices": self.devices,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        return cls(name=d["name"], grid=ScenarioGrid.from_dict(d["grid"]),
                   schemes=tuple(SchemeSpec.from_dict(s)
                                 for s in d["schemes"]),
                   N=int(d["N"]), trials=int(d["trials"]),
                   seed=int(d.get("seed", 0)), backend=d.get("backend"),
                   devices=d.get("devices", 1),
                   version=int(d.get("version", SPEC_VERSION)))

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    # -- content addressing -------------------------------------------------

    def canonical_json(self) -> str:
        """Key-sorted, whitespace-free JSON: the hashing preimage."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def spec_hash(self) -> str:
        """sha256 of the canonical JSON -- the store address.  Covers
        every field, execution knobs included: an unchanged hash promises
        the stored numbers are what a re-run would produce."""
        return hashlib.sha256(
            self.canonical_json().encode("utf-8")).hexdigest()

    def replace(self, **changes) -> "ExperimentSpec":
        return dataclasses.replace(self, **changes)


__all__ = [
    "SPEC_VERSION", "ScenarioGrid", "SchemeSpec", "scheme_spec",
    "ExperimentSpec",
]
