"""Declarative experiment specs: the whole study as one value.

The paper's headline results are grid sweeps -- schemes x (mu, sigma^2)
scenario panels x N x trials -- and every related-work direction
(HCMM-style load optimization, per-worker coded allocation sweeps) has
the same shape.  ``ExperimentSpec`` captures that shape declaratively:

    spec = ExperimentSpec(
        name="fig5",
        grid=ScenarioGrid(K=50, points=[(mu, mu * mu / 6, int(mu))
                                        for mu in (10, 20, 50, 100)]),
        schemes=(scheme_spec("work_exchange"),
                 scheme_spec("mds", opt_trials=64)),
        N=1_000_000, trials=20, seed=1234)

The scenario axis is pluggable (``repro.scenarios``): ``grid`` accepts
any registered ``ScenarioFamily`` -- the paper's ``uniform_random``
points and ``explicit`` rate vectors (for which ``ScenarioGrid`` stays
as the PR-4 constructor facade), measured ``trace_corpus`` windows,
``drifting`` AR(1)/regime-switch rate evolution (whose per-round rate
schedule threads through every sampler backend), and ``hcmm_sweep``
load-optimized coded operating points.

Specs are plain values: serializable to/from JSON losslessly (floats
survive by shortest-repr round-trip), hashable via a canonical content
hash (``spec_hash``), and therefore able to key the content-addressed
results store (``repro.experiments.store``).  Execution knobs that
change the sampled numbers -- backend, device count, seeds -- are part
of the spec and hence of the hash: one hash, one set of numbers.  The
two PR-4 families serialize in their original shape, so pre-refactor
hashes and store addresses survive.

``repro.experiments.plan`` compiles a spec into an execution ``Plan``;
``repro.experiments.engine`` runs the plan.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Mapping, Optional, Tuple, Union

from repro.control.config import LiveConfig
from repro.core.types import HetSpec
from repro.hettrain.config import TrainConfig
from repro.scenarios import (ExplicitScenario, ScenarioFamily,
                             ScenarioPoint, UniformRandomScenario,
                             scenario_from_dict)
from repro.serving.config import ServingConfig

SPEC_VERSION = 1


class ScenarioGrid:
    """PR-4 constructor facade over the two original scenario families.

    ``ScenarioGrid(K=, points=)`` builds a ``uniform_random`` family,
    ``ScenarioGrid(explicit=)`` an ``explicit`` one -- exactly the PR-4
    surface, returning the registered family instances that now carry
    the behaviour.  ``ScenarioGrid.from_dict`` deserializes *any*
    registered family (``repro.scenarios.scenario_from_dict``),
    including the key-less PR-4 shapes; unknown family names or unknown
    keys raise ``KeyError`` listing the registered families.
    """

    def __new__(cls, K: int = 0,
                points: Tuple[ScenarioPoint, ...] = (),
                explicit: Tuple[HetSpec, ...] = ()):
        if bool(tuple(points)) == bool(tuple(explicit)):
            raise ValueError("ScenarioGrid needs exactly one of points= "
                             "or explicit=")
        if points:
            return UniformRandomScenario(K=K, points=tuple(points))
        return ExplicitScenario(explicit=tuple(explicit))

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> ScenarioFamily:
        return scenario_from_dict(d)


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One scheme task: registry name + constructor params + report key.

    ``params`` is stored as a sorted ``(key, value)`` tuple so the spec
    stays hashable; build instances with :func:`scheme_spec` to pass
    params as keyword arguments.  ``key`` names the task's row in the
    result (defaults to the scheme name -- give explicit keys when the
    same scheme appears twice with different params, e.g. a threshold
    sweep).  ``seed`` overrides the experiment seed for this task; every
    task draws from its own fresh ``default_rng(seed)``, so adding or
    reordering tasks never perturbs another task's numbers.
    """

    scheme: str
    params: Tuple[Tuple[str, Any], ...] = ()
    key: Optional[str] = None
    seed: Optional[int] = None

    def __post_init__(self):
        if isinstance(self.params, Mapping):
            items = self.params.items()
        else:
            items = tuple(self.params)
        object.__setattr__(self, "params",
                           tuple(sorted((str(k), v) for k, v in items)))

    @property
    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    @property
    def report_key(self) -> str:
        return self.key if self.key is not None else self.scheme

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"scheme": self.scheme}
        if self.params:
            d["params"] = self.params_dict
        if self.key is not None:
            d["key"] = self.key
        if self.seed is not None:
            d["seed"] = int(self.seed)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SchemeSpec":
        return cls(scheme=d["scheme"], params=tuple(d.get("params",
                                                          {}).items()),
                   key=d.get("key"), seed=d.get("seed"))


def scheme_spec(scheme: str, *, key: Optional[str] = None,
                seed: Optional[int] = None, **params) -> SchemeSpec:
    """Ergonomic ``SchemeSpec`` constructor: params as kwargs."""
    return SchemeSpec(scheme=scheme, params=tuple(params.items()), key=key,
                      seed=seed)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A complete declarative experiment.

    ``backend=None`` means "resolve ``REPRO_SAMPLER_BACKEND`` (default
    numpy) at compile time"; ``devices`` is ``1``, an int, or ``"auto"``
    (every available device) and only applies to the sharded backends
    (jax / pallas) -- compilation normalizes both into concrete values,
    and the *resolved* spec is what the store hashes.

    ``serving`` attaches a streaming-arrival axis
    (``repro.serving.ServingConfig``): every scheme task then runs as a
    dispatch policy through the slotted queueing engine at each offered
    load, one report row per (grid point x load).  ``None`` (batch MC,
    the default) serializes with the key omitted, so every pre-serving
    spec hash and store address is unchanged.

    ``execution="live"`` routes every scheme task through the asyncio
    control plane (``repro.control``) instead of Monte Carlo: real
    transport messages, real jitted matmul shards, ``trials`` live
    episodes per grid point, measured ``T_comp`` in the same MCReport
    shape (plus ``extra["control_plane"]``).  ``live`` carries the
    transport/pacing/fault knobs (``repro.control.LiveConfig``;
    defaults apply when ``execution="live"`` with ``live=None``).  Both
    keys are omitted from serialization at their defaults -- "mc" and
    ``None`` -- so every pre-live spec hash and store address is
    unchanged.

    ``panel="fused"`` runs the batch-MC tasks through the fused
    whole-panel dispatcher (``repro.core.schemes.mc_grid_panel``): the
    work-exchange known/unknown pair of the panel becomes ONE engine
    call on backends with a panel executor (jax / pallas), every other
    task keeps its own per-task stream and stays bit-identical to
    ``"per_scheme"``.  The fused pair's numbers are statistically
    equivalent but not bit-equal (one shared stream), which is why the
    mode is opt-in.  The key is omitted from serialization at the
    ``"per_scheme"`` default, so every pre-panel spec hash and store
    address is unchanged.

    ``training`` attaches the heterogeneous-training axis
    (``repro.hettrain.TrainConfig``): every scheme task then runs as an
    epoch-assignment policy over real gradients -- ``N`` becomes units
    (microbatches) per optimizer step, ``trials`` the independent
    virtual-time realizations of the one shared trajectory, and each
    report row carries the loss curve, per-step ``T_comp`` and
    straggler-wait fractions in ``extra["training"]``.  ``None`` (the
    default) serializes with the key omitted, so every pre-training
    spec hash and store address is unchanged (pinned by test).
    """

    name: str
    grid: ScenarioFamily
    schemes: Tuple[SchemeSpec, ...]
    N: int
    trials: int
    seed: int = 0
    backend: Optional[str] = None
    devices: Union[int, str] = 1
    serving: Optional[ServingConfig] = None
    execution: str = "mc"
    live: Optional[LiveConfig] = None
    panel: str = "per_scheme"
    training: Optional[TrainConfig] = None
    version: int = SPEC_VERSION

    def __post_init__(self):
        if not isinstance(self.grid, ScenarioFamily):
            raise TypeError(f"grid must be a registered ScenarioFamily "
                            f"(or built via ScenarioGrid); got "
                            f"{type(self.grid).__name__}")
        if self.serving is not None and not isinstance(self.serving,
                                                       ServingConfig):
            raise TypeError(f"serving must be a ServingConfig or None; "
                            f"got {type(self.serving).__name__}")
        if self.execution not in ("mc", "live"):
            raise ValueError(f"execution must be 'mc' or 'live'; "
                             f"got {self.execution!r}")
        if self.live is not None and not isinstance(self.live, LiveConfig):
            raise TypeError(f"live must be a LiveConfig or None; "
                            f"got {type(self.live).__name__}")
        if self.execution == "live":
            if self.serving is not None:
                raise ValueError("execution='live' and serving= are "
                                 "mutually exclusive axes")
            if self.live is None:
                object.__setattr__(self, "live", LiveConfig())
        elif self.live is not None:
            raise ValueError("live= requires execution='live'")
        if self.panel not in ("per_scheme", "fused"):
            raise ValueError(f"panel must be 'per_scheme' or 'fused'; "
                             f"got {self.panel!r}")
        if self.panel == "fused" and (self.serving is not None
                                      or self.execution != "mc"):
            raise ValueError("panel='fused' applies to batch MC only; "
                             "drop serving= / execution='live'")
        if self.training is not None:
            if not isinstance(self.training, TrainConfig):
                raise TypeError(f"training must be a TrainConfig or None; "
                                f"got {type(self.training).__name__}")
            if self.serving is not None or self.execution != "mc":
                raise ValueError("training= and serving= / "
                                 "execution='live' are mutually exclusive "
                                 "axes")
            if self.panel != "per_scheme":
                raise ValueError("training= runs per-scheme; drop "
                                 "panel='fused'")
        object.__setattr__(self, "schemes", tuple(self.schemes))
        if not self.schemes:
            raise ValueError("ExperimentSpec needs at least one scheme")
        keys = [s.report_key for s in self.schemes]
        if len(set(keys)) != len(keys):
            dupes = sorted({k for k in keys if keys.count(k) > 1})
            raise ValueError(f"duplicate scheme report keys {dupes}; give "
                             f"distinct key= values")
        if isinstance(self.devices, str) and self.devices != "auto":
            raise ValueError(f"devices must be an int or 'auto'; "
                             f"got {self.devices!r}")
        if self.N <= 0 or self.trials <= 0:
            raise ValueError("N and trials must be positive")

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "version": int(self.version),
            "name": self.name,
            "grid": self.grid.to_dict(),
            "schemes": [s.to_dict() for s in self.schemes],
            "N": int(self.N),
            "trials": int(self.trials),
            "seed": int(self.seed),
            "backend": self.backend,
            "devices": self.devices,
        }
        if self.serving is not None:
            # key omitted when absent: pre-serving hashes stay valid
            d["serving"] = self.serving.to_dict()
        if self.execution != "mc":
            # both live keys omitted at defaults: pre-live hashes survive
            d["execution"] = self.execution
            d["live"] = self.live.to_dict()
        if self.panel != "per_scheme":
            # key omitted at the default: pre-panel hashes survive
            d["panel"] = self.panel
        if self.training is not None:
            # key omitted when absent: pre-training hashes stay valid
            d["training"] = self.training.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        serving = d.get("serving")
        live = d.get("live")
        training = d.get("training")
        return cls(name=d["name"], grid=ScenarioGrid.from_dict(d["grid"]),
                   schemes=tuple(SchemeSpec.from_dict(s)
                                 for s in d["schemes"]),
                   N=int(d["N"]), trials=int(d["trials"]),
                   seed=int(d.get("seed", 0)), backend=d.get("backend"),
                   devices=d.get("devices", 1),
                   serving=(None if serving is None
                            else ServingConfig.from_dict(serving)),
                   execution=d.get("execution", "mc"),
                   live=(None if live is None
                         else LiveConfig.from_dict(live)),
                   panel=d.get("panel", "per_scheme"),
                   training=(None if training is None
                             else TrainConfig.from_dict(training)),
                   version=int(d.get("version", SPEC_VERSION)))

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    # -- content addressing -------------------------------------------------

    def canonical_json(self) -> str:
        """Key-sorted, whitespace-free JSON: the hashing preimage."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def spec_hash(self) -> str:
        """sha256 of the canonical JSON -- the store address.  Covers
        every field, execution knobs included: an unchanged hash promises
        the stored numbers are what a re-run would produce."""
        return hashlib.sha256(
            self.canonical_json().encode("utf-8")).hexdigest()

    def replace(self, **changes) -> "ExperimentSpec":
        return dataclasses.replace(self, **changes)


__all__ = [
    "SPEC_VERSION", "ScenarioGrid", "ScenarioFamily", "SchemeSpec",
    "scheme_spec", "ExperimentSpec",
]
