"""Spec -> Plan compilation: resolve every execution knob up front.

``compile_plan`` turns a declarative ``ExperimentSpec`` into an
execution ``Plan``:

* the sampler backend is resolved (explicit field, else
  ``REPRO_SAMPLER_BACKEND``, else numpy) and validated against the
  registry;
* the device count is normalized to a concrete int -- ``"auto"`` and
  over-asks clamp to what the host offers, and backends without a
  sharded executor (numpy: the bit-exact single-device oracle) pin to 1;
* every scheme task is validated by instantiating it (unknown names and
  bad params fail at compile time, not mid-run) and gets its concrete
  rng seed;
* the scenario grid is materialized into ``HetSpec`` rows.

The plan's ``spec`` field is the *resolved* spec -- the value the store
hashes, so a cache hit promises the stored numbers are what this exact
execution would produce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.schemes import get_scheme
from repro.core.samplers import grid_bucket_shape, resolve_backend
from repro.core.types import HetSpec

from .spec import ExperimentSpec

# backends with a sharded multi-device executor (repro.core.samplers
# ``grid_sharding``); everything else runs single-device
SHARDED_BACKENDS = ("jax", "pallas")


@dataclasses.dataclass(frozen=True)
class Task:
    """One resolved scheme run over the whole scenario grid."""

    key: str
    scheme: str
    params: Tuple[Tuple[str, Any], ...]
    seed: int

    @property
    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)


@dataclasses.dataclass
class Plan:
    """Compiled execution plan: resolved spec + materialized work.

    ``rate_schedules`` is the scenario family's optional ``(G, R, K)``
    per-exchange-round service-rate schedule (drifting / trace-corpus
    grids), handed to every scheme task whose scheme declares
    ``supports_rate_schedule``.
    """

    spec: ExperimentSpec          # backend/devices concrete
    het_specs: List[HetSpec]
    tasks: List[Task]
    rate_schedules: Optional[np.ndarray] = None

    @property
    def spec_hash(self) -> str:
        return self.spec.spec_hash()

    @property
    def backend(self) -> str:
        return self.spec.backend

    @property
    def devices(self) -> int:
        return int(self.spec.devices)

    @property
    def bucket_shape(self) -> Optional[Dict[str, int]]:
        """The padded ``(rows, K[, R])`` shape bucket this plan's panel
        dispatches at on a transform backend (None on the exact numpy
        oracle, which never pads).  Plans with equal buckets share one
        compilation -- and one ``REPRO_JAX_CACHE_DIR`` persistent-cache
        entry -- regardless of their raw ``(G, trials, K, R)``."""
        if self.backend not in SHARDED_BACKENDS or not self.het_specs:
            return None
        R = (None if self.rate_schedules is None
             else int(self.rate_schedules.shape[1]))
        return grid_bucket_shape(len(self.het_specs), self.spec.trials,
                                 self.het_specs[0].K, R,
                                 backend=self.backend)


def _resolve_devices(requested, backend: str) -> int:
    if backend not in SHARDED_BACKENDS:
        return 1
    if requested == "auto" or requested is None:
        want = None
    else:
        want = int(requested)
        if want <= 1:
            return 1
    import jax
    have = len(jax.devices())
    return have if want is None else max(1, min(want, have))


def compile_plan(spec: ExperimentSpec) -> Plan:
    """Resolve backend/devices, validate tasks, materialize the grid."""
    backend = resolve_backend(spec.backend)
    devices = _resolve_devices(spec.devices, backend)
    if spec.serving is not None:
        # the queueing engine resolves like the sampler backend does
        # (explicit field > $REPRO_SERVING_BACKEND > numpy) and the
        # concrete name lands in the stored spec: the cache address
        # promises which engine produced the numbers
        from repro.serving.backends import get_serving_backend
        sname = spec.serving.resolve_backend()
        if sname != spec.serving.backend:
            spec = spec.replace(
                serving=dataclasses.replace(spec.serving, backend=sname))
        if get_serving_backend(sname).shards:
            # the scan engine stacks (load x trial) rows -- a batch axis
            # the 1-D grid mesh splits like any other
            devices = _resolve_devices(spec.devices, "jax")
        else:
            # the numpy oracle loop is sequential in time and runs
            # single-device regardless of sampler backend
            devices = 1
    if spec.execution == "live":
        # live episodes are one asyncio event loop; the sharded executor
        # does not apply, and the transport must exist at compile time
        devices = 1
        spec.live.build_transport()
    if spec.panel == "fused" and backend != "pallas":
        # the jax coupled-CRN fused-panel engine runs single-device;
        # only the pallas kernel path shards the stacked mixed-mode
        # rows (see we_rounds_grid)
        devices = 1
    if spec.training is not None:
        # the training engine is one jit stream (scan over unit groups);
        # the sharded MC executor does not apply
        devices = 1
    tasks = []
    for s in spec.schemes:
        scheme = get_scheme(s.scheme, **s.params_dict)  # fail fast
        if spec.execution == "live":
            from repro.control.coordinator import live_supported
            live_supported(scheme)      # unsupported schemes fail here
        tasks.append(Task(key=s.report_key, scheme=s.scheme,
                          params=s.params,
                          seed=int(s.seed if s.seed is not None
                                   else spec.seed)))
    resolved = spec.replace(backend=backend, devices=devices)
    return Plan(spec=resolved, het_specs=spec.grid.specs(), tasks=tasks,
                rate_schedules=spec.grid.rate_schedules())


__all__ = ["SHARDED_BACKENDS", "Task", "Plan", "compile_plan"]
