"""Content-addressed results store: ``results/store/<spec-hash>.json``.

Every stored file is one ``ExperimentResult`` record: the *resolved*
spec (backend and devices concrete), its hash, the per-task ``MCReport``
rows, and the execution environment.  The file name IS the spec hash,
so identity is structural: re-running an unchanged spec is a cache hit,
and any change to the spec -- scenario grid, trial budget, backend,
device count, seeds -- lands at a new address instead of silently
overwriting old numbers.

Writes are atomic (tmp file + rename); unreadable or mismatched entries
read as misses rather than crashes, so a corrupted store degrades to
recomputation.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import List, Optional, Union

from .spec import ExperimentSpec

DEFAULT_STORE_ROOT = Path("results") / "store"


class ResultsStore:
    """Filesystem store keyed by ``ExperimentSpec.spec_hash()``."""

    def __init__(self, root: Union[str, Path] = DEFAULT_STORE_ROOT):
        self.root = Path(root)

    def path_for(self, spec_hash: str) -> Path:
        return self.root / f"{spec_hash}.json"

    def _hash_of(self, key) -> str:
        if isinstance(key, ExperimentSpec):
            # address by what running the spec here-and-now would store:
            # compile resolves backend=None / devices="auto" AND clamps a
            # concrete device over-ask exactly like run_experiment does
            # (idempotent on already-resolved specs)
            from .plan import compile_plan
            return compile_plan(key).spec.spec_hash()
        return str(key)

    def __contains__(self, key) -> bool:
        return self.path_for(self._hash_of(key)).exists()

    def entries(self) -> List[str]:
        """Stored spec hashes (file names without the .json suffix)."""
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.json"))

    def get(self, key) -> Optional["ExperimentResult"]:
        """Load the result for a spec (or literal hash); None on miss.

        A file that cannot be parsed, or whose recorded hash does not
        match its address, counts as a miss -- the engine recomputes and
        rewrites it.
        """
        from .engine import ExperimentResult

        spec_hash = self._hash_of(key)
        path = self.path_for(spec_hash)
        try:
            result = ExperimentResult.from_dict(
                json.loads(path.read_text()))
        except (OSError, ValueError, KeyError, TypeError, AttributeError):
            # unreadable, unparseable, or structurally wrong records all
            # degrade to recomputation
            return None
        if result.spec_hash != spec_hash:
            return None
        return result

    def put(self, result: "ExperimentResult") -> Path:
        """Atomically write a result at its content address."""
        path = self.path_for(result.spec_hash)
        self.root.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(result.to_dict(), f, indent=1)
            os.chmod(tmp, 0o644)       # mkstemp defaults to 0600
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path


def default_store() -> ResultsStore:
    """The repo-standard store under ``results/store``."""
    return ResultsStore(DEFAULT_STORE_ROOT)


__all__ = ["DEFAULT_STORE_ROOT", "ResultsStore", "default_store"]
