"""Training step factory + host-side fit loop.

``make_train_step`` builds the jit-able pure step (loss -> grads -> AdamW),
used both by the real CPU training examples and by the multi-pod dry-run
(lowered with ShapeDtypeStructs).  Gradient compression and the
heterogeneity-aware microbatch schedule plug in around this step
(distributed/hetsched.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamW


def make_train_step(model, opt: AdamW, mode: str = "scan",
                    remat: bool = True, accum: int = 1) -> Callable:
    """accum > 1: gradient accumulation over microbatches (lax.scan).

    The global batch is split on its leading axis; activations live for
    one microbatch at a time (peak activation memory / accum) while the
    numerics match the full-batch step (grads are mean-accumulated in
    f32).  The per-microbatch boundary is also where work-exchange
    reassignment slots in on a heterogeneous fleet (DESIGN §3).
    """
    def grad_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, mode=mode, remat=remat)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum,
                                    *x.shape[1:]), batch)

            def body(acc, mb):
                (loss, metrics), g = grad_of(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / accum, acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metrics) = jax.lax.scan(body, zeros, micro)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metrics)
        new_params, new_state = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, grad_norm=gnorm)
        return new_params, new_state, out_metrics

    return train_step


def make_grad_step(model, mode: str = "scan", remat: bool = False):
    """Per-microbatch gradient (no update) -- the work-exchange unit op."""
    def grad_step(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, mode=mode, remat=remat)[0]
        return jax.value_and_grad(loss_fn)(params)
    return grad_step


def fit(model, params, opt: AdamW, batches, mode: str = "scan",
        remat: bool = False, log_every: int = 10,
        callback: Optional[Callable] = None):
    """Simple synchronous host loop (CPU examples / tests)."""
    step_fn = jax.jit(make_train_step(model, opt, mode, remat))
    opt_state = opt.init(params)
    history = []
    for i, batch in enumerate(batches):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or callback:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            if callback:
                callback(i, m)
    return params, opt_state, history
