"""Serving step factories: prefill and decode (one token, KV cache)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_prefill_step(model, mode: str = "unroll") -> Callable:
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache, mode=mode)
    return prefill_step


def make_decode_step(model, mode: str = "unroll") -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, mode=mode)
    return decode_step


def greedy_generate(model, params, prompt_batch, cache, steps: int,
                    mode: str = "unroll"):
    """Greedy generation for the examples; returns (tokens, cache)."""
    prefill = jax.jit(make_prefill_step(model, mode))
    decode = jax.jit(make_decode_step(model, mode), donate_argnums=(1,))
    logits, cache = prefill(params, prompt_batch, cache)
    tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1), cache
