from .loop import fit, make_grad_step, make_train_step
from .serve import greedy_generate, make_decode_step, make_prefill_step

__all__ = ["fit", "make_grad_step", "make_train_step",
           "greedy_generate", "make_decode_step", "make_prefill_step"]
