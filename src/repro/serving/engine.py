"""The slotted-time serving engine: arrivals in, latency curves out.

``simulate_serving`` runs one (scheme, heterogeneity, offered load) cell
as a trials-batched discrete-event approximation, the MC-engine
discipline applied to the arrival plane: all state is ``(trials, Q, K)``
int64 arrays advanced slot by slot with pure numpy, no per-job Python
objects.  Per slot, in order:

1. *rebalance* -- exchange-class policies re-deal every leftover unit
   across workers by a stream deal: active jobs concatenate (admission
   order) into one unit stream, worker k takes the contiguous interval
   between largest-remainder boundaries of the believed rates.  Exactly
   integer-conserving; units a worker gains count into ``n_comm``.
2. *arrivals + admission* -- the arrival process offers jobs; admission
   rejects on buffer overflow and (``admission="deadline"``) on
   predicted sojourn ``(backlog + u) / lambda_sum`` past the deadline.
   Closed-loop clients resubmit ``think_slots`` after completion.
3. *placement* -- the dispatch policy maps each admitted job's units to
   per-worker shares (``repro.serving.policies``).
4. *service* -- each worker serves its FIFO backlog up to an independent
   ``Poisson(lambda_k dt)`` unit budget; under a drifting / trace
   scenario the schedule moves the TRUE rates for every policy (the
   cluster really slows down), while placement still follows nominal
   rates -- or the online ``(served+1)/(busy+1)`` estimates for
   estimate-driven policies.
5. *completion* -- the policy's done criterion fires, sojourn is
   recorded, coded leftovers are purged.

An exact int64 conservation identity (units shipped == served +
cancelled + backlog) is asserted EVERY slot -- a dispatch-policy bug
dies loudly, not as a subtly wrong latency curve.

Metrics (completion-slot >= warmup only) fold into one ``MCReport`` per
cell: ``t_comp`` = mean sojourn (per-trial mean, trials without a single
window completion censored at the horizon), ``iterations`` = completed
jobs, ``n_comm`` = exchanged units, and ``extra`` carries the latency
surface (p50/p95/p99, throughput, goodput, occupancy, queue depth,
reject + SLO-miss rates) -- so serving rows flow through the store, the
CLI, and ``MCReport.to_dict`` untouched.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.schemes import MCReport
from repro.core.types import HetSpec

from .config import AUTO_SLOTS_PER_JOB, ServingConfig
from .policies import dispatch_policy, lr_round_rows

__all__ = ["simulate_serving", "run_serving_grid"]

_BIG = np.iinfo(np.int64).max


def simulate_serving(het: HetSpec, scheme_name: str,
                     params: Optional[Dict[str, Any]], cfg: ServingConfig,
                     N: int, load: float, trials: int,
                     rng: np.random.Generator,
                     rate_schedule: Optional[np.ndarray] = None) -> MCReport:
    """One load cell: simulate ``trials`` independent queues and fold the
    latency/throughput surface into an ``MCReport`` (see module docs).

    ``rate_schedule`` is an optional ``(R, K)`` true-rate schedule for
    this grid point (drifting / trace scenarios), stretched uniformly
    over the slot horizon.
    """
    policy = dispatch_policy(scheme_name, dict(params or {}), het, N)
    arrival = cfg.build_arrival()
    T, K, Q, S = int(trials), het.K, int(cfg.max_queue_jobs), int(cfg.slots)
    if T < 1:
        raise ValueError("trials must be >= 1")
    N = int(N)
    lam = het.lambdas
    lam_sum = het.lambda_sum
    dt = (float(cfg.slot_dt) if cfg.slot_dt is not None
          else N / lam_sum / AUTO_SLOTS_PER_JOB)
    warm = int(float(cfg.warmup_frac) * S)
    window_t = (S - warm) * dt
    horizon_t = S * dt
    deadline_t = (None if cfg.deadline_slo is None
                  else float(cfg.deadline_slo) * N / lam_sum)
    jobs_per_slot = float(load) * lam_sum * dt / N
    sched = None
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float64)
        if sched.ndim != 2 or sched.shape[1] != K:
            raise ValueError(f"rate_schedule must be (rounds, K={K}); "
                             f"got {sched.shape}")

    # offered demand: open-loop processes precompute the stream, closed
    # loop runs off the resubmission ring
    if arrival.closed_loop:
        counts = np.zeros((T, S), dtype=np.int64)
        resub = np.zeros((T, S + 1), dtype=np.int64)
        resub[:, 0] = arrival.population_for(float(load), K)
        think = int(arrival.think_slots)
    else:
        counts = np.asarray(
            arrival.job_counts(T, S, jobs_per_slot, rng), dtype=np.int64)
        resub, think = None, 0

    # job state, one row per buffer slot
    R = np.zeros((T, Q, K), dtype=np.int64)        # remaining units
    S0 = np.zeros((T, Q, K), dtype=np.int64)       # shipped at placement
    units = np.zeros((T, Q), dtype=np.int64)
    seq = np.zeros((T, Q), dtype=np.int64)         # admission order
    arr_slot = np.zeros((T, Q), dtype=np.int64)
    active = np.zeros((T, Q), dtype=bool)
    aux = np.full((T, Q), -1, dtype=np.int64)      # policy tag (hedged)
    seq_ctr = np.zeros(T, dtype=np.int64)

    # online rate beliefs: units served over busy seconds, prior 1.0
    served_w = np.zeros((T, K), dtype=np.float64)
    busy_w = np.zeros((T, K), dtype=np.float64)
    believed_nominal = np.broadcast_to(lam, (T, K))

    # exact conservation ledger
    shipped_cum = np.zeros(T, dtype=np.int64)
    served_cum = np.zeros(T, dtype=np.int64)
    cancelled_cum = np.zeros(T, dtype=np.int64)

    # measurement-window accumulators
    soj_all: List[np.ndarray] = []
    sum_soj = np.zeros(T, dtype=np.float64)
    completed_w = np.zeros(T, dtype=np.int64)
    completed_full = np.zeros(T, dtype=np.int64)
    goodput_w = np.zeros(T, dtype=np.int64)
    slo_miss = np.zeros(T, dtype=np.int64)
    moved_w = np.zeros(T, dtype=np.float64)
    qd_sum = np.zeros(T, dtype=np.float64)
    served_units_w = np.zeros(T, dtype=np.int64)
    offered = np.zeros(T, dtype=np.int64)
    rejected = np.zeros(T, dtype=np.int64)

    geo_p = 1.0 / max(N, 1)
    # admission fills the lowest free buffer row, so live jobs stay
    # compact at the front: q_hi (high-water mark of rows ever used)
    # bounds every O(Q) pass by the actual concurrency, not the cap
    q_hi = 0
    q_hi_peak = 0
    q_hi_sum = 0
    for s in range(S):
        lam_t = lam
        if sched is not None:
            row = min(s * sched.shape[0] // S, sched.shape[0] - 1)
            lam_t = sched[row]

        # -- 1. rebalance (exchange-class policies) ------------------------
        # ship ONLY surplus (the paper's leftover-reassignment, not a
        # full re-deal): workers holding more backlog than their rate
        # share give up units -- newest jobs first, so the head-of-line
        # job keeps its parallel spread -- and the moved units deal into
        # the deficit workers' contiguous stream intervals (exactly
        # integer-conserving, per job and per trial)
        if (policy.exchanges and s % int(cfg.exchange_every) == 0 and s
                and q_hi):
            Rv, activev, seqv = R[:, :q_hi], active[:, :q_hi], seq[:, :q_hi]
            weights = ((served_w + 1.0) / (busy_w + 1.0)
                       if policy.uses_estimates else believed_nominal)
            b = Rv.sum(axis=1)                        # (T, K) backlogs
            targets = lr_round_rows(weights, b.sum(axis=1))
            surplus = np.clip(b - targets, 0, None)
            deficit = np.clip(targets - b, 0, None)
            if surplus.any():
                key = np.where(activev, seqv, _BIG)
                order = np.argsort(key, axis=1, kind="stable")
                R_ord = np.take_along_axis(Rv, order[:, :, None], axis=1)
                # units queued behind job q on worker k (newer jobs)
                behind = (np.cumsum(R_ord[:, ::-1], axis=1)[:, ::-1]
                          - R_ord)
                rm = np.clip(np.minimum(
                    R_ord, surplus[:, None, :] - behind), 0, None)
                rm_q = rm.sum(axis=2)                 # (T, Qh) moved/job
                end = np.cumsum(rm_q, axis=1)
                start = end - rm_q
                dbounds = np.concatenate(
                    [np.zeros((T, 1), dtype=np.int64),
                     np.cumsum(deficit, axis=1)], axis=1)
                add = np.clip(
                    np.minimum(end[:, :, None], dbounds[:, None, 1:])
                    - np.maximum(start[:, :, None], dbounds[:, None, :-1]),
                    0, None)
                np.put_along_axis(Rv, order[:, :, None], R_ord - rm + add,
                                  axis=1)
                if policy.count_comm and s >= warm:
                    moved_w += add.sum(axis=(1, 2))

        # -- 2+3. arrivals, admission, placement ---------------------------
        n_new = counts[:, s] + (resub[:, s] if resub is not None else 0)
        for j in range(int(n_new.max()) if T else 0):
            cand = n_new > j
            if s >= warm:
                offered += cand
            if cfg.job_units_dist == "geometric":
                u = rng.geometric(geo_p, size=T).astype(np.int64)
            else:
                u = np.full(T, N, dtype=np.int64)
            inactive = ~active
            has_free = inactive.any(axis=1)
            qidx = np.argmax(inactive, axis=1)
            ok = cand & has_free
            if cfg.admission == "deadline":
                pred = (R.sum(axis=(1, 2)) + u) / lam_sum
                ok &= pred <= deadline_t
            rej = cand & ~ok
            if s >= warm:
                rejected += rej
            if resub is not None and s + 1 < S:
                # a bounced closed-loop client retries next slot
                resub[:, s + 1] += rej
            tr = np.nonzero(ok)[0]
            if tr.size == 0:
                continue
            ua = u[tr]
            believed = (((served_w[tr] + 1.0) / (busy_w[tr] + 1.0))
                        if policy.uses_estimates
                        else np.broadcast_to(lam, (tr.size, K)))
            placed = policy.place(ua, believed)
            shares, ptag = (placed if isinstance(placed, tuple)
                            else (placed, None))
            q = qidx[tr]
            R[tr, q] = shares
            S0[tr, q] = shares
            units[tr, q] = ua
            seq[tr, q] = seq_ctr[tr]
            seq_ctr[tr] += 1
            arr_slot[tr, q] = s
            active[tr, q] = True
            aux[tr, q] = -1 if ptag is None else ptag
            shipped_cum[tr] += shares.sum(axis=1)
            q_hi = max(q_hi, int(q.max()) + 1)

        # -- 4. service: per-worker FIFO up to Poisson(lambda_k dt) --------
        cap = rng.poisson(lam_t * dt, size=(T, K)).astype(np.int64)
        Rv, activev = R[:, :q_hi], active[:, :q_hi]
        bk_before = Rv.sum(axis=1)                 # (T, K)
        key = np.where(activev, seq[:, :q_hi], _BIG)
        order = np.argsort(key, axis=1, kind="stable")
        R_ord = np.take_along_axis(Rv, order[:, :, None], axis=1)
        ahead = np.cumsum(R_ord, axis=1) - R_ord
        srv = np.minimum(R_ord, np.clip(cap[:, None, :] - ahead, 0, None))
        np.put_along_axis(Rv, order[:, :, None], R_ord - srv, axis=1)
        srv_k = srv.sum(axis=1)                    # (T, K)
        served_cum += srv_k.sum(axis=1)
        served_w += srv_k
        busy_w += dt * (bk_before > 0)

        # -- 5. completions ------------------------------------------------
        done = policy.done_mask(Rv, S0[:, :q_hi], units[:, :q_hi],
                                activev, aux[:, :q_hi]) & activev
        if done.any():
            if policy.purge:
                cancelled_cum += (Rv * done[:, :, None]).sum(axis=(1, 2))
                Rv[done] = 0
            n_done_t = done.sum(axis=1)
            completed_full += n_done_t
            if s >= warm:
                tidx = np.nonzero(done)[0]
                vals = ((s + 1 - arr_slot[:, :q_hi]) * dt)[done]
                soj_all.append(vals)
                np.add.at(sum_soj, tidx, vals)
                completed_w += n_done_t
                goodput_w += (units[:, :q_hi] * done).sum(axis=1)
                if deadline_t is not None:
                    np.add.at(slo_miss, tidx,
                              (vals > deadline_t + 1e-12).astype(np.int64))
            activev &= ~done
            if resub is not None and s + 1 + think < S:
                resub[:, s + 1 + think] += n_done_t
            # the mark must also SHRINK: after a burst drains, a frozen
            # q_hi keeps every later pass O(peak) instead of O(live) --
            # recompact to the last live row once occupancy halves
            live_rows = activev.any(axis=0)
            if int(live_rows.sum()) < q_hi // 2:
                nz = np.nonzero(live_rows)[0]
                q_hi = int(nz[-1]) + 1 if nz.size else 0

        if s >= warm:
            qd_sum += Rv.sum(axis=(1, 2))
            served_units_w += srv_k.sum(axis=1)
        q_hi_peak = max(q_hi_peak, q_hi)
        q_hi_sum += q_hi

        # -- conservation: exact, every slot -------------------------------
        backlog = Rv.sum(axis=(1, 2))
        if not np.array_equal(shipped_cum,
                              served_cum + cancelled_cum + backlog):
            raise AssertionError(
                f"work conservation violated at slot {s} "
                f"({scheme_name}): shipped {shipped_cum.tolist()} != "
                f"served {served_cum.tolist()} + cancelled "
                f"{cancelled_cum.tolist()} + backlog {backlog.tolist()}")

    soj_pool = (np.concatenate(soj_all) if soj_all
                else np.empty(0, dtype=np.float64))
    censored = int((completed_w == 0).sum())
    per_trial = np.where(completed_w > 0,
                         sum_soj / np.maximum(completed_w, 1), horizon_t)
    if soj_pool.size:
        p50, p95, p99 = (float(x) for x in
                         np.percentile(soj_pool, [50.0, 95.0, 99.0]))
        latency_censored = False
    else:
        # no job completed inside the measurement window: the horizon is
        # only a LOWER BOUND on the true latency, not a measurement --
        # flagged below so knee detection and the CLI can tell a
        # saturated cell from a measured one
        p50 = p95 = p99 = horizon_t
        latency_censored = True
    its = completed_w.astype(np.float64)
    extra: Dict[str, Any] = {
        "serving": 1.0,
        "offered_load": float(load),
        "slot_dt": float(dt),
        "p50": p50, "p95": p95, "p99": p99,
        "throughput_jobs": float(completed_w.mean() / window_t),
        "goodput_units": float(goodput_w.mean() / window_t),
        "occupancy": float(served_units_w.mean() / (lam_sum * window_t)),
        "queue_depth": float(qd_sum.mean() / max(S - warm, 1)),
        "reject_rate": float(rejected.sum() / max(offered.sum(), 1)),
        "completed_jobs": float(completed_full.mean()),
        "units_admitted": float(shipped_cum.mean()),
        "units_served": float(served_cum.mean()),
        "units_cancelled": float(cancelled_cum.mean()),
        "units_backlog": float(R.sum(axis=(1, 2)).mean()),
        # scan-window telemetry: mean/peak high-water mark over slots
        # (the compaction regression test reads these -- a burst that
        # drains must pull the mean well below the peak)
        "q_hi_mean": float(q_hi_sum / max(S, 1)),
        "q_hi_peak": float(q_hi_peak),
    }
    if deadline_t is not None:
        extra["deadline_s"] = float(deadline_t)
        extra["slo_miss_rate"] = float(slo_miss.sum()
                                       / max(completed_w.sum(), 1))
    # censoring telemetry: ``latency_censored`` marks the full fallback
    # (every percentile above is the horizon bound, not a measurement);
    # ``censored_frac`` is the per-trial fraction that completed nothing
    # (partial censoring biases percentiles low -- the slow trials'
    # latencies are the ones missing from the pool)
    extra["latency_censored"] = 1.0 if latency_censored else 0.0
    if censored:
        extra["censored"] = float(censored)
        extra["censored_frac"] = float(censored / T)
    return MCReport(
        scheme=policy.scheme.name, trials=T,
        t_comp=float(per_trial.mean()), t_comp_std=float(per_trial.std()),
        iterations=float(its.mean()), iterations_std=float(its.std()),
        n_comm=float(moved_w.mean()), n_comm_std=float(moved_w.std()),
        extra=extra)


def run_serving_grid(scheme_name: str, params: Optional[Dict[str, Any]],
                     het_specs: Sequence[HetSpec], cfg: ServingConfig,
                     N: int, trials: int, seed: int,
                     rate_schedules: Optional[np.ndarray] = None,
                     backend: Optional[str] = None) -> List[MCReport]:
    """The serving analogue of ``Scheme.mc_grid``: one report per
    (grid point x offered load), loads innermost, ``extra["grid_point"]``
    marking the scenario row.  Each cell draws from its own
    ``default_rng([seed, g, load_index])`` so numbers are independent of
    which other cells run -- the engine seed discipline.

    ``backend`` picks the queueing engine (kwarg > ``cfg.backend`` >
    ``$REPRO_SERVING_BACKEND`` > ``"numpy"``); the ``jax`` backend runs
    every load of a cell as one jitted ``lax.scan`` dispatch
    (``repro.serving.scan``), the numpy default is this module's loop."""
    from .backends import get_serving_backend, resolve_serving_backend

    name = resolve_serving_backend(
        backend if backend is not None else cfg.backend)
    sweep = get_serving_backend(name).sweep
    reports: List[MCReport] = []
    for g, het in enumerate(het_specs):
        sched = (None if rate_schedules is None
                 else np.asarray(rate_schedules[g], dtype=np.float64))
        rows = sweep(het, scheme_name, params, cfg, N, trials,
                     int(seed), g, sched)
        for rep in rows:
            rep.extra["grid_point"] = float(g)
        reports.extend(rows)
    return reports
