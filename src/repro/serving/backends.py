"""Pluggable serving-engine backends (``SERVING_BACKENDS``).

The sampler-backend discipline (``repro.core.samplers.SAMPLER_BACKENDS``)
applied to the queueing plane: the slotted numpy loop in ``engine.py``
stays the exact int64-conservation oracle, and the ``jax`` backend
(``repro.serving.scan``) compiles the whole per-slot step as ONE jitted
``lax.scan`` over slots with the ``loads`` sweep batched as extra
trial-block rows -- one dispatch per (policy, schedule) cell produces the
whole load-vs-latency curve.

A backend's unit of work is the *sweep*: every load of one
``(het, scheme, rate_schedule)`` cell, returning one ``MCReport`` per
load in ``cfg.loads`` order.  The numpy sweep reproduces the historical
``run_serving_grid`` per-load loop bit-for-bit (``default_rng([seed, g,
load_index])`` per cell); registering a new backend makes it inherit the
conformance battery in ``tests/test_serving.py`` automatically.

Resolution order is kwarg > ``$REPRO_SERVING_BACKEND`` > ``"numpy"``
(``resolve_serving_backend``); like the sampler knob, an explicit
``"numpy"`` is indistinguishable from the default and defers to the
environment.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List

from repro.core.registry import Registry

__all__ = [
    "SERVING_BACKENDS", "SERVING_ENV", "ServingBackend",
    "register_serving_backend", "get_serving_backend",
    "list_serving_backends", "resolve_serving_backend",
    "serving_backend_available",
]

SERVING_ENV = "REPRO_SERVING_BACKEND"


@dataclass(frozen=True)
class ServingBackend:
    """One queueing engine: ``sweep(het, scheme_name, params, cfg, N,
    trials, seed, grid_index, rate_schedule)`` -> ``[MCReport]``, one per
    load in ``cfg.loads`` order.  ``shards`` marks engines that split the
    stacked (load x trial) rows over an active grid mesh (so
    ``compile_plan`` may lift the serving ``devices=1`` pin)."""

    name: str
    sweep: Callable[..., List]
    description: str = ""
    shards: bool = False
    available: Callable[[], bool] = field(default=lambda: True, repr=False)


SERVING_BACKENDS: Registry[ServingBackend] = Registry("serving backend")


def register_serving_backend(backend: ServingBackend,
                             aliases=()) -> ServingBackend:
    return SERVING_BACKENDS.register(backend.name, backend, aliases=aliases)


def get_serving_backend(name: str) -> ServingBackend:
    return SERVING_BACKENDS.get(name)


def list_serving_backends() -> List[str]:
    return SERVING_BACKENDS.names()


def serving_backend_available(name: str) -> bool:
    return SERVING_BACKENDS.get(name).available()


def resolve_serving_backend(name: str = None) -> str:
    """Canonical backend name: kwarg > ``$REPRO_SERVING_BACKEND`` >
    ``"numpy"``.  An explicit ``"numpy"`` defers to the environment (the
    sampler-backend semantics: the default is a preference, not a pin).
    Unknown names raise ``KeyError`` listing the registry; registered but
    unavailable ones raise ``RuntimeError``."""
    if name is None or name == "numpy":
        name = os.environ.get(SERVING_ENV) or "numpy"
    backend = SERVING_BACKENDS.get(name)
    if not backend.available():
        raise RuntimeError(
            f"serving backend {backend.name!r} is registered but "
            f"unavailable on this host (is jax importable?)")
    return backend.name


# ---------------------------------------------------------------------------
# the two built-in engines
# ---------------------------------------------------------------------------

def _numpy_sweep(het, scheme_name, params, cfg, N, trials, seed,
                 grid_index, rate_schedule):
    """The historical ``run_serving_grid`` inner loop, verbatim: one
    ``simulate_serving`` call per load with its own
    ``default_rng([seed, g, li])`` stream -- the bit-exact oracle."""
    import numpy as np

    from .engine import simulate_serving

    reports = []
    for li, load in enumerate(cfg.loads):
        rng = np.random.default_rng(
            [int(seed) & (2 ** 63 - 1), int(grid_index), li])
        reports.append(simulate_serving(
            het, scheme_name, params, cfg, N, float(load), trials, rng,
            rate_schedule=rate_schedule))
    return reports


def _jax_sweep(het, scheme_name, params, cfg, N, trials, seed,
               grid_index, rate_schedule):
    from .scan import scan_sweep
    return scan_sweep(het, scheme_name, params, cfg, N, trials, seed,
                      grid_index, rate_schedule)


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


register_serving_backend(ServingBackend(
    name="numpy",
    sweep=_numpy_sweep,
    description="slotted numpy loop; exact int64-conservation oracle"))

register_serving_backend(ServingBackend(
    name="jax",
    sweep=_jax_sweep,
    description="one jitted lax.scan over slots; loads batched as rows, "
                "shape-bucketed, shard_map over the grid mesh",
    shards=True,
    available=_jax_available))
