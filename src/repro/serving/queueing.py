"""Closed-form M/M/K queueing results (Erlang-C) for engine validation.

The serving engine is a slotted-time simulator; these are the textbook
steady-state formulas it is sanity-checked against in the one regime
where an exact answer exists: homogeneous workers, 1-unit jobs, Poisson
arrivals, and a work-conserving pooled dispatch policy (work exchange
with per-slot rebalancing).  In that regime the number-in-system process
is exactly M/M/K up to the O(slot_dt) discretization, so the simulated
mean sojourn must match ``mmk_sojourn`` within MC + slotting tolerance
(``tests/test_serving.py``).

Not to be confused with ``repro.core.erlang`` -- that module computes
order statistics of Erlang *completion times* (paper Section 3); this
one is queueing theory for the arrival plane.
"""
from __future__ import annotations

import math

__all__ = ["erlang_b", "erlang_c", "mmk_wait", "mmk_sojourn",
           "mm1_sojourn"]


def erlang_b(K: int, a: float) -> float:
    """Erlang-B blocking probability for ``K`` servers at offered load
    ``a = lambda / mu`` (in Erlangs), by the standard stable recursion
    ``B(0) = 1,  B(j) = a B(j-1) / (j + a B(j-1))``."""
    if K < 1:
        raise ValueError("erlang_b needs K >= 1")
    if a < 0:
        raise ValueError("offered load must be >= 0")
    b = 1.0
    for j in range(1, K + 1):
        b = a * b / (j + a * b)
    return b


def erlang_c(K: int, a: float) -> float:
    """Erlang-C probability that an arriving job must wait (M/M/K with
    ``a = lambda / mu < K``): ``C = K B / (K - a (1 - B))``."""
    if not a < K:
        raise ValueError(f"M/M/K needs offered load a < K; got a={a}, K={K}")
    b = erlang_b(K, a)
    return K * b / (K - a * (1.0 - b))


def mmk_wait(lam: float, mu: float, K: int) -> float:
    """Mean queueing delay (excluding service) of M/M/K:
    ``W_q = C(K, a) / (K mu - lambda)``."""
    if lam >= K * mu:
        return math.inf
    return erlang_c(K, lam / mu) / (K * mu - lam)


def mmk_sojourn(lam: float, mu: float, K: int) -> float:
    """Mean sojourn (wait + service) of M/M/K: ``W = W_q + 1/mu``."""
    return mmk_wait(lam, mu, K) + 1.0 / mu


def mm1_sojourn(lam: float, mu: float) -> float:
    """Mean sojourn of M/M/1: ``1 / (mu - lambda)`` (equals
    ``mmk_sojourn(lam, mu, 1)``; kept for readable tests)."""
    if lam >= mu:
        return math.inf
    return 1.0 / (mu - lam)
