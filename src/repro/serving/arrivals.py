"""Arrival processes behind a small registry -- the demand axis.

Exactly the ``SCHEME_REGISTRY`` / ``SCENARIO_REGISTRY`` pattern, applied
to *who sends jobs and when*:

    from repro.serving import get_arrival, list_arrivals

    arr = get_arrival("poisson")                  # open-loop Poisson
    arr = get_arrival("trace", epochs=12)         # corpus-modulated
    arr = get_arrival("closed_loop", think_slots=4)

Every process is a frozen dataclass (a value -- all randomness flows
through the engine's rng) exposing ``job_counts(trials, slots,
jobs_per_slot, rng) -> (trials, slots) int64``, the number of jobs
offered per slot per trial.  ``jobs_per_slot`` is the *mean* demand the
engine derives from the swept offered load; open-loop processes modulate
it, the closed-loop process ignores it (demand comes from a finite
client population instead -- the engine reads ``closed_loop`` /
``population_for`` / ``think_slots`` and drives resubmission itself).

``trace`` reuses the measured-trace corpora of ``repro.scenarios.traces``
as *demand* profiles: the corpus' per-epoch mean rate across workers,
normalized to mean 1 and stretched over the slot horizon, multiplies the
Poisson intensity -- measured diurnal burstiness for free, keyed by the
immutable corpus name (so it hashes like the ``trace_corpus`` scenario
family does).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Type

import numpy as np

from repro.core.registry import Registry

ARRIVAL_REGISTRY: Registry[Type["ArrivalProcess"]] = \
    Registry("arrival process")


def register_arrival(name: str):
    """Class decorator: key an ArrivalProcess subclass under ``name``."""
    def deco(cls: Type["ArrivalProcess"]) -> Type["ArrivalProcess"]:
        ARRIVAL_REGISTRY.register(name, cls)
        cls.name = name
        return cls
    return deco


def list_arrivals() -> List[str]:
    return ARRIVAL_REGISTRY.names()


def get_arrival(name: str, **params) -> "ArrivalProcess":
    """Instantiate a registered arrival process; unknown names or params
    fail loudly (the ``validate_backend`` discipline)."""
    cls = ARRIVAL_REGISTRY.get(name)
    try:
        return cls(**params)
    except TypeError:
        allowed = [f.name for f in dataclasses.fields(cls)]
        raise KeyError(f"bad params {sorted(params)} for arrival process "
                       f"{name!r}; allowed {allowed}") from None


class ArrivalProcess:
    """Common surface of every arrival process (see module docstring)."""

    name: str = "abstract"
    closed_loop: bool = False

    def job_counts(self, trials: int, slots: int, jobs_per_slot: float,
                   rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


@register_arrival("poisson")
@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Open-loop memoryless stream: ``Poisson(jobs_per_slot)`` per slot."""

    def job_counts(self, trials, slots, jobs_per_slot, rng):
        return rng.poisson(jobs_per_slot, size=(trials, slots))


@register_arrival("trace")
@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Poisson stream whose intensity follows a measured-trace corpus.

    The demand profile is the corpus' per-epoch mean rate over all
    workers (epochs ``epoch_start .. epoch_start + epochs``, wrapping),
    normalized to mean 1 so the swept offered load stays the *average*
    load; epochs are stretched uniformly over the slot horizon.
    """

    corpus: Optional[str] = None        # None -> the committed default
    epoch_start: int = 0
    epochs: Optional[int] = None

    def profile(self, slots: int) -> np.ndarray:
        """(slots,) intensity multipliers, mean exactly 1."""
        from repro.scenarios.traces import DEFAULT_CORPUS, load_corpus
        corpus = load_corpus(self.corpus or DEFAULT_CORPUS)
        window = corpus.window(corpus.workers, 0, int(self.epoch_start),
                               self.epochs)
        per_epoch = window.mean(axis=0)              # (E,) mean rate
        prof = per_epoch / per_epoch.mean()
        E = prof.size
        rows = np.minimum(np.arange(slots) * E // max(slots, 1), E - 1)
        stretched = prof[rows]
        return stretched / stretched.mean()

    def job_counts(self, trials, slots, jobs_per_slot, rng):
        lam = jobs_per_slot * self.profile(slots)
        return rng.poisson(np.broadcast_to(lam, (trials, slots)))


@register_arrival("burst")
@dataclasses.dataclass(frozen=True)
class BurstArrivals(ArrivalProcess):
    """Open-loop burst-then-idle stream: the whole offered demand lands
    in the first ``burst_frac`` of the horizon (intensity ``1 /
    burst_frac`` there, silence after; mean exactly 1, so the swept load
    stays the average).  The adversarial shape for queue mechanics --
    occupancy spikes to the buffer cap then drains to nothing, which is
    exactly what the engine's ``q_hi`` compaction regression test
    needs."""

    burst_frac: float = 0.1

    def __post_init__(self):
        if not 0.0 < float(self.burst_frac) <= 1.0:
            raise ValueError("burst_frac must be in (0, 1]")

    def job_counts(self, trials, slots, jobs_per_slot, rng):
        cut = max(1, int(round(float(self.burst_frac) * slots)))
        lam = np.zeros(slots, dtype=np.float64)
        lam[:cut] = jobs_per_slot * slots / cut
        return rng.poisson(np.broadcast_to(lam, (trials, slots)))


@register_arrival("closed_loop")
@dataclasses.dataclass(frozen=True)
class ClosedLoopArrivals(ArrivalProcess):
    """Finite client population with think time (interactive workload).

    Each client submits one job, thinks ``think_slots`` slots after the
    job completes, then resubmits -- the engine drives the resubmission
    loop.  ``population=None`` derives the population from the swept
    load knob as ``max(1, round(load * K))`` clients (load = clients
    per worker), so load sweeps stay meaningful in closed loop.
    """

    closed_loop = True
    population: Optional[int] = None
    think_slots: int = 0

    def __post_init__(self):
        if self.population is not None and int(self.population) < 1:
            raise ValueError("closed_loop population must be >= 1")
        if int(self.think_slots) < 0:
            raise ValueError("think_slots must be >= 0")

    def population_for(self, load: float, K: int) -> int:
        if self.population is not None:
            return int(self.population)
        return max(1, int(round(load * K)))

    def job_counts(self, trials, slots, jobs_per_slot, rng):
        # demand is driven by the engine's resubmission loop, not a
        # precomputed stream
        return np.zeros((trials, slots), dtype=np.int64)


__all__ = [
    "ARRIVAL_REGISTRY", "ArrivalProcess", "register_arrival", "get_arrival",
    "list_arrivals", "PoissonArrivals", "TraceArrivals", "BurstArrivals",
    "ClosedLoopArrivals",
]
