"""Dispatch policies: every registered scheme, recast for arrivals.

The batch schemes answer "how do I split ONE batch of N units and when
is it done?"; under continuous arrivals the same two decisions recur per
job: *placement* (which workers get how many of this job's units) and
*completion* (when do the served shards constitute a finished job).  A
``DispatchPolicy`` is exactly that pair, derived from a scheme instance:

    placement               completion              flags
    ------------------------------------------------------------------
    oracle       proportional (re-dealt)  drain     exchanges, free comm
    work_exchange proportional (re-dealt) drain     exchanges
    work_exchange_unknown  by online estimates      exchanges, estimates
    fixed / trace_replay   proportional, static     drain
    uniform      equal, static            drain
    mds          ceil(u/L) coded shards   L shards done     purge
    het_mds      HCMM loads (r * u total) loads cover u     purge
    hedged       K-1 primaries + spare    primaries + min(replica) purge
    gradient_coded  FR groups             every group has a finisher purge
    (anything else) scheme.initial_sizes  drain / served >= u [#]_

.. [#] the generic fallback keys off ``Scheme.redundant`` -- so a future
   ``@register_scheme`` inherits the serving engine (and its test
   battery) with no adapter at all.

Policies are trials-batched like the engine: ``place`` maps the units of
M admitted jobs to an ``(M, K)`` integer share matrix; ``done_mask``
maps the engine's ``(T, Q, K)`` remaining/shipped state to per-job
completion.  Schemes that *exchange* set ``exchanges`` and the engine
re-deals leftover units across workers every ``exchange_every`` slots
(counted into ``n_comm`` unless the policy is the free-coordination
oracle); coded schemes set ``purge`` and the engine cancels leftover
shards on completion.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import numpy as np

from repro.core.schemes import Scheme, get_scheme

__all__ = ["DispatchPolicy", "dispatch_policy", "lr_round_rows",
           "POLICY_ADAPTERS", "register_policy"]


def lr_round_rows(weights: np.ndarray, totals: np.ndarray) -> np.ndarray:
    """Row-wise largest-remainder rounding: split ``totals[m]`` units
    proportionally to ``weights[m]`` into non-negative integers that sum
    exactly to ``totals[m]`` (the batched form of
    ``repro.core.assignment.largest_remainder_round``).  All-zero weight
    rows fall back to a uniform split."""
    w = np.asarray(weights, dtype=np.float64)
    totals = np.asarray(totals, dtype=np.int64)
    s = w.sum(axis=1, keepdims=True)
    w = np.where(s > 0, w, 1.0)
    shares = w / w.sum(axis=1, keepdims=True) * totals[:, None]
    base = np.floor(shares).astype(np.int64)
    deficit = totals - base.sum(axis=1)
    order = np.argsort(-(shares - base), axis=1, kind="stable")
    bump = np.zeros_like(base)
    take = (np.arange(w.shape[1])[None, :] < deficit[:, None])
    np.put_along_axis(bump, order, take.astype(np.int64), axis=1)
    return base + bump


class DispatchPolicy:
    """Scheme -> (placement, completion) adapter; see module docstring.

    ``place(units, believed)`` returns the ``(M, K)`` integer shares for
    M admitted jobs (``believed`` is the ``(M, K)`` rate belief: nominal
    rates, or the per-trial online estimates for estimate-driven
    policies) -- optionally ``(shares, aux)`` with a per-job int64 tag
    the engine stores and hands back to ``done_mask``.
    ``done_mask(R, S0, units, active, aux)`` marks finished jobs from
    the remaining/shipped unit state.
    """

    exchanges = False        # engine re-deals leftovers periodically
    count_comm = True        # re-dealt units count into n_comm
    purge = False            # cancel leftover shards on completion
    uses_estimates = False   # placement/re-deal follow online estimates

    def __init__(self, scheme: Scheme, het, N: int):
        self.scheme = scheme
        self.het = het
        self.K = het.K
        self.N = int(N)

    def place(self, units: np.ndarray, believed: np.ndarray):
        raise NotImplementedError

    def done_mask(self, R, S0, units, active, aux) -> np.ndarray:
        raise NotImplementedError

    @staticmethod
    def _drain(R, active):
        return active & (R.sum(axis=2) == 0)


POLICY_ADAPTERS: Dict[str, Type[DispatchPolicy]] = {}


def register_policy(*scheme_names):
    """Class decorator: adapt the named schemes with this policy."""
    def deco(cls: Type[DispatchPolicy]) -> Type[DispatchPolicy]:
        for name in scheme_names:
            if name in POLICY_ADAPTERS:
                raise ValueError(f"policy for scheme {name!r} already "
                                 f"registered")
            POLICY_ADAPTERS[name] = cls
        return cls
    return deco


def dispatch_policy(scheme_name: str, params: dict, het,
                    N: int) -> DispatchPolicy:
    """Adapt a registered scheme (by name or alias) into its dispatch
    policy; schemes without a dedicated adapter get the generic one."""
    scheme = get_scheme(scheme_name, **(params or {}))
    cls = POLICY_ADAPTERS.get(scheme.name, GenericPolicy)
    return cls(scheme, het, N)


# ---------------------------------------------------------------------------
# exchange-class policies: proportional placement + periodic re-deal
# ---------------------------------------------------------------------------

@register_policy("work_exchange")
class ExchangePolicy(DispatchPolicy):
    """Work-exchange dispatch, rates known: place proportionally to the
    nominal rates; the engine re-deals ALL leftover units across workers
    every ``exchange_every`` slots (moved units -> ``n_comm``)."""

    exchanges = True

    def place(self, units, believed):
        lam = np.broadcast_to(self.het.lambdas, (units.size, self.K))
        return lr_round_rows(lam, units)

    def done_mask(self, R, S0, units, active, aux):
        return self._drain(R, active)


@register_policy("work_exchange_unknown")
class ExchangeUnknownPolicy(ExchangePolicy):
    """Work-exchange dispatch, rates unknown: placement and re-deals
    follow the engine's online served/busy-time estimates (prior 1.0),
    the serving-plane analogue of paper eq. 23."""

    uses_estimates = True

    def place(self, units, believed):
        return lr_round_rows(believed, units)


@register_policy("oracle")
class PooledPolicy(ExchangePolicy):
    """Theorem-1 style lower bound under arrivals: perfectly rebalanced
    every slot with FREE coordination -- the re-deal happens but moved
    units never count into ``n_comm``."""

    count_comm = False


# ---------------------------------------------------------------------------
# static uncoded policies
# ---------------------------------------------------------------------------

@register_policy("fixed", "trace_replay")
class StaticPolicy(DispatchPolicy):
    """Heterogeneity-aware static split: proportional once, never moved."""

    def place(self, units, believed):
        lam = np.broadcast_to(self.het.lambdas, (units.size, self.K))
        return lr_round_rows(lam, units)

    def done_mask(self, R, S0, units, active, aux):
        return self._drain(R, active)


@register_policy("uniform")
class UniformPolicy(StaticPolicy):
    """Heterogeneity-blind static split: u/K each."""

    def place(self, units, believed):
        return lr_round_rows(np.ones((units.size, self.K)), units)


# ---------------------------------------------------------------------------
# coded policies: redundancy instead of exchange
# ---------------------------------------------------------------------------

@register_policy("mds")
class MDSPolicy(DispatchPolicy):
    """(K, L) MDS dispatch: every worker gets a ceil(u/L) coded shard;
    the job decodes when any L shards drain, leftovers are cancelled.
    ``L=None`` resolves once per (het, mean job size) by the scheme's
    own MC sweep, pinned to the exact numpy sampler."""

    purge = True

    def __init__(self, scheme, het, N):
        super().__init__(scheme, het, N)
        if scheme.L is not None:
            self.L = int(scheme.L)
            if not 1 <= self.L <= het.K:
                raise ValueError(f"L must be in [1, {het.K}]; got {self.L}")
        else:
            from repro.core.schemes import mds_sweep_batched
            self.L = int(mds_sweep_batched(het, max(self.N, 1),
                                           scheme.opt_trials,
                                           np.random.default_rng(0),
                                           backend="numpy")[0])

    def place(self, units, believed):
        m = -(-units // self.L)                      # ceil(u / L)
        return np.broadcast_to(m[:, None], (units.size, self.K)).copy()

    def done_mask(self, R, S0, units, active, aux):
        decoded = ((S0 > 0) & (R == 0)).sum(axis=2) >= self.L
        return active & decoded


@register_policy("het_mds")
class CoverPolicy(DispatchPolicy):
    """HCMM-style heterogeneous coded dispatch: worker k gets a coded
    load proportional to its rate with aggregate redundancy r (total
    ceil(r u)); the job completes when the DRAINED workers' loads cover
    u.  Leftovers are cancelled."""

    purge = True

    def place(self, units, believed):
        lam = np.broadcast_to(self.het.lambdas, (units.size, self.K))
        total = np.ceil(self.scheme.redundancy
                        * units.astype(np.float64)).astype(np.int64)
        return lr_round_rows(lam, np.maximum(total, units))

    def done_mask(self, R, S0, units, active, aux):
        covered = (S0 * (R == 0)).sum(axis=2) >= units
        return active & covered


@register_policy("hedged")
class HedgedPolicy(DispatchPolicy):
    """Replication-on-slowest: the fastest worker is a hot spare
    mirroring the predicted straggler's shard; the job completes when
    every primary shard drains, the straggler's counting as done when
    either replica drains.  ``aux`` carries the per-job straggler id
    (-1 = no hedge: degenerate drain)."""

    purge = True

    def __init__(self, scheme, het, N):
        super().__init__(scheme, het, N)
        self.spare = (int(np.argmax(het.lambdas)) if het.K > 1 else -1)

    def place(self, units, believed):
        M = units.size
        shares = np.zeros((M, self.K), dtype=np.int64)
        if self.spare < 0:
            shares[:, 0] = units
            return shares, np.full(M, -1, dtype=np.int64)
        others = np.delete(np.arange(self.K), self.spare)
        lam_o = self.het.lambdas[others]
        prim = lr_round_rows(np.broadcast_to(lam_o, (M, self.K - 1)),
                             units)
        shares[:, others] = prim
        # straggler = lowest-rate worker that actually got load
        loaded = prim > 0
        key = np.where(loaded, lam_o[None, :], np.inf)
        strag_o = np.argmin(key, axis=1)
        has = loaded.any(axis=1)
        strag = np.where(has, others[strag_o], -1).astype(np.int64)
        rows = np.nonzero(has)[0]
        shares[rows, self.spare] = prim[rows, strag_o[rows]]
        return shares, strag

    def done_mask(self, R, S0, units, active, aux):
        if self.spare < 0:
            return self._drain(R, active)
        col = np.arange(self.K)
        prim = (col != self.spare)[None, None, :] & (S0 > 0)
        undrained = (prim & (R > 0)).sum(axis=2)
        idx = np.maximum(aux, 0)[..., None]
        strag_rem = np.take_along_axis(R, idx, axis=2)[..., 0]
        strag_undrained = (aux >= 0) & (strag_rem > 0)
        spare_drained = R[..., self.spare] == 0
        hedged_ok = ~strag_undrained | spare_drained
        done = (undrained - strag_undrained.astype(np.int64) == 0) \
            & hedged_ok
        return active & np.where(aux >= 0, done,
                                 R.sum(axis=2) == 0)


@register_policy("gradient_coded")
class GradientCodedPolicy(DispatchPolicy):
    """Fractional-repetition dispatch: workers form groups of s+1, the
    job's units split into one block per group, every group member
    serves a replica of its block; the job completes when every
    (non-empty) block has a drained replica.  Workers beyond the largest
    multiple of s+1 idle, exactly as in the batch scheme."""

    purge = True

    def __init__(self, scheme, het, N):
        super().__init__(scheme, het, N)
        self.s = int(scheme.s)
        self.K_eff = het.K - het.K % (self.s + 1)
        if self.K_eff < self.s + 1:
            raise ValueError(f"need >= {self.s + 1} workers for "
                             f"s={self.s}")
        self.groups = self.K_eff // (self.s + 1)

    def place(self, units, believed):
        M = units.size
        blocks = lr_round_rows(np.ones((M, self.groups)), units)
        shares = np.zeros((M, self.K), dtype=np.int64)
        shares[:, :self.K_eff] = np.repeat(blocks, self.s + 1, axis=1)
        return shares

    def done_mask(self, R, S0, units, active, aux):
        T, Q, _ = R.shape
        grouped = R[..., :self.K_eff].reshape(T, Q, self.groups,
                                              self.s + 1)
        covered = (grouped == 0).any(axis=3).all(axis=2)
        return active & covered


# ---------------------------------------------------------------------------
# generic fallback: any future scheme inherits the serving engine
# ---------------------------------------------------------------------------

class GenericPolicy(DispatchPolicy):
    """Adapter of last resort, from the base ``Scheme`` surface alone:
    placement is ``scheme.initial_sizes(het, u)`` per job; completion is
    drain for conservative schemes and served >= u (leftovers cancelled)
    for ``redundant`` ones."""

    def __init__(self, scheme, het, N):
        super().__init__(scheme, het, N)
        self.purge = bool(scheme.redundant)

    def place(self, units, believed):
        shares = np.zeros((units.size, self.K), dtype=np.int64)
        for m, u in enumerate(units):
            shares[m] = np.asarray(
                self.scheme.initial_sizes(self.het, int(u)), dtype=np.int64)
        return shares

    def done_mask(self, R, S0, units, active, aux):
        if self.scheme.redundant:
            return active & ((S0 - R).sum(axis=2) >= units)
        return self._drain(R, active)
