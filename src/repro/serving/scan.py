"""The ``jax`` serving backend: one jitted ``lax.scan`` per load sweep.

The numpy engine (``repro.serving.engine``) walks slots in a Python loop,
one ``simulate_serving`` call per offered load.  Here the whole per-slot
step -- arrivals offer -> deadline admission -> placement -> surplus-only
exchange / purge-on-decode -> FIFO service up to per-worker Poisson
budgets -> completion/SLO accounting -- is compiled as ONE ``lax.scan``
over slots, and the ``loads`` sweep rides along as extra trial-block
rows: state is ``(B, Q, K)`` int32/float32 with ``B = len(loads) *
trials``, so a single dispatch produces the whole load-vs-latency curve
for a policy.

Shape discipline is the PR-8 sampler machinery applied to queueing:

* ``Q`` (``max_queue_jobs``), ``K`` (``bucket_cols``), the slot horizon
  ``S`` and the batch ``B`` are padded to pow2 buckets (opt-out
  ``REPRO_SHAPE_BUCKETS=0``) so every ``ServingConfig`` shape family
  shares one compilation -- and one ``REPRO_JAX_CACHE_DIR`` entry.  The
  true sizes travel as traced scalars; the numpy engine's dynamic
  ``q_hi`` slicing becomes masking, padded slots are dead (``live``
  flag), padded workers carry rate 0.
* per-slot schedule rows (drifting / trace scenarios) are pre-stretched
  on the host and read by the scan as indexed xs loads, like the pallas
  drift kernel's direct row read.
* with a grid mesh active (``repro.core.samplers.grid_sharding``) the
  stacked (load x trial) rows shard over the 1-D mesh via ``shard_map``
  with per-device key streams, exactly like ``work_exchange_grid``.

The step body is sort- and scatter-free by construction: XLA CPU
serializes ``sort``/``scatter``/``cumsum`` (reduce-window) per row, and
at one call per slot they dominate the scan wall.  Instead the queue is
stored physically in FIFO order -- active jobs are a contiguous prefix,
admission appends at ``n_active``, completion compacts survivors left
via a comparison-count rank + gather -- so every FIFO prefix sum is a
log-step doubling cumsum and largest-remainder ranks come from
comparison counts.  All replacements are exact (same winners, same
integer sums), so the engine's numbers are bit-identical to the sorted
formulation's.

Three further measured wins shape the dispatch (each proven bitwise
against the plain formulation before landing):

* **host-drawn service budgets.** The per-(slot, row, worker) Poisson
  caps are state-independent, so they are drawn once on the host and
  streamed through the scan's xs instead of folding keys per slot.
  Fixed-units configs then carry *no* in-scan RNG at all -- which is
  what makes the sharded run bitwise equal to the single-device run --
  and only geometric job sizes still consume keys inside the step.
* **dead-state elision + two-tier queue width.** The carry is a dict
  pytree and policy state nobody reads (coded thresholds, hedged
  mirrors, per-job unit counts under fixed sizing) is dropped at trace
  time.  Per-step cost is ~linear in the physical queue width, so
  fixed-units sweeps first run every row at ``_TIER_Q`` physical rows
  with the TRUE admission cap, carry a per-row overflow flag, and
  re-run exactly the flagged rows at full width -- an exact splice
  (rng-free rows are independent), pinned bitwise by
  ``test_queue_tier_splice_bitwise``.
* **legacy CPU emitter.** Both jits pass
  ``compiler_options={"xla_cpu_use_thunk_runtime": False}``: the thunk
  runtime pays a per-op dispatch fee for every op in the scan body
  every slot, while the legacy emitter compiles the loop body to
  straight-line code (~1.8x on this engine; scoped per-jit so other
  benches keep the default runtime, and a no-op off CPU).

Policies run as scan-compatible pure functions (``_build_policy``),
derived from the same ``DispatchPolicy`` adapters the numpy loop uses;
adapters without a scan form (the ``GenericPolicy`` fallback for future
schemes) transparently drop to the numpy sweep, so registering a scheme
never breaks the jax backend.

Correctness contract: the int32 conservation ledger is carried through
the scan and the exact identity (shipped == served + cancelled +
backlog) is asserted on the final scanned ledger; sojourn percentiles
are recovered from an integer histogram over completion slot-counts
(sojourns are exact multiples of ``slot_dt``), so the host percentile
math is identical to the oracle's pooled path.  The conformance battery
pins this backend to the numpy oracle at 6 combined standard errors.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.samplers import (_shape_buckets_enabled, active_grid_mesh,
                                 bucket_cols)
from repro.core.schemes import MCReport
from repro.core.types import HetSpec

from .config import AUTO_SLOTS_PER_JOB, ServingConfig
from .policies import (CoverPolicy, ExchangePolicy, ExchangeUnknownPolicy,
                       GradientCodedPolicy, HedgedPolicy, MDSPolicy,
                       PooledPolicy, StaticPolicy, UniformPolicy,
                       dispatch_policy)

__all__ = ["scan_sweep"]

# physical queue rows for the first Q-tier pass (see scan_sweep); tests
# may pin it (sys.maxsize disables tiering) to compare against the
# single full-width dispatch
_TIER_Q = 16

def _pow2(n: int, floor: int = 1) -> int:
    n = max(int(n), int(floor))
    return 1 << (n - 1).bit_length()


# ---------------------------------------------------------------------------
# scan-compatible policy forms
# ---------------------------------------------------------------------------
# Exact-type dispatch (not isinstance): every concrete adapter maps to a
# (kind, static_args) pair; anything else -- GenericPolicy or a future
# adapter class -- returns None and the sweep falls back to numpy.

def _policy_static(policy) -> Optional[Tuple[str, Tuple]]:
    t = type(policy)
    if t in (ExchangePolicy, ExchangeUnknownPolicy, PooledPolicy,
             StaticPolicy):
        return ("prop", ())
    if t is UniformPolicy:
        return ("uniform", ())
    if t is MDSPolicy:
        return ("mds", (int(policy.L),))
    if t is CoverPolicy:
        return ("cover", ())
    if t is HedgedPolicy:
        return ("hedged", (int(policy.spare),))
    if t is GradientCodedPolicy:
        return ("gc", (int(policy.s), int(policy.K_eff),
                       int(policy.groups)))
    return None


def _cumsum(jnp, x, axis):
    """Inclusive cumsum by log-step doubling.  XLA CPU lowers
    ``jnp.cumsum`` to a reduce-window -- O(n^2) work per call, and the
    scan body pays it every slot -- while the doubling form is O(n log n)
    shifted adds, ~3x cheaper at the engine's (B, Q, K) shapes.  Exact
    for ints (addition is associative)."""
    n = x.shape[axis]
    d = 1
    while d < n:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (d, 0)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, n - d)
        x = x + jnp.pad(x[tuple(sl)], pad)
        d *= 2
    return x


def _lr_round_rows_jnp(jnp, w, totals, fallback):
    """``repro.serving.policies.lr_round_rows`` in jnp: row-wise
    largest-remainder rounding; all-zero weight rows fall back to a
    uniform split over ``fallback`` (the real-column mask, so padded
    workers never receive units).

    The remainder ranks come from a comparison-count (stable-descending
    position = #{larger} + #{equal at lower index}), not ``argsort``:
    bitwise-identical winners, and XLA CPU's serial per-row sort -- the
    scan body's dominant cost at (B, K) per arrival -- never runs."""
    s = w.sum(axis=1, keepdims=True)
    w = jnp.where(s > 0, w, fallback[None, :])
    shares = w / w.sum(axis=1, keepdims=True) \
        * totals[:, None].astype(jnp.float32)
    base = jnp.floor(shares).astype(jnp.int32)
    deficit = jnp.clip(totals - base.sum(axis=1), 0, None)
    frac = shares - base
    col = jnp.arange(w.shape[1])
    gt = frac[:, None, :] > frac[:, :, None]
    tie = (frac[:, None, :] == frac[:, :, None]) \
        & (col[None, None, :] < col[None, :, None])
    rank = (gt | tie).sum(axis=2)
    return base + (rank < deficit[:, None]).astype(jnp.int32)


def _build_policy(jnp, kind: str, pargs: Tuple, Kb: int):
    """(place, done) pure functions for one policy kind.

    ``place(u, believed, ctx) -> (shares (B, Kb) i32, ptag (B,) i32)``;
    ``done(R, S0, units, active, aux, ctx) -> (B, Qb) bool``.  ``ctx``
    carries the traced per-sweep values: ``lam_nom`` (Kb,), ``col_mask``
    (Kb,) bool, ``col_mask_f`` (Kb,) f32, ``redundancy`` scalar.
    """
    def drain(R, S0, units, active, aux, ctx):
        return R.sum(axis=2) == 0

    no_tag = None  # placement without a per-job tag

    if kind == "prop":
        def place(u, believed, ctx):
            return _lr_round_rows_jnp(jnp, believed, u,
                                      ctx["col_mask_f"]), no_tag
        return place, drain

    if kind == "uniform":
        def place(u, believed, ctx):
            w = jnp.broadcast_to(ctx["col_mask_f"][None, :],
                                 believed.shape)
            return _lr_round_rows_jnp(jnp, w, u, ctx["col_mask_f"]), no_tag
        return place, drain

    if kind == "mds":
        (L,) = pargs

        def place(u, believed, ctx):
            m = -(-u // L)
            shares = m[:, None] * ctx["col_mask"].astype(jnp.int32)[None, :]
            return shares, no_tag

        def done(R, S0, units, active, aux, ctx):
            return ((S0 > 0) & (R == 0)).sum(axis=2) >= L
        return place, done

    if kind == "cover":
        def place(u, believed, ctx):
            total = jnp.ceil(ctx["redundancy"]
                             * u.astype(jnp.float32)).astype(jnp.int32)
            return _lr_round_rows_jnp(
                jnp, believed, jnp.maximum(total, u),
                ctx["col_mask_f"]), no_tag

        def done(R, S0, units, active, aux, ctx):
            return (S0 * (R == 0)).sum(axis=2) >= units
        return place, done

    if kind == "hedged":
        (spare,) = pargs
        if spare < 0:                       # K == 1: degenerate drain
            def place(u, believed, ctx):
                shares = jnp.zeros((u.shape[0], Kb), dtype=jnp.int32)
                return shares.at[:, 0].set(u), no_tag
            return place, drain

        def place(u, believed, ctx):
            w = believed * ctx["col_mask_f"][None, :]
            w = w.at[:, spare].set(0.0)
            fb = ctx["col_mask_f"].at[spare].set(0.0)
            prim = _lr_round_rows_jnp(jnp, w, u, fb)
            loaded = prim > 0
            keyk = jnp.where(loaded, w, jnp.inf)
            strag = jnp.argmin(keyk, axis=1)
            has = loaded.any(axis=1)
            strag_val = jnp.take_along_axis(prim, strag[:, None],
                                            axis=1)[:, 0]
            shares = prim.at[:, spare].set(jnp.where(has, strag_val, 0))
            ptag = jnp.where(has, strag, -1).astype(jnp.int32)
            return shares, ptag

        def done(R, S0, units, active, aux, ctx):
            col = jnp.arange(Kb)
            prim = (col != spare)[None, None, :] & (S0 > 0)
            undrained = (prim & (R > 0)).sum(axis=2)
            idx = jnp.maximum(aux, 0)[..., None]
            strag_rem = jnp.take_along_axis(R, idx, axis=2)[..., 0]
            strag_und = (aux >= 0) & (strag_rem > 0)
            spare_drained = R[..., spare] == 0
            ok = (undrained - strag_und.astype(jnp.int32) == 0) \
                & (~strag_und | spare_drained)
            return jnp.where(aux >= 0, ok, R.sum(axis=2) == 0)
        return place, done

    if kind == "gc":
        s_, K_eff, groups = pargs

        def place(u, believed, ctx):
            w = jnp.ones((u.shape[0], groups), dtype=jnp.float32)
            blocks = _lr_round_rows_jnp(jnp, w, u,
                                        jnp.ones(groups, jnp.float32))
            shares = jnp.zeros((u.shape[0], Kb), dtype=jnp.int32)
            return shares.at[:, :K_eff].set(
                jnp.repeat(blocks, s_ + 1, axis=1)), no_tag

        def done(R, S0, units, active, aux, ctx):
            B, Q = R.shape[0], R.shape[1]
            grouped = R[..., :K_eff].reshape(B, Q, groups, s_ + 1)
            return (grouped == 0).any(axis=3).all(axis=2)
        return place, done

    raise AssertionError(f"unknown scan policy kind {kind!r}")


# ---------------------------------------------------------------------------
# the compiled engine, one entry per (policy x engine-config x mesh)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _compiled_sweep(static: Tuple):
    """Jitted sweep runner.  ``static`` pins everything that shapes the
    traced program -- policy kind + its static args, the engine flags,
    admission / unit-dist modes, the arrival fori trip count ``A_max``,
    and the active mesh (None = single device).  Array shapes retrace
    inside jit as usual; shape bucketing keeps them stable across
    ServingConfig families."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    (kind, pargs, exchanges, count_comm, purge, uses_est, admission,
     units_dist, A_max, Kb, mesh) = static
    place, done_fn = _build_policy(jnp, kind, pargs, Kb)

    # the block closes over nothing traced; every per-sweep value rides
    # in as an argument so shard_map can partition them explicitly
    def block(seeds, counts, caps, lam_sched, live, warm_f, do_exch,
              slot_idx, q_mask, lam_nom, scal):
        B = counts.shape[1]
        Qb = q_mask.shape[0]
        Hb = counts.shape[0] + 1
        key0 = jax.random.PRNGKey(seeds[0])
        dt, deadline_t, lam_sum, geo_p = (scal[0], scal[1], scal[2],
                                          scal[3])
        n_units = scal[4].astype(jnp.int32)
        k_cap = scal[5].astype(jnp.int32)
        redundancy = scal[6]
        col_mask = jnp.arange(Kb) < k_cap
        col_mask_f = col_mask.astype(jnp.float32)
        ctx = {"lam_nom": lam_nom, "col_mask": col_mask,
               "col_mask_f": col_mask_f, "redundancy": redundancy}

        def believed_of(served_w, busy_w):
            if uses_est:
                return ((served_w + 1.0) / (busy_w + 1.0)
                        ) * col_mask_f[None, :]
            return jnp.broadcast_to(lam_nom[None, :], (B, Kb)) \
                * col_mask_f[None, :]

        iota_q = jnp.arange(Qb)
        # true queue capacity (cfg.max_queue_jobs), NOT the physical row
        # count: under Q-tiering the state may carry fewer rows than the
        # configured cap, and admission must follow the configured cap so
        # a row that never outgrows the physical rows is bit-identical
        # to the full-width run (rows that would outgrow them raise the
        # ``over`` flag and are rerun at full width by the host)
        q_cap = scal[7].astype(jnp.int32)

        # dead-state elision (compile-time): S0 only feeds coded
        # completion tests, per-job units only exist under geometric
        # sizes (fixed sizes fold to the n_units scalar -- integer
        # products, so bit-identical), and the aux tag is hedged-only.
        # Dropping a dead (B, Q, K) array saves its write + compaction
        # gather every slot.
        need_S0 = (kind in ("mds", "cover")
                   or (kind == "hedged" and pargs[0] >= 0))
        need_aux = kind == "hedged" and pargs[0] >= 0
        need_units = units_dist != "fixed"

        def step(st, xs):
            st = dict(st)
            counts_s, cap_s, live_s, warm_s, exch_s, s = xs
            # geometric job sizes are the only in-scan randomness left
            # (service caps ride in as xs); fixed-units configs are
            # rng-free inside the scan, so single-device and sharded
            # runs are bitwise equal
            key_s = (jax.random.fold_in(key0, s)
                     if units_dist == "geometric" else None)
            R = st["R"]
            n_active = st["n"]
            # invariant: active jobs are the queue prefix, in FIFO order
            # (admission appends, completion compacts), and inactive rows
            # carry R == 0 (drain policies finish empty, coded policies
            # purge) -- so FIFO prefix sums are plain cumsums, no sort
            active = iota_q[None, :] < n_active[:, None]

            # -- 1. rebalance: surplus-only re-deal (exchange class) ----
            if exchanges:
                weights = believed_of(st["served_w"], st["busy_w"])
                b = R.sum(axis=1)
                targets = _lr_round_rows_jnp(jnp, weights,
                                             b.sum(axis=1), col_mask_f)
                surplus = jnp.clip(b - targets, 0, None)
                deficit = jnp.clip(targets - b, 0, None)
                behind = b[:, None, :] - _cumsum(jnp, R, 1)
                rm = jnp.clip(jnp.minimum(
                    R, surplus[:, None, :] - behind), 0, None)
                rm_q = rm.sum(axis=2)
                end = _cumsum(jnp, rm_q, 1)
                start = end - rm_q
                db = jnp.concatenate(
                    [jnp.zeros((B, 1), jnp.int32),
                     _cumsum(jnp, deficit, 1)], axis=1)
                add = jnp.clip(
                    jnp.minimum(end[:, :, None], db[:, None, 1:])
                    - jnp.maximum(start[:, :, None], db[:, None, :-1]),
                    0, None)
                apply = exch_s & live_s
                R = jnp.where(apply, R - rm + add, R)
                if count_comm:
                    st["moved_w"] = st["moved_w"] + jnp.where(
                        apply & warm_s,
                        add.sum(axis=(1, 2)).astype(jnp.float32), 0.0)
            st["R"] = R

            def _service(st, active):
                st = dict(st)
                # -- 4. service: per-worker FIFO up to Poisson budgets --
                # the queue is stored in FIFO order, so "work ahead of
                # me" is the exclusive prefix sum -- no per-slot sort;
                # the Poisson budgets are state-independent, so they are
                # drawn host-side and ride in as the ``cap_s`` xs row
                R = st["R"]
                bk_before = R.sum(axis=1)
                ahead = _cumsum(jnp, R, 1) - R
                srv = jnp.minimum(
                    R, jnp.clip(cap_s[:, None, :] - ahead, 0, None))
                R = R - srv
                srv_k = srv.sum(axis=1)
                st["served"] = st["served"] + srv_k.sum(axis=1)
                st["served_w"] = st["served_w"] + srv_k.astype(jnp.float32)
                st["busy_w"] = st["busy_w"] \
                    + dt * (bk_before > 0).astype(jnp.float32)

                # -- 5. completions ------------------------------------
                S0 = st.get("S0")
                units = st["units"] if need_units else n_units
                aux = st.get("aux")
                done = done_fn(R, S0, units, active, aux, ctx) \
                    & active & live_s
                if purge:
                    st["cancelled"] = st["cancelled"] \
                        + (R * done[:, :, None]).sum(axis=(1, 2))
                    R = jnp.where(done[:, :, None], 0, R)
                n_done = done.sum(axis=1)
                st["completed"] = st["completed"] + n_done
                wdone = done & warm_s
                if need_units:
                    st["goodput_w"] = st["goodput_w"] \
                        + (units * wdone).sum(axis=1)
                else:
                    st["goodput_w"] = st["goodput_w"] \
                        + n_units * wdone.sum(axis=1)
                soj = jnp.clip(s + 1 - st["arr"], 0, Hb - 1)
                st["hist"] = st["hist"].at[
                    jnp.arange(B)[:, None], soj].add(
                    wdone.astype(jnp.int32))

                # -- 6. compaction: survivors slide left, order kept ----
                # src index per destination via one-hot reduce (cheap);
                # a sort or scatter here would serialize on CPU like the
                # FIFO sort did
                keep = (active & ~done).astype(jnp.int32)
                kc = _cumsum(jnp, keep, 1)
                n_active = kc[:, -1]
                dest_ok = iota_q[None, :] < n_active[:, None]
                oh = (keep[:, None, :] > 0) \
                    & ((kc - keep)[:, None, :] == iota_q[None, :, None])
                src = (oh * iota_q[None, None, :]).sum(axis=2)
                gather = functools.partial(jnp.take_along_axis,
                                           indices=src, axis=1)
                st["R"] = jnp.where(
                    dest_ok[:, :, None],
                    jnp.take_along_axis(R, src[:, :, None], axis=1), 0)
                if need_S0:
                    st["S0"] = jnp.where(
                        dest_ok[:, :, None],
                        jnp.take_along_axis(S0, src[:, :, None],
                                            axis=1), 0)
                if need_units:
                    st["units"] = jnp.where(dest_ok, gather(units), 0)
                st["arr"] = jnp.where(dest_ok, gather(st["arr"]), 0)
                if need_aux:
                    st["aux"] = jnp.where(dest_ok, gather(aux), -1)
                st["n"] = n_active

                st["qd_sum"] = st["qd_sum"] + jnp.where(
                    warm_s, st["R"].sum(axis=(1, 2)).astype(jnp.float32),
                    0.0)
                st["su_w"] = st["su_w"] \
                    + jnp.where(warm_s, srv_k.sum(axis=1), 0)
                return st, None

            # -- 2+3. arrivals, admission, placement --------------------
            # a new job appends at position n_active (the active prefix
            # grows in arrival order -- first free slot == prefix end).
            # fixed job sizes admit a closed form for the whole slot's
            # arrivals: every candidate carries the same u, so capacity
            # and deadline admission are both "first a of counts_s
            # candidates" thresholds and the A_max fori collapses to one
            # masked write (bit-identical: the loop consumed no rng)
            if units_dist == "fixed":
                st["offered"] = st["offered"] \
                    + jnp.where(warm_s, counts_s, 0)
                a = jnp.minimum(counts_s, q_cap - n_active)
                if admission == "deadline":
                    room = deadline_t * lam_sum \
                        - R.sum(axis=(1, 2)).astype(jnp.float32)
                    a_dl = jnp.floor(
                        room / jnp.maximum(n_units, 1)).astype(jnp.int32)
                    a = jnp.minimum(a, jnp.clip(a_dl, 0, None))
                a = jnp.clip(a, 0, None)
                # exact overflow detection for Q-tiering: the admitted
                # prefix would not fit the physical rows, so this row's
                # trajectory diverges from the full-width run from here
                # on -- flag it for a full-width rerun
                st["over"] = st["over"] | (n_active + a > Qb)
                st["rejected"] = st["rejected"] \
                    + jnp.where(warm_s, counts_s - a, 0)
                u = jnp.full((B,), n_units, jnp.int32)
                believed = believed_of(st["served_w"], st["busy_w"])
                shares, ptag = place(u, believed, ctx)
                newm = (iota_q[None, :] >= n_active[:, None]) \
                    & (iota_q[None, :] < (n_active + a)[:, None])
                st["R"] = jnp.where(newm[:, :, None],
                                    shares[:, None, :], R)
                if need_S0:
                    st["S0"] = jnp.where(newm[:, :, None],
                                         shares[:, None, :], st["S0"])
                st["arr"] = jnp.where(newm, s, st["arr"])
                if need_aux:
                    if ptag is None:
                        ptag = jnp.full((B,), -1, jnp.int32)
                    st["aux"] = jnp.where(newm, ptag[:, None], st["aux"])
                st["n"] = n_active + a
                st["shipped"] = st["shipped"] + a * shares.sum(axis=1)
                active = iota_q[None, :] < st["n"][:, None]
                return _service(st, active)

            def arr_body(j, st2):
                st2 = dict(st2)
                n_act = st2["n"]
                cand = counts_s > j
                st2["offered"] = st2["offered"] + (cand & warm_s)
                kj = jax.random.fold_in(key_s, j)
                uu = jax.random.uniform(kj, (B,))
                u = jnp.maximum(jnp.ceil(
                    jnp.log1p(-uu) / jnp.log1p(-geo_p)), 1.0
                ).astype(jnp.int32)
                ok = cand & (n_act < q_cap)
                if admission == "deadline":
                    pred = (st2["R"].sum(axis=(1, 2)) + u
                            ).astype(jnp.float32) / lam_sum
                    ok = ok & (pred <= deadline_t)
                st2["rejected"] = st2["rejected"] + ((cand & ~ok) & warm_s)
                believed = believed_of(st2["served_w"], st2["busy_w"])
                shares, ptag = place(u, believed, ctx)
                onehot = (iota_q[None, :] == n_act[:, None]) \
                    & ok[:, None]
                st2["R"] = jnp.where(onehot[:, :, None],
                                     shares[:, None, :], st2["R"])
                if need_S0:
                    st2["S0"] = jnp.where(onehot[:, :, None],
                                          shares[:, None, :], st2["S0"])
                st2["units"] = jnp.where(onehot, u[:, None], st2["units"])
                st2["arr"] = jnp.where(onehot, s, st2["arr"])
                if need_aux:
                    if ptag is None:
                        ptag = jnp.full((B,), -1, jnp.int32)
                    st2["aux"] = jnp.where(onehot, ptag[:, None],
                                           st2["aux"])
                st2["n"] = n_act + ok.astype(jnp.int32)
                st2["shipped"] = st2["shipped"] \
                    + jnp.where(ok, shares.sum(axis=1), 0)
                return st2

            st = lax.fori_loop(0, A_max, arr_body, st)
            active = iota_q[None, :] < st["n"][:, None]
            return _service(st, active)

        zi = functools.partial(jnp.zeros, dtype=jnp.int32)
        zf = functools.partial(jnp.zeros, dtype=jnp.float32)
        st0 = {"R": zi((B, Qb, Kb)), "arr": zi((B, Qb)), "n": zi((B,)),
               "served_w": zf((B, Kb)), "busy_w": zf((B, Kb)),
               "shipped": zi((B,)), "served": zi((B,)),
               "cancelled": zi((B,)), "hist": zi((B, Hb)),
               "completed": zi((B,)), "goodput_w": zi((B,)),
               "moved_w": zf((B,)), "qd_sum": zf((B,)),
               "su_w": zi((B,)), "offered": zi((B,)),
               "rejected": zi((B,)), "over": jnp.zeros((B,), bool)}
        if need_S0:
            st0["S0"] = zi((B, Qb, Kb))
        if need_units:
            st0["units"] = zi((B, Qb))
        if need_aux:
            st0["aux"] = jnp.full((B, Qb), -1, jnp.int32)
        xs = (counts, caps, live, warm_f, do_exch, slot_idx)
        st, _ = lax.scan(step, st0, xs)
        backlog = st["R"].sum(axis=(1, 2))
        return (st["shipped"], st["served"], st["cancelled"], backlog,
                st["hist"], st["completed"], st["goodput_w"],
                st["moved_w"], st["qd_sum"], st["su_w"], st["offered"],
                st["rejected"], st["over"])

    # the scan body is hundreds of small (B, Q, K) ops: under the thunk
    # runtime each pays a per-op dispatch (thread-pool handoff) every
    # slot, which dominates the wall at these shapes.  The legacy
    # emitter compiles the whole while body to straight-line code --
    # measured ~1.8x on the fig_load sweep, bit-identical outputs.
    # Scoped to this jit only; grids with large arrays keep the default.
    _copts = {"xla_cpu_use_thunk_runtime": False}
    if mesh is None or mesh.size <= 1:
        return jax.jit(block, compiler_options=_copts)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    axis = mesh.axis_names[0]
    rows = P(axis)
    rep1 = P(None)
    sharded = shard_map(
        block, mesh=mesh,
        in_specs=(rows,                 # seeds: one stream per device
                  P(None, axis),        # counts (S, B): rows sharded
                  P(None, axis, None),  # caps (S, B, K): rows sharded
                  P(None, None),        # lam_sched, replicated
                  rep1, rep1, rep1, rep1,   # live / warm / exch / slot
                  rep1,                 # q_mask
                  rep1,                 # lam_nom
                  rep1),                # scal
        out_specs=(rows, rows, rows, rows, P(axis, None), rows, rows,
                   rows, rows, rows, rows, rows, rows),
        check_rep=False)
    return jax.jit(sharded, compiler_options=_copts)


# ---------------------------------------------------------------------------
# the sweep: host-side assembly around the compiled scan
# ---------------------------------------------------------------------------

def scan_sweep(het: HetSpec, scheme_name: str,
               params: Optional[Dict[str, Any]], cfg: ServingConfig,
               N: int, trials: int, seed: int, grid_index: int,
               rate_schedule: Optional[np.ndarray] = None
               ) -> List[MCReport]:
    """Every load of one (het, scheme, schedule) cell in ONE dispatch;
    returns one ``MCReport`` per load in ``cfg.loads`` order, extras
    keyed identically to the numpy oracle (plus ``serving_backend``)."""
    policy = dispatch_policy(scheme_name, dict(params or {}), het, N)
    arrival = cfg.build_arrival()
    if arrival.closed_loop:
        raise ValueError(
            "closed-loop arrivals are engine-driven (the resubmission "
            "ring needs per-slot completions); the jax serving backend "
            "cannot precompute the stream -- use the numpy backend")
    static_policy = _policy_static(policy)
    if static_policy is None:
        # adapter without a scan form (GenericPolicy / future classes):
        # future schemes keep working, honestly labelled
        from .backends import _numpy_sweep
        reports = _numpy_sweep(het, scheme_name, params, cfg, N, trials,
                               seed, grid_index, rate_schedule)
        for rep in reports:
            rep.extra["serving_backend"] = "numpy"
        return reports

    T, K, S = int(trials), het.K, int(cfg.slots)
    if T < 1:
        raise ValueError("trials must be >= 1")
    N = int(N)
    lam = np.asarray(het.lambdas, dtype=np.float64)
    lam_sum = float(het.lambda_sum)
    dt = (float(cfg.slot_dt) if cfg.slot_dt is not None
          else N / lam_sum / AUTO_SLOTS_PER_JOB)
    warm = int(float(cfg.warmup_frac) * S)
    window_t = (S - warm) * dt
    horizon_t = S * dt
    deadline_t = (None if cfg.deadline_slo is None
                  else float(cfg.deadline_slo) * N / lam_sum)
    loads = [float(x) for x in cfg.loads]
    L = len(loads)

    buckets = _shape_buckets_enabled()
    Sb = _pow2(S) if buckets else S
    Qb = _pow2(int(cfg.max_queue_jobs)) if buckets \
        else int(cfg.max_queue_jobs)
    Kb = bucket_cols(K)
    mesh = active_grid_mesh()
    D = int(mesh.size) if mesh is not None else 1
    B0 = L * T
    Bb = _pow2(B0, floor=8) if buckets else B0
    Bb = -(-Bb // D) * D                    # device-divisible rows

    # arrivals: each load keeps its own default_rng([seed, g, li]) stream
    # (the engine seed discipline -- cells are independent of the sweep)
    counts = np.zeros((Bb, Sb), dtype=np.int32)
    for li, load in enumerate(loads):
        rng = np.random.default_rng(
            [int(seed) & (2 ** 63 - 1), int(grid_index), li])
        jobs_per_slot = load * lam_sum * dt / N
        counts[li * T:(li + 1) * T, :S] = np.asarray(
            arrival.job_counts(T, S, jobs_per_slot, rng), dtype=np.int32)
    A_max = _pow2(int(counts.max()), floor=1)

    # per-slot true-rate rows, pre-stretched over the horizon; padded
    # worker columns carry rate 0 so Poisson budgets stay dead
    lam_pad = np.zeros(Kb, dtype=np.float32)
    lam_pad[:K] = lam
    lam_sched = np.broadcast_to(lam_pad, (Sb, Kb)).copy()
    if rate_schedule is not None:
        sched = np.asarray(rate_schedule, dtype=np.float64)
        if sched.ndim != 2 or sched.shape[1] != K:
            raise ValueError(f"rate_schedule must be (rounds, K={K}); "
                             f"got {sched.shape}")
        rows = np.minimum(np.arange(S) * sched.shape[0] // S,
                          sched.shape[0] - 1)
        lam_sched[:S, :K] = sched[rows].astype(np.float32)

    sl = np.arange(Sb)
    live = sl < S
    warm_f = (sl >= warm) & live
    every = int(cfg.exchange_every)
    do_exch = (policy.exchanges & (sl > 0) & (sl % every == 0) & live)
    q_mask = np.arange(Qb) < int(cfg.max_queue_jobs)

    rng_dev = np.random.default_rng(
        [int(seed) & (2 ** 63 - 1), int(grid_index), 2 ** 31])
    seeds = rng_dev.integers(0, 2 ** 32, size=(D,), dtype=np.uint32)

    # per-(slot, row, worker) Poisson service budgets: iid given the
    # rate schedule, so drawn up front on the host (dead slots and
    # padded workers carry rate 0 -> cap 0) and streamed in as xs
    rng_cap = np.random.default_rng(
        [int(seed) & (2 ** 63 - 1), int(grid_index), 2 ** 31 + 1])
    caps = rng_cap.poisson(
        lam_sched[:, None, :].astype(np.float64) * dt
        * live[:, None, None], size=(Sb, Bb, Kb)).astype(np.int32)

    redundancy = float(getattr(policy.scheme, "redundancy", 0.0) or 0.0)
    scal = np.array([dt,
                     np.inf if deadline_t is None else deadline_t,
                     lam_sum,
                     1.0 / max(N, 1),
                     float(N),
                     float(K),
                     redundancy,
                     float(cfg.max_queue_jobs)], dtype=np.float32)

    kind, pargs = static_policy
    fn = _compiled_sweep((kind, pargs, bool(policy.exchanges),
                          bool(policy.count_comm), bool(policy.purge),
                          bool(policy.uses_estimates),
                          str(cfg.admission), str(cfg.job_units_dist),
                          A_max, Kb, mesh))
    counts_T = np.ascontiguousarray(counts.T)

    def dispatch(Q_phys: int, counts_x, caps_x):
        qm = np.arange(Q_phys) < int(cfg.max_queue_jobs)
        out = fn(seeds, counts_x, caps_x, lam_sched, live, warm_f,
                 do_exch, sl.astype(np.int32), qm, lam_pad, scal)
        return [np.array(x) for x in out]   # copies: splice writes below

    # Q-tiering: per-step cost is ~linear in the physical queue rows,
    # but the configured cap covers worst-case bursts most rows never
    # reach.  Fixed-units configs are rng-free inside the scan and rows
    # are fully independent, so: run everything with _TIER_Q rows, then
    # rerun exactly the rows whose ``over`` flag shows the admitted
    # prefix outgrew them.  Spliced output is bit-identical to a direct
    # full-width run.  Geometric sizes draw per-(step, batch-position)
    # uniforms, so row subsets would shift their streams -- no tiering.
    use_tier = (str(cfg.job_units_dist) == "fixed" and buckets
                and Qb > _TIER_Q)
    if use_tier:
        out = dispatch(_TIER_Q, counts_T, caps)
        over = out[12].astype(bool)
        if over.any():
            rows = np.nonzero(over)[0]
            B2 = len(rows)
            B2b = _pow2(B2, floor=8) if buckets else B2
            B2b = -(-B2b // D) * D
            c2 = np.zeros((Sb, B2b), np.int32)
            c2[:, :B2] = counts_T[:, rows]
            k2 = np.zeros((Sb, B2b, Kb), np.int32)
            k2[:, :B2] = caps[:, rows, :]
            out2 = dispatch(Qb, c2, k2)
            for i in range(12):
                out[i][rows] = out2[i][:B2]
    else:
        out = dispatch(Qb, counts_T, caps)
    (shipped, served, cancelled, backlog, hist, completed_full,
     goodput_w, moved_w, qd_sum, served_units_w, offered,
     rejected) = out[:12]

    # exact conservation identity on the final scanned ledger
    ok = shipped[:B0] == (served[:B0] + cancelled[:B0] + backlog[:B0])
    if not ok.all():
        bad = int(np.nonzero(~ok)[0][0])
        raise AssertionError(
            f"work conservation violated in the scan backend "
            f"({scheme_name}, row {bad}): shipped {int(shipped[bad])} != "
            f"served {int(served[bad])} + cancelled {int(cancelled[bad])}"
            f" + backlog {int(backlog[bad])}")

    bin_vals = np.arange(Sb + 1, dtype=np.float64) * dt
    reports: List[MCReport] = []
    for li, load in enumerate(loads):
        r = slice(li * T, (li + 1) * T)
        h = hist[r]                              # (T, Hb) warm completions
        cw = h.sum(axis=1)
        sum_soj = (h * bin_vals[None, :]).sum(axis=1)
        per_trial = np.where(cw > 0, sum_soj / np.maximum(cw, 1),
                             horizon_t)
        pooled = h.sum(axis=0)
        if pooled.sum() > 0:
            soj_pool = np.repeat(bin_vals, pooled)
            p50, p95, p99 = (float(x) for x in
                             np.percentile(soj_pool,
                                           [50.0, 95.0, 99.0]))
            latency_censored = False
        else:
            p50 = p95 = p99 = horizon_t
            latency_censored = True
        censored = int((cw == 0).sum())
        extra: Dict[str, Any] = {
            "serving": 1.0,
            "offered_load": float(load),
            "slot_dt": float(dt),
            "p50": p50, "p95": p95, "p99": p99,
            "throughput_jobs": float(cw.mean() / window_t),
            "goodput_units": float(goodput_w[r].mean() / window_t),
            "occupancy": float(served_units_w[r].mean()
                               / (lam_sum * window_t)),
            "queue_depth": float(qd_sum[r].mean() / max(S - warm, 1)),
            "reject_rate": float(rejected[r].sum()
                                 / max(offered[r].sum(), 1)),
            "completed_jobs": float(completed_full[r].mean()),
            "units_admitted": float(shipped[r].mean()),
            "units_served": float(served[r].mean()),
            "units_cancelled": float(cancelled[r].mean()),
            "units_backlog": float(backlog[r].mean()),
        }
        if deadline_t is not None:
            extra["deadline_s"] = float(deadline_t)
            miss_bins = bin_vals > deadline_t + 1e-12
            extra["slo_miss_rate"] = float(
                (pooled * miss_bins).sum() / max(cw.sum(), 1))
        extra["latency_censored"] = 1.0 if latency_censored else 0.0
        if censored:
            extra["censored"] = float(censored)
            extra["censored_frac"] = float(censored / T)
        extra["serving_backend"] = "jax"
        per_cw = cw.astype(np.float64)
        reports.append(MCReport(
            scheme=policy.scheme.name, trials=T,
            t_comp=float(per_trial.mean()),
            t_comp_std=float(per_trial.std()),
            iterations=float(per_cw.mean()),
            iterations_std=float(per_cw.std()),
            n_comm=float(moved_w[r].mean()),
            n_comm_std=float(moved_w[r].std()),
            extra=extra))
    return reports
