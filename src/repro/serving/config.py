"""``ServingConfig``: the streaming-arrival axis of an ExperimentSpec.

A frozen, JSON-lossless value (the ``SchemeSpec`` discipline: params as
sorted key/value tuples, strict ``from_dict``) that turns a batch
experiment into a load sweep: attach one to ``ExperimentSpec(serving=)``
and every scheme task runs through the slotted queueing engine at each
offered load instead of through ``Scheme.mc_grid`` -- one ``MCReport``
per (grid point x load level), latency percentiles in ``extra``.

Specs WITHOUT a serving config serialize exactly as before (the key is
omitted when ``None``), so every pre-PR-6 ``spec_hash`` and store
address survives.

Knobs:

``loads``
    Offered load sweep, as fractions of the cluster's aggregate service
    capacity ``lambda_sum`` (0.85 = jobs arrive at 85% of what the
    cluster can serve).  In closed loop, load = clients per worker.
``job_units_dist``
    Per-job unit counts: ``"fixed"`` (every job is exactly N units) or
    ``"geometric"`` (mean N, heavy-ish tail).  N comes from the spec.
``slots`` / ``slot_dt`` / ``warmup_frac``
    Horizon, slot width in seconds (``None`` = auto: ~40 slots per
    pooled job service time), and the warmup fraction excluded from
    metrics.
``deadline_slo``
    SLO deadline in multiples of the pooled ideal sojourn ``N /
    lambda_sum`` (scale-free across grid points); ``None`` disables
    SLO-miss accounting.
``admission``
    ``"queue"`` rejects only on buffer overflow; ``"deadline"`` also
    rejects jobs whose predicted sojourn (backlog / lambda_sum) already
    exceeds the deadline -- load shedding instead of late completions.
``max_queue_jobs`` / ``exchange_every``
    Buffer capacity (jobs, per trial) and the rebalance period (slots)
    for exchange-class dispatch policies.
``backend``
    The queueing engine (``SERVING_BACKENDS``): ``"numpy"`` = the exact
    slotted oracle loop, ``"jax"`` = one jitted ``lax.scan`` per load
    sweep.  The key is OMITTED from ``to_dict`` at the default, so every
    pre-backend spec hash and store address survives; the default also
    defers to ``$REPRO_SERVING_BACKEND`` at resolution time
    (``resolve_backend``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

from .arrivals import get_arrival, list_arrivals

_ADMISSIONS = ("queue", "deadline")
_UNIT_DISTS = ("fixed", "geometric")

# auto slot_dt: this many slots per pooled job service time N/lambda_sum
AUTO_SLOTS_PER_JOB = 40.0


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """The arrival/queueing axis as one hashable value."""

    loads: Tuple[float, ...] = (0.5, 0.8)
    arrival: str = "poisson"
    arrival_params: Tuple[Tuple[str, Any], ...] = ()
    job_units_dist: str = "fixed"
    slots: int = 1000
    slot_dt: Optional[float] = None
    warmup_frac: float = 0.25
    deadline_slo: Optional[float] = None
    admission: str = "queue"
    max_queue_jobs: int = 64
    exchange_every: int = 1
    backend: str = "numpy"

    def __post_init__(self):
        object.__setattr__(self, "loads",
                           tuple(float(x) for x in self.loads))
        if isinstance(self.arrival_params, Mapping):
            items = self.arrival_params.items()
        else:
            items = tuple(self.arrival_params)
        object.__setattr__(self, "arrival_params",
                           tuple(sorted((str(k), v) for k, v in items)))
        if not self.loads or any(x <= 0 for x in self.loads):
            raise ValueError("loads must be a non-empty tuple of positive "
                             "offered-load fractions")
        if self.job_units_dist not in _UNIT_DISTS:
            raise ValueError(f"job_units_dist must be one of {_UNIT_DISTS}; "
                             f"got {self.job_units_dist!r}")
        if self.admission not in _ADMISSIONS:
            raise ValueError(f"admission must be one of {_ADMISSIONS}; "
                             f"got {self.admission!r}")
        if self.admission == "deadline" and self.deadline_slo is None:
            raise ValueError("admission='deadline' needs deadline_slo")
        if self.deadline_slo is not None and self.deadline_slo <= 0:
            raise ValueError("deadline_slo must be positive")
        if int(self.slots) <= 0:
            raise ValueError("slots must be positive")
        if self.slot_dt is not None and float(self.slot_dt) <= 0:
            raise ValueError("slot_dt must be positive (or None for auto)")
        if not 0.0 <= float(self.warmup_frac) < 1.0:
            raise ValueError("warmup_frac must be in [0, 1)")
        if int(self.max_queue_jobs) <= 0:
            raise ValueError("max_queue_jobs must be positive")
        if int(self.exchange_every) <= 0:
            raise ValueError("exchange_every must be positive")
        # fail at construction, not mid-run: unknown arrival names/params
        # raise KeyError listing the registry (validate_backend discipline)
        get_arrival(self.arrival, **self.arrival_params_dict)
        # same discipline for the engine name (availability is checked
        # at resolution time, not here -- a spec naming "jax" must stay
        # constructible on a host without jax)
        from .backends import SERVING_BACKENDS
        SERVING_BACKENDS.get(self.backend)

    def resolve_backend(self) -> str:
        """The engine this config runs on: the explicit field, with the
        ``"numpy"`` default deferring to ``$REPRO_SERVING_BACKEND``."""
        from .backends import resolve_serving_backend
        return resolve_serving_backend(self.backend)

    @property
    def arrival_params_dict(self) -> Dict[str, Any]:
        return dict(self.arrival_params)

    def build_arrival(self):
        return get_arrival(self.arrival, **self.arrival_params_dict)

    # -- serialization (the dict is the hash input; ``backend`` is
    # omitted at its default so pre-backend hashes survive) -----------------

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "loads": [float(x) for x in self.loads],
            "arrival": self.arrival,
            "arrival_params": self.arrival_params_dict,
            "job_units_dist": self.job_units_dist,
            "slots": int(self.slots),
            "slot_dt": (None if self.slot_dt is None
                        else float(self.slot_dt)),
            "warmup_frac": float(self.warmup_frac),
            "deadline_slo": (None if self.deadline_slo is None
                             else float(self.deadline_slo)),
            "admission": self.admission,
            "max_queue_jobs": int(self.max_queue_jobs),
            "exchange_every": int(self.exchange_every),
        }
        if self.backend != "numpy":
            d["backend"] = self.backend
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ServingConfig":
        allowed = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - allowed
        if unknown:
            raise KeyError(f"unknown serving key(s) {sorted(unknown)}; "
                           f"allowed {sorted(allowed)} (registered arrival "
                           f"processes: {list_arrivals()})")
        kwargs = dict(d)
        if "loads" in kwargs:
            kwargs["loads"] = tuple(kwargs["loads"])
        if "arrival_params" in kwargs:
            kwargs["arrival_params"] = tuple(kwargs["arrival_params"]
                                             .items())
        return cls(**kwargs)


__all__ = ["ServingConfig", "AUTO_SLOTS_PER_JOB"]
