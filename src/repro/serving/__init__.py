"""Streaming-arrival serving: the fourth subsystem.

The paper's question is one batch of N units over K heterogeneous
workers; this package asks the production question behind it -- jobs
arrive continuously, what are p50/p99 latency, sustainable throughput,
and SLO-miss rate per scheme, per offered load?  (The regime of
Behrouzi-Far & Soljanin, arXiv:1808.02838, with HCMM-style loads from
arXiv:1701.05973 as one of the contenders.)

Three registries already cover *how work is split* (schemes), *how
samples are drawn* (sampler backends), and *what the cluster looks like*
(scenario families); ``ARRIVAL_REGISTRY`` adds *who sends jobs and
when*.  Every registered scheme is recast as a dispatch policy
(``repro.serving.policies``) and run through a pluggable queueing engine
behind ``SERVING_BACKENDS`` (``repro.serving.backends``): the slotted
numpy loop (``repro.serving.engine``) is the exact conservation oracle,
the ``jax`` backend (``repro.serving.scan``) compiles the whole load
sweep as one jitted ``lax.scan`` dispatch and shards the stacked
(load x trial) rows over the grid mesh.  ``repro.serving.queueing``
holds the closed-form M/M/K results both engines are validated against.

Wiring: attach ``ServingConfig`` to ``ExperimentSpec(serving=...)`` and
the ordinary ``run_experiment`` path -- compile, store, CLI -- sweeps
offered load instead of running single-batch MC.
"""
from .arrivals import (ARRIVAL_REGISTRY, ArrivalProcess, BurstArrivals,
                       ClosedLoopArrivals, PoissonArrivals, TraceArrivals,
                       get_arrival, list_arrivals, register_arrival)
from .backends import (SERVING_BACKENDS, SERVING_ENV, ServingBackend,
                       get_serving_backend, list_serving_backends,
                       register_serving_backend, resolve_serving_backend,
                       serving_backend_available)
from .config import AUTO_SLOTS_PER_JOB, ServingConfig
from .engine import run_serving_grid, simulate_serving
from .policies import (POLICY_ADAPTERS, DispatchPolicy, dispatch_policy,
                       lr_round_rows, register_policy)
from .queueing import erlang_b, erlang_c, mm1_sojourn, mmk_sojourn, mmk_wait

__all__ = [
    "ARRIVAL_REGISTRY", "ArrivalProcess", "PoissonArrivals",
    "TraceArrivals", "BurstArrivals", "ClosedLoopArrivals",
    "register_arrival", "get_arrival", "list_arrivals",
    "SERVING_BACKENDS", "SERVING_ENV", "ServingBackend",
    "register_serving_backend", "get_serving_backend",
    "list_serving_backends", "resolve_serving_backend",
    "serving_backend_available",
    "ServingConfig", "AUTO_SLOTS_PER_JOB",
    "simulate_serving", "run_serving_grid",
    "DispatchPolicy", "POLICY_ADAPTERS", "dispatch_policy",
    "register_policy", "lr_round_rows",
    "erlang_b", "erlang_c", "mmk_wait", "mmk_sojourn", "mm1_sojourn",
]
