"""Learning-rate schedules (warmup + cosine / linear / constant)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
