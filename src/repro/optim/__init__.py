from .adamw import AdamW, AdamWState
from .schedules import constant, warmup_cosine

__all__ = ["AdamW", "AdamWState", "constant", "warmup_cosine"]
