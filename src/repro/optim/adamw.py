"""AdamW from scratch (no optax): bf16 params + fp32 master/moments.

State layout mirrors the param tree leaf-for-leaf so the sharding specs of
parameters apply verbatim to every optimizer-state copy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # ()
    mu: Any                    # fp32, like params
    nu: Any                    # fp32, like params
    master: Any                # fp32 master weights


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        f32 = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=f32(params), nu=f32(params), master=master)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                                 for g in jax.tree.leaves(g32)) + 1e-12)
            scale = jnp.minimum(1.0, self.grad_clip / gnorm)
            g32 = jax.tree.map(lambda g: g * scale, g32)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, g32)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(w, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            u = u + self.weight_decay * w
            return w - lr * u

        master = jax.tree.map(upd, state.master, mu, nu)
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), master, params)
        return new_params, AdamWState(step, mu, nu, master)
