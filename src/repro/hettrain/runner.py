"""Scheme-driven training studies: one grid task -> MCReport rows.

``run_training_grid`` is the training analogue of
``run_serving_grid`` / ``run_live_grid`` -- the executor
(``repro.experiments.engine``) calls it once per scheme task of a spec
with ``training=TrainConfig(...)``.

Two decoupled computations per task:

1. **The optimizer trajectory** -- real gradients through the batched
   ``ScanGradEngine``, one canonical-order dispatch per step over that
   step's ``N`` units.  Work conservation makes the per-step gradient
   sum policy-independent, so the trajectory is computed ONCE per task
   and shared by every grid point and trial; any two scheme tasks of
   the same spec produce bit-identical loss curves (pinned by tests).
2. **Virtual time** -- per grid point x trial, the scheme's scheduler
   (exchange / cover protocol) or ``simulate`` fallback replays the
   same per-step unit sets over a fresh ``VirtualWorkerPool``,
   producing T_comp, epochs, N_comm, straggler-wait fractions and
   refetch traffic.  Drifting / trace grids pace the pool by the
   per-round rate schedule while schedulers keep seeing nominal rates;
   simulate-only schemes run at nominal and are stamped
   ``nominal_rates_only`` (the executor convention).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.estimator import make_estimator
from repro.core.runtime import VirtualWorkerPool
from repro.core.schemes import MCReport, _report, get_scheme
from repro.data.pipeline import HetShardedLoader

from .config import TrainConfig
from .engine import ScanGradEngine
from .policies import build_scheduler, policy_mode, run_virtual_step


def compute_trajectory(training: TrainConfig, N: int):
    """The policy-independent part: loss curve + engine stats.

    Step ``s`` consumes units ``[s*N, (s+1)*N)``; the gradient sum is
    one canonical-order fused dispatch, divided by ``N`` and fed to
    AdamW.  Returns ``(loss_curve, params, engine)``.
    """
    import jax

    model, params = training.build_model()
    store = training.build_store()
    opt = training.build_optimizer()
    engine = ScanGradEngine(model, store)
    update = jax.jit(opt.update)
    opt_state = opt.init(params)
    curve: List[float] = []
    for s in range(int(training.steps)):
        unit_ids = range(s * N, (s + 1) * N)
        grads_sum, losses = engine.grad_sum(params, unit_ids)
        grads = jax.tree.map(lambda g: g / N, grads_sum)
        params, opt_state = update(grads, opt_state, params)
        curve.append(float(np.asarray(losses).mean()))
    return curve, params, engine


def _trial_rng(seed: int, g: int, trial: int) -> np.random.Generator:
    """Fresh independent stream per (task seed, grid point, trial)."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=int(seed),
                               spawn_key=(int(g), int(trial))))


def _virtual_trial(scheme, mode: str, het, training: TrainConfig, N: int,
                   store, rng: np.random.Generator,
                   traces: Optional[np.ndarray]) -> Dict[str, Any]:
    """One virtual-time realization of the whole run (all steps)."""
    K = het.K
    pool = VirtualWorkerPool(het.lambdas, rng=rng, traces=traces)
    steps = int(training.steps)
    t_steps = np.empty(steps)
    iters = 0
    n_comm = 0.0
    wait = 0.0
    refetch = 0
    if mode == "scheduler":
        estimator = (make_estimator(training.estimator, K)
                     if getattr(scheme, "known", True) is False else None)
        loader = HetShardedLoader(store, K)
        for s in range(steps):
            unit_ids = list(range(s * N, (s + 1) * N))
            sched = build_scheduler(scheme, unit_ids, het.lambdas,
                                    estimator=estimator,
                                    threshold_frac=training.threshold_frac)
            st = run_virtual_step(sched, pool, unit_ids, loader=loader)
            t_steps[s] = st.t_comp
            iters += st.iterations
            n_comm += st.n_comm
            wait += st.straggler_wait
        refetch = loader.refetched_tokens
    else:
        for s in range(steps):
            rs = scheme.simulate(het, N, pool.rng)
            t_steps[s] = rs.t_comp
            iters += rs.iterations
            n_comm += rs.n_comm
    total = float(t_steps.sum())
    return {"t_steps": t_steps, "t_total": total, "iterations": iters,
            "n_comm": n_comm,
            "wait_frac": wait / (K * max(total, 1e-12)),
            "refetch_tokens": refetch}


def run_training_grid(scheme_name: str, params: Dict[str, Any],
                      het_specs: Sequence, training: TrainConfig,
                      N: int, trials: int, seed: int,
                      rate_schedules: Optional[np.ndarray] = None
                      ) -> List[MCReport]:
    """One scheme task of a training spec: a report row per grid point.

    ``N`` is units (microbatches) per optimizer step; ``trials`` is the
    number of independent virtual-time realizations of the one shared
    trajectory.  ``rate_schedules`` (optional ``(G, R, K)``) paces the
    pool by measured/drifting per-round rates.
    """
    scheme = get_scheme(scheme_name, **params)
    mode = policy_mode(scheme)
    curve, _, engine = compute_trajectory(training, N)
    curve_arr = np.asarray(curve)

    reports: List[MCReport] = []
    for g, het in enumerate(het_specs):
        store = training.build_store()
        runs = [_virtual_trial(scheme, mode, het, training, N, store,
                               _trial_rng(seed, g, t),
                               None if rate_schedules is None
                               else rate_schedules[g].T)
                for t in range(int(trials))]
        ts = np.array([r["t_total"] for r in runs])
        its = np.array([r["iterations"] for r in runs], dtype=np.float64)
        cs = np.array([r["n_comm"] for r in runs])
        t_per_step = np.mean(np.stack([r["t_steps"] for r in runs]),
                             axis=0)
        info: Dict[str, Any] = {
            "mode": mode,
            "steps": int(training.steps),
            "units_per_step": int(N),
            "loss_curve": [float(x) for x in curve],
            "final_loss": float(curve[-1]),
            "t_comp_per_step": [float(x) for x in t_per_step],
            "straggler_wait_frac": float(np.mean([r["wait_frac"]
                                                  for r in runs])),
            "refetch_tokens": float(np.mean([r["refetch_tokens"]
                                             for r in runs])),
            "engine": engine.stats(),
        }
        if training.target_loss is not None:
            hit = np.nonzero(curve_arr <= float(training.target_loss))[0]
            if hit.size:
                s_hit = int(hit[0])
                info["steps_to_target"] = s_hit + 1
                # mean over trials of the virtual wall through step s_hit
                info["wall_to_target"] = float(np.mean(
                    [r["t_steps"][: s_hit + 1].sum() for r in runs]))
            else:
                info["steps_to_target"] = -1
                info["wall_to_target"] = -1.0
        rep = _report(scheme.name, ts, its, cs,
                      extra={"grid_point": g, "training": info})
        if rate_schedules is not None and mode == "simulate":
            # the grid drifts but this scheme has no id-aware protocol to
            # follow it: same stamp as the MC executor
            rep.extra["nominal_rates_only"] = 1
        reports.append(rep)
    return reports


__all__ = ["run_training_grid", "compute_trajectory"]
