"""Heterogeneity-aware distributed-training subsystem.

Every registered scheme becomes an epoch-assignment policy over real
gradients: the batched ``lax.scan`` engine (``engine``) computes one
canonical-order gradient dispatch per optimizer step -- bit-identical
across policies by work conservation -- while each policy's scheduler
(``policies``) moves virtual wall-clock over a ``VirtualWorkerPool``.
``runner.run_training_grid`` is the executor entry point for specs with
``ExperimentSpec(training=TrainConfig(...))``.

``TrainConfig`` imports eagerly (specs must stay import-light); the
jax-heavy engine/runner/policies modules load on attribute access.
"""
from .config import MODEL_PRESETS, TrainConfig

_LAZY = {
    "ScanGradEngine": "engine", "bucket_units": "engine",
    "tree_bytes": "engine", "MIN_BUCKET": "engine",
    "StepStats": "policies", "policy_mode": "policies",
    "run_virtual_step": "policies", "build_scheduler": "policies",
    "run_training_grid": "runner", "compute_trajectory": "runner",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)


__all__ = ["TrainConfig", "MODEL_PRESETS", *_LAZY]
