"""Every registered scheme as an epoch-assignment training policy.

One executor drives one optimizer step's worth of *virtual* scheduling
-- which worker processed which units, when -- against any scheme the
registry can produce a scheduler for:

* **exchange protocols** (``make_scheduler`` -> ``MasterScheduler``):
  work_exchange known/unknown, trace_replay, and the static fixed /
  uniform assignments (threshold 1e9 => one wait-all epoch);
* **cover protocols** (``make_scheduler`` -> ``CoverScheduler``,
  flagged ``cover``): gradient_coded races whole replicated queues and
  completes at coverage -- the registry path that replaced the bespoke
  ``_coded_step`` branch in ``hetsched.py``;
* **simulate-only schemes** (oracle, mds, het_mds, hedged): no id-aware
  protocol, so the runner times steps through ``scheme.simulate`` at the
  nominal rates instead (stamped ``nominal_rates_only`` under drift).

The executor never touches gradients: it returns *who did what, when*
(``groups``) plus the timing ledger, and the gradient engine runs one
canonical-order dispatch per step regardless -- which is exactly why the
optimizer trajectory is bit-identical across policies.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.runtime import VirtualWorkerPool


@dataclasses.dataclass
class StepStats:
    """One virtual step's scheduling ledger (no gradients)."""

    t_comp: float                  # virtual wall-clock for the step
    iterations: int                # assignment epochs
    n_comm: int                    # units moved (eq. 2) / shipped redundancy
    straggler_wait: float          # sum over workers of idle-at-barrier time
    groups: List[Tuple[int, List[int]]]   # (worker, credited units) in
                                          # completion order

    @property
    def wait_frac(self) -> float:
        """Fraction of total worker-time spent idle at barriers."""
        K = max(len({w for w, _ in self.groups}), 1)
        denom = K * max(self.t_comp, 1e-12)
        return float(min(self.straggler_wait / denom, 1.0))


def policy_mode(scheme) -> str:
    """``"scheduler"`` (exchange or cover protocol) or ``"simulate"``."""
    if getattr(scheme, "cover_scheduler", False):
        return "scheduler"
    try:
        scheme.make_scheduler([0], rates=np.ones(1))
        return "scheduler"
    except NotImplementedError:
        return "simulate"


def run_virtual_step(sched, pool: VirtualWorkerPool,
                     unit_ids: Sequence[int],
                     failures: Sequence[int] = (),
                     loader=None) -> StepStats:
    """Drive one scheduler to completion over the pool's virtual clocks.

    ``failures`` are worker ids dead from this step's first epoch on
    (their leftover units are reassigned / covered).  ``loader`` (a
    ``HetShardedLoader``) gets prefetch + ownership-touch calls so
    re-fetch traffic is counted without materializing batches.  Asserts
    exact unit conservation: the credited groups partition the step.
    """
    K = pool.K
    dead = np.zeros(K, dtype=bool)
    for w in failures:
        dead[int(w)] = True
    processed: set = set()
    groups: List[Tuple[int, List[int]]] = []
    wait = 0.0

    if getattr(sched, "cover", False):
        a = sched.next_assignment()
        if loader is not None:
            for k in range(K):
                loader.prefetch(k, a.queues[k])
        t_k = pool.finish_times(a.sizes, dead)
        for w in np.nonzero(dead)[0]:
            sched.mark_failed(int(w))
        t_done, done, cover_groups = sched.resolve(t_k)
        for w, units in cover_groups:
            processed.update(units)
            groups.append((w, list(units)))
        # workers whose whole queue finished before the cover instant
        # idle until the master declares completion
        early = np.isfinite(t_k) & (t_k <= t_done)
        wait = float(np.sum(t_done - t_k[early]))
    else:
        epoch = 0
        while not sched.finished:
            a = sched.next_assignment()
            if a is None:
                break
            if epoch == 0 and loader is not None:
                for k in range(K):
                    loader.prefetch(k, a.queues[k])
            elapsed, done = pool.run_epoch(a, dead)
            for k in range(K):
                todo = a.queues[k][: int(done[k])]
                if todo:
                    if loader is not None:
                        loader.touch(k, todo)
                    for u in todo:
                        assert u not in processed, f"unit {u} done twice"
                    processed.update(todo)
                    groups.append((k, list(todo)))
            if a.wait_all:
                # barrier epoch: everyone waits for the slowest
                t_k = pool.last_t_k
                fin = np.isfinite(t_k)
                if fin.any():
                    wait += float(np.sum(elapsed - t_k[fin]))
            sched.report(done, elapsed)
            for w in np.nonzero(dead)[0]:
                sched.mark_failed(int(w))
            epoch += 1

    assert processed == set(int(u) for u in unit_ids), \
        "work conservation violated"
    return StepStats(t_comp=float(sched.t_comp),
                     iterations=int(sched.iterations),
                     n_comm=int(sched.n_comm), straggler_wait=wait,
                     groups=groups)


def build_scheduler(scheme, unit_ids: Sequence[int],
                    rates: np.ndarray, estimator=None,
                    threshold_frac: Optional[float] = None):
    """Uniform ``make_scheduler`` call (known schemes ignore the
    estimator; unknown-heterogeneity schemes carry it across steps)."""
    return scheme.make_scheduler(unit_ids, rates=np.asarray(rates, float),
                                 estimator=estimator,
                                 threshold_frac=threshold_frac)


__all__ = ["StepStats", "policy_mode", "run_virtual_step",
           "build_scheduler"]
