"""Batched ``lax.scan`` microbatch-gradient engine.

The old ``HetTrainer`` drove one jitted gradient call per *unit* from a
Python loop -- K x units_per_step dispatches per step, each paying the
host-to-device round trip, and a fresh XLA compile whenever unit batch
shapes differed across workers.  ``ScanGradEngine`` replaces that with
ONE dispatch per unit group: the group's units are stacked on a leading
axis and a jitted ``lax.scan`` folds ``value_and_grad`` over them,
mean-free f32 accumulation into a zeros tree (the ``make_train_step``
accumulation idiom).

Two properties the training subsystem leans on:

* **pow2 unit-count bucketing** (the PR-8 shape-bucket discipline):
  group sizes are padded up to the next power of two (floor
  ``MIN_BUCKET``) by repeating the last unit under a zero mask, so every
  epoch/step shares a handful of compiled shapes instead of one per
  distinct group size.  Masked slots add ``g * 0.0`` in f32 -- exactly 0
  -- so padding never changes the sum bitwise.
* **canonical-order dispatch**: ``grad_sum`` sorts unit ids before
  stacking.  A full-step call therefore returns a bit-identical gradient
  sum no matter which policy scheduled the units (work conservation,
  pinned at engine scale by the policy battery).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

MIN_BUCKET = 4


def bucket_units(n: int, min_bucket: int = MIN_BUCKET) -> int:
    """Next power-of-two group size >= n (floor ``min_bucket``)."""
    if n <= 0:
        raise ValueError("bucket_units needs n >= 1")
    b = max(int(min_bucket), 1)
    while b < n:
        b *= 2
    return b


def tree_bytes(tree) -> float:
    """Dense byte size of one gradient tree (the uncompressed wire cost)."""
    import jax
    return float(sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(tree)))


class ScanGradEngine:
    """Jitted scan over a stacked unit group -> (f32 grad sum, losses).

    One instance per (model, store): the jit cache is keyed on the
    bucketed group size, so all callers -- the canonical full-step path,
    per-worker compressor groups, every policy -- share compiles.
    """

    def __init__(self, model, store, min_bucket: int = MIN_BUCKET):
        import jax
        self.model = model
        self.store = store
        self.min_bucket = int(min_bucket)
        self.dispatches = 0          # engine calls (each = one device launch)
        self.units_in = 0            # real units summed
        self.bucket_sizes: set = set()    # distinct compiled group sizes
        self._jit = jax.jit(self._scan)

    # -- the jitted kernel --------------------------------------------------

    def _scan(self, params, toks, labels, mask):
        import jax
        import jax.numpy as jnp

        def unit_loss(p, batch):
            return self.model.loss(p, batch, mode="scan", remat=False)[0]

        def body(acc, xs):
            t, l, m = xs
            loss, g = jax.value_and_grad(unit_loss)(
                params, {"tokens": t, "labels": l})
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) * m, acc, g)
            return acc, loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, losses = jax.lax.scan(body, zeros, (toks, labels, mask))
        return grads, losses

    # -- host-side dispatch -------------------------------------------------

    def _stack(self, unit_ids: Sequence[int]):
        ids = sorted(int(u) for u in unit_ids)
        B = bucket_units(len(ids), self.min_bucket)
        batches: List[Dict[str, np.ndarray]] = [self.store.fetch(u)
                                                for u in ids]
        batches += [batches[-1]] * (B - len(ids))   # masked pad slots
        toks = np.stack([b["tokens"] for b in batches])
        labels = np.stack([b["labels"] for b in batches])
        mask = np.zeros(B, dtype=np.float32)
        mask[: len(ids)] = 1.0
        return ids, toks, labels, mask

    def grad_sum(self, params, unit_ids: Sequence[int]):
        """One dispatch: (f32 gradient SUM over the group, per-unit
        losses in canonical sorted-id order).  Divide by the step's unit
        count at the caller -- partial groups must stay sums so they
        compose."""
        ids, toks, labels, mask = self._stack(unit_ids)
        grads, losses = self._jit(params, toks, labels, mask)
        self.dispatches += 1
        self.units_in += len(ids)
        self.bucket_sizes.add(int(mask.size))
        return grads, np.asarray(losses)[: len(ids)]

    def stats(self) -> Dict[str, float]:
        return {"dispatches": self.dispatches, "units": self.units_in,
                "bucket_sizes": sorted(self.bucket_sizes)}


__all__ = ["ScanGradEngine", "bucket_units", "tree_bytes", "MIN_BUCKET"]
