"""``TrainConfig``: the heterogeneous-training axis of an ExperimentSpec.

A frozen, JSON-lossless value (the ``ServingConfig`` discipline) that
turns a Monte-Carlo experiment into a *training study*: set
``ExperimentSpec(training=TrainConfig(...))`` and every scheme task
becomes an epoch-assignment policy over real gradients -- the batched
``lax.scan`` microbatch engine computes the optimizer trajectory (one
canonical-order dispatch per step, bit-identical across policies by work
conservation) while each policy's scheduler moves virtual wall-clock.
One ``MCReport`` per grid point with the loss curve, per-step ``T_comp``
and straggler-wait fractions in ``extra["training"]``.

Specs WITHOUT a training config serialize exactly as before (the key is
omitted when ``None``), so every pre-PR-9 ``spec_hash`` and store
address survives.

Knobs:

``steps``
    Optimizer steps per run.  Each step consumes ``spec.N`` fresh units
    (microbatches); ``spec.trials`` is the number of independent
    virtual-time realizations of the same trajectory.
``model`` / ``unit_batch`` / ``seq_len`` / ``vocab``
    Model preset (``MODEL_PRESETS``: reduced phi3-family transformers)
    and the microbatch-unit shape.
``data`` / ``data_seed`` / ``init_seed``
    ``"structured"`` is the learnable synthetic task (loss actually
    descends), ``"random"`` the i.i.d. token stream; unit content is a
    pure function of ``(data_seed, unit_id)``, which is what makes the
    gradient sum policy-independent.
``lr`` / ``weight_decay``
    AdamW hyperparameters.
``estimator`` / ``threshold_frac``
    The online-rate estimator (``repro.core.estimator`` registry) the
    unknown-heterogeneity policies carry across steps, and the
    work-exchange cutting threshold.
``target_loss``
    When set, reports also carry ``wall_to_target`` / ``steps_to_target``
    (virtual wall-clock until the loss curve first reaches the target) --
    the fig_train panel's y-axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

from repro.core.estimator import ESTIMATOR_REGISTRY, make_estimator

_DATAS = ("structured", "random")

# reduced same-family transformer presets (repro.configs smoke shapes);
# dims only -- vocab comes from the ``vocab`` knob
MODEL_PRESETS: Dict[str, Dict[str, int]] = {
    "tiny": dict(n_layers=2, d_model=32, n_heads=2, head_dim=16,
                 n_kv_heads=2, d_ff=64),
    "small": dict(n_layers=2, d_model=64, n_heads=4, head_dim=16,
                  n_kv_heads=2, d_ff=128),
}

_BASE_ARCH = "phi3-mini-3.8b"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """The heterogeneous-training axis as one hashable value."""

    steps: int = 8
    model: str = "tiny"
    unit_batch: int = 2
    seq_len: int = 16
    vocab: int = 128
    data: str = "structured"
    data_seed: int = 3
    init_seed: int = 0
    lr: float = 1e-2
    weight_decay: float = 0.0
    estimator: str = "cumulative"
    threshold_frac: float = 0.05
    target_loss: Optional[float] = None

    def __post_init__(self):
        if int(self.steps) <= 0:
            raise ValueError("steps must be positive")
        if self.model not in MODEL_PRESETS:
            raise ValueError(f"model must be one of "
                             f"{sorted(MODEL_PRESETS)}; got {self.model!r}")
        if (int(self.unit_batch) <= 0 or int(self.seq_len) <= 0
                or int(self.vocab) <= 1):
            raise ValueError("unit_batch/seq_len must be positive and "
                             "vocab > 1")
        if self.data not in _DATAS:
            raise ValueError(f"data must be one of {_DATAS}; "
                             f"got {self.data!r}")
        if float(self.lr) <= 0:
            raise ValueError("lr must be positive")
        if float(self.weight_decay) < 0:
            raise ValueError("weight_decay must be >= 0")
        if not 0.0 < float(self.threshold_frac):
            raise ValueError("threshold_frac must be positive")
        if self.target_loss is not None and float(self.target_loss) <= 0:
            raise ValueError("target_loss must be positive (or None)")
        # fail at construction, not mid-run: unknown estimator kinds
        # raise KeyError listing the registry
        make_estimator(self.estimator, 1)

    # -- builders (jax imported lazily: specs stay import-light) ------------

    def build_model(self):
        """The reduced transformer this config trains (model, params)."""
        import jax

        from repro.configs import get_config, smoke_config
        from repro.models import build_model
        cfg = dataclasses.replace(
            smoke_config(get_config(_BASE_ARCH)), dtype="float32",
            vocab_size=int(self.vocab), **MODEL_PRESETS[self.model])
        model = build_model(cfg)
        params = model.init(jax.random.key(int(self.init_seed)))
        return model, params

    def build_store(self):
        from repro.data.pipeline import UnitStore
        return UnitStore(unit_batch=int(self.unit_batch),
                         seq_len=int(self.seq_len), vocab=int(self.vocab),
                         seed=int(self.data_seed),
                         structured=(self.data == "structured"))

    def build_optimizer(self):
        from repro.optim import AdamW
        return AdamW(lr=float(self.lr),
                     weight_decay=float(self.weight_decay))

    # -- serialization (every knob appears: the dict is the hash input) -----

    def to_dict(self) -> Dict[str, Any]:
        return {
            "steps": int(self.steps),
            "model": self.model,
            "unit_batch": int(self.unit_batch),
            "seq_len": int(self.seq_len),
            "vocab": int(self.vocab),
            "data": self.data,
            "data_seed": int(self.data_seed),
            "init_seed": int(self.init_seed),
            "lr": float(self.lr),
            "weight_decay": float(self.weight_decay),
            "estimator": self.estimator,
            "threshold_frac": float(self.threshold_frac),
            "target_loss": (None if self.target_loss is None
                            else float(self.target_loss)),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TrainConfig":
        allowed = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - allowed
        if unknown:
            raise KeyError(f"unknown training key(s) {sorted(unknown)}; "
                           f"allowed {sorted(allowed)} (registered "
                           f"estimators: {ESTIMATOR_REGISTRY.names()})")
        return cls(**dict(d))


__all__ = ["TrainConfig", "MODEL_PRESETS"]
