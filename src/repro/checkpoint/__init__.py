from .ckpt import (latest_checkpoint, reshard_rates, restore_checkpoint,
                   save_checkpoint)

__all__ = ["latest_checkpoint", "reshard_rates", "restore_checkpoint",
           "save_checkpoint"]
