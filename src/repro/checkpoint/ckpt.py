"""Checkpointing: save/restore of params + optimizer + scheduler state.

Fault-tolerance substrate (DESIGN §3): work-exchange handles *within-step*
worker loss; checkpoint/restart handles whole-job restarts.  Format is
dependency-free (.npz tensors + msgpack-free JSON manifest with the pytree
structure), supports:
  * atomic writes (tmp + rename),
  * keep-last-k retention,
  * ELASTIC restore: the saved work-exchange rate estimates are resharded
    when the restored cluster has a different worker count K (rates are
    resampled proportionally -- new workers start from the prior).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[dict, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return arrays, treedef


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    extra: Optional[Dict] = None, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_"))
    arrays, treedef = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(arrays), "extra": extra or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: Path, keep: int) -> None:
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)


def latest_checkpoint(directory: str | Path) -> Optional[Path]:
    directory = Path(directory)
    if not directory.exists():
        return None
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step_"))
    return ckpts[-1] if ckpts else None


def restore_checkpoint(path: str | Path, like: Any) -> tuple[Any, Dict]:
    """Restore into the structure of ``like`` (validates shapes/dtypes)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    leaves_like, treedef = jax.tree.flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"expected {len(leaves_like)} -- structure changed?")
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"a{i}"]
        if hasattr(ref, "shape") and tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=getattr(ref, "dtype", None)))
    return jax.tree.unflatten(treedef, leaves), manifest["extra"]


# ---------------------------------------------------------------------------
# elastic scheduler-state restore
# ---------------------------------------------------------------------------

def reshard_rates(rates: np.ndarray, new_k: int,
                  prior_rate: float = 1.0) -> np.ndarray:
    """Adapt saved per-worker rate estimates to a different cluster size.

    Shrink: keep the first new_k (the surviving workers, by convention).
    Grow: new workers start from the mean of known rates (better prior
    than 1.0 -- they are drawn from the same fleet).
    """
    rates = np.asarray(rates, dtype=np.float64)
    if new_k <= rates.size:
        return rates[:new_k].copy()
    prior = float(rates.mean()) if rates.size else prior_rate
    return np.concatenate([rates, np.full(new_k - rates.size, prior)])
