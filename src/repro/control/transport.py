"""Pluggable async transports: the control plane's comm layer.

The connector/listener split mirrors dask.distributed's ``comm/core.py``:
a ``Transport`` (the fifth plugin surface, registered in
``TRANSPORT_REGISTRY`` with the same ``register_*``/``get_*``/``list_*``
discipline as schemes/samplers/scenarios/arrivals) builds ``Listener``s
on the serving side and ``Comm``s on the connecting side; an established
``Comm`` is a bidirectional ordered message channel.

    from repro.control import get_transport

    transport = get_transport("inproc")
    listener = transport.listen(handle_comm)    # server side
    await listener.start()
    comm = await transport.connect(listener.address)
    await comm.send({"type": "hello"})

Registered transports:

``inproc``
    In-process asyncio queue pairs (``repro.control.inproc``), the
    reference transport every conformance test runs against.
``flaky``
    A fault-injection wrapper around any inner transport
    (``repro.control.faults``): per-message latency/jitter and seeded
    random drops, for exercising the coordinator's timeout/retry path
    and worker-loss degradation.

Messages are plain dicts; in-process transports pass them by reference,
so senders must not mutate a message after ``send`` (the coordinator and
worker never do).
"""
from __future__ import annotations

import inspect
from typing import Awaitable, Callable, Dict, List, Optional, Sequence, Type

from repro.core.registry import Registry


class CommClosedError(ConnectionError):
    """The peer closed the channel (or the address is not listening)."""


class Comm:
    """One established bidirectional message channel."""

    async def send(self, msg: Dict) -> None:
        raise NotImplementedError

    async def recv(self, timeout: Optional[float] = None) -> Dict:
        """Next message in send order.  Raises ``asyncio.TimeoutError``
        when ``timeout`` (seconds) elapses with nothing to deliver, and
        ``CommClosedError`` once the peer has closed."""
        raise NotImplementedError

    async def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


# the server-side accept callback: one task per accepted comm
HandleComm = Callable[[Comm], Awaitable[None]]


class Listener:
    """A serving endpoint bound to ``address``."""

    address: str

    async def start(self) -> None:
        raise NotImplementedError

    async def stop(self) -> None:
        raise NotImplementedError


class Transport:
    """Connector/listener factory for one wire protocol."""

    name: str = "abstract"

    def listen(self, handle_comm: HandleComm,
               address: Optional[str] = None) -> Listener:
        raise NotImplementedError

    async def connect(self, address: str) -> Comm:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# registry (the fifth plugin surface, born on repro.core.registry)
# ---------------------------------------------------------------------------

TRANSPORT_REGISTRY: Registry[Type[Transport]] = Registry("transport")


def register_transport(name: str, *, aliases: Sequence[str] = ()):
    """Class decorator: key a Transport subclass under ``name``."""
    def deco(cls: Type[Transport]) -> Type[Transport]:
        TRANSPORT_REGISTRY.register(name, cls, aliases=aliases)
        cls.name = name
        return cls
    return deco


def get_transport(name: str, **params) -> Transport:
    """Instantiate a registered transport; unknown names or params fail
    loudly (the ``validate_backend`` discipline)."""
    cls = TRANSPORT_REGISTRY.get(name)
    try:
        return cls(**params)
    except TypeError:
        allowed = [p for p in inspect.signature(cls).parameters
                   if p != "self"]
        raise KeyError(f"bad params {sorted(params)} for transport "
                       f"{name!r}; allowed {allowed}") from None


def list_transports(include_aliases: bool = False) -> List[str]:
    return TRANSPORT_REGISTRY.names(include_aliases)


__all__ = [
    "CommClosedError", "Comm", "HandleComm", "Listener", "Transport",
    "TRANSPORT_REGISTRY", "register_transport", "get_transport",
    "list_transports",
]
