"""TCP localhost transport: asyncio streams behind the Comm contract.

The first transport that crosses a real socket: ``listen`` binds an
``asyncio.start_server`` on ``127.0.0.1`` (port 0 -- the OS picks;
``address`` is concrete only after ``start()``), ``connect`` opens a
stream to ``tcp://host:port``.  Messages are JSON documents in 4-byte
big-endian length-prefixed frames -- dask.distributed's framing shape
without the multi-frame machinery, which the control plane's small dict
messages don't need.  numpy scalars serialize through a default hook
(the telemetry/ledger payloads carry ``np.int64``/``np.float64``).

Delivery is FIFO per direction (one TCP stream each way is one ordered
byte stream) and lossless until close, so the transport inherits the
same conformance battery as ``inproc``; EOF surfaces as
``CommClosedError``, matching the contract.  Composes under ``flaky``
(``get_transport("flaky", inner="tcp")``) for loss/latency injection on
a real socket.
"""
from __future__ import annotations

import asyncio
import json
import struct
from typing import Dict, Optional

import numpy as np

from .transport import (Comm, CommClosedError, HandleComm, Listener,
                        Transport, register_transport)

_HOST = "127.0.0.1"
_LEN = struct.Struct(">I")        # 4-byte big-endian frame length
MAX_FRAME = 64 * 1024 * 1024      # sanity bound, not a protocol limit


def _default(o):
    """JSON hook for the numpy scalars control messages carry."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def _encode(msg: Dict) -> bytes:
    body = json.dumps(msg, default=_default).encode("utf-8")
    return _LEN.pack(len(body)) + body


class TCPComm(Comm):
    """One established stream pair (reader/writer) as a message channel."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, label: str):
        self._reader = reader
        self._writer = writer
        self.label = label
        self._closed = False
        self._peer_closed = False

    async def send(self, msg: Dict) -> None:
        if self.closed:
            raise CommClosedError(f"{self.label}: channel closed")
        try:
            self._writer.write(_encode(msg))
            await self._writer.drain()
        except (ConnectionError, RuntimeError) as e:
            self._peer_closed = True
            raise CommClosedError(f"{self.label}: {e}") from None

    async def _read_frame(self) -> Dict:
        try:
            head = await self._reader.readexactly(_LEN.size)
            (n,) = _LEN.unpack(head)
            if n > MAX_FRAME:
                raise CommClosedError(f"{self.label}: oversized frame "
                                      f"({n} bytes)")
            body = await self._reader.readexactly(n)
        except (asyncio.IncompleteReadError, ConnectionError):
            self._peer_closed = True
            raise CommClosedError(f"{self.label}: peer closed") from None
        return json.loads(body.decode("utf-8"))

    async def recv(self, timeout: Optional[float] = None) -> Dict:
        if self._closed:
            raise CommClosedError(f"{self.label}: channel closed")
        if self._peer_closed:
            raise CommClosedError(f"{self.label}: peer closed")
        frame = self._read_frame()
        return await (asyncio.wait_for(frame, timeout)
                      if timeout is not None else frame)

    async def close(self) -> None:
        if not self._closed:
            self._closed = True
            try:
                self._writer.close()
                await self._writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    @property
    def closed(self) -> bool:
        return self._closed or self._peer_closed


class TCPListener(Listener):
    def __init__(self, handle_comm: HandleComm, address: Optional[str]):
        self.address = address or f"tcp://{_HOST}:0"
        self._handle_comm = handle_comm
        self._server: Optional[asyncio.base_events.Server] = None
        self._tasks: list = []

    async def start(self) -> None:
        _, _, port = _split(self.address)
        self._server = await asyncio.start_server(self._accept, _HOST,
                                                  port)
        real = self._server.sockets[0].getsockname()[1]
        self.address = f"tcp://{_HOST}:{real}"

    async def _accept(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        comm = TCPComm(reader, writer, f"{self.address}#server")
        self._tasks.append(asyncio.ensure_future(self._handle_comm(comm)))

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for t in self._tasks:
            t.cancel()
        self._tasks.clear()


def _split(address: str):
    if not address.startswith("tcp://"):
        raise ValueError(f"not a tcp address: {address!r}")
    host, _, port = address[len("tcp://"):].rpartition(":")
    return address, host, int(port)


@register_transport("tcp")
class TCPTransport(Transport):
    """Localhost TCP with length-prefixed JSON frames."""

    def listen(self, handle_comm: HandleComm,
               address: Optional[str] = None) -> Listener:
        return TCPListener(handle_comm, address)

    async def connect(self, address: str) -> Comm:
        _, host, port = _split(address)
        try:
            reader, writer = await asyncio.open_connection(host, port)
        except ConnectionError as e:
            raise CommClosedError(f"no tcp listener at {address!r}: "
                                  f"{e}") from None
        return TCPComm(reader, writer, f"{address}#client")


__all__ = ["TCPComm", "TCPListener", "TCPTransport", "MAX_FRAME"]
