"""Live async control plane: execute work exchange over real transports.

The paper's schemes are *planning* logic; this package is the runtime
that executes them.  An asyncio ``Coordinator`` drives K ``Worker``
tasks over a pluggable ``Transport`` (the fifth plugin surface,
``TRANSPORT_REGISTRY``): workers run real jitted matmul shards paced by
their Exp(1/lambda_k) service clocks, the coordinator takes every
exchange decision by calling the existing registry schemes'
``make_scheduler``/``plan``, and each episode emits a structured
telemetry timeline plus a measured-vs-predicted ``T_comp`` record.

    from repro.control import LiveConfig, run_live

    rep = run_live("work_exchange", {}, het, N=2000,
                   cfg=LiveConfig(), trials=4)
    rep.t_comp                         # measured, model seconds
    rep.extra["control_plane"]         # timeline, ledger, overhead

or, through the declarative API:

    ExperimentSpec(..., execution="live", live=LiveConfig())
"""
from . import transport
from .transport import (Comm, CommClosedError, HandleComm, Listener,
                        Transport, TRANSPORT_REGISTRY, get_transport,
                        list_transports, register_transport)
from . import inproc       # noqa: F401  (registers "inproc")
from . import faults       # noqa: F401  (registers "flaky")
from . import tcp          # noqa: F401  (registers "tcp")
from .inproc import InProcTransport
from .faults import FlakyTransport
from .tcp import TCPTransport
from .config import LiveConfig
from .compute import MatmulPayload
from .telemetry import Telemetry
from .worker import Worker
from .coordinator import (Coordinator, EpisodeStats, WorkerLost,
                          WorkerProxy, run_live, run_live_grid)

__all__ = [
    "Comm", "CommClosedError", "HandleComm", "Listener", "Transport",
    "TRANSPORT_REGISTRY", "register_transport", "get_transport",
    "list_transports", "InProcTransport", "FlakyTransport",
    "TCPTransport", "LiveConfig",
    "MatmulPayload", "Telemetry", "Worker", "Coordinator", "EpisodeStats",
    "WorkerLost", "WorkerProxy", "run_live", "run_live_grid",
]
