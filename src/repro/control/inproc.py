"""In-process transport: asyncio queue pairs behind the Comm contract.

The shape of dask.distributed's ``comm/inproc.py`` without the
cross-thread machinery: a process-global table maps ``inproc://<n>``
addresses to listeners; ``connect`` builds two unbounded queues (one per
direction) and hands the server-side peer to the listener's
``handle_comm`` as its own task.  Delivery is FIFO per direction and
never drops -- the reference behaviour every other transport's
conformance run is measured against.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Dict, Optional

from .transport import (Comm, CommClosedError, HandleComm, Listener,
                        Transport, register_transport)

_ADDRESS_COUNTER = itertools.count()
_LISTENERS: Dict[str, "InProcListener"] = {}

_CLOSE = object()      # end-of-channel sentinel


class InProcComm(Comm):
    """One endpoint of an in-process channel (a queue pair)."""

    def __init__(self, send_q: asyncio.Queue, recv_q: asyncio.Queue,
                 label: str):
        self._send_q = send_q
        self._recv_q = recv_q
        self.label = label
        self._closed = False
        self._peer_closed = False

    async def send(self, msg: Dict) -> None:
        if self._closed or self._peer_closed:
            raise CommClosedError(f"{self.label}: channel closed")
        self._send_q.put_nowait(msg)

    async def recv(self, timeout: Optional[float] = None) -> Dict:
        if self._peer_closed and self._recv_q.empty():
            raise CommClosedError(f"{self.label}: peer closed")
        if self._closed:
            raise CommClosedError(f"{self.label}: channel closed")
        get = self._recv_q.get()
        msg = await (asyncio.wait_for(get, timeout) if timeout is not None
                     else get)
        if msg is _CLOSE:
            self._peer_closed = True
            raise CommClosedError(f"{self.label}: peer closed")
        return msg

    async def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._send_q.put_nowait(_CLOSE)

    @property
    def closed(self) -> bool:
        return self._closed or self._peer_closed


class InProcListener(Listener):
    def __init__(self, address: str, handle_comm: HandleComm):
        self.address = address
        self._handle_comm = handle_comm
        self._tasks: list = []
        self._started = False

    async def start(self) -> None:
        _LISTENERS[self.address] = self
        self._started = True

    async def stop(self) -> None:
        _LISTENERS.pop(self.address, None)
        self._started = False
        for t in self._tasks:
            t.cancel()
        self._tasks.clear()

    def _accept(self) -> Comm:
        """Build a channel pair; serve one end, return the other."""
        a_to_b: asyncio.Queue = asyncio.Queue()
        b_to_a: asyncio.Queue = asyncio.Queue()
        server = InProcComm(b_to_a, a_to_b, f"{self.address}#server")
        client = InProcComm(a_to_b, b_to_a, f"{self.address}#client")
        self._tasks.append(asyncio.ensure_future(
            self._handle_comm(server)))
        return client


@register_transport("inproc")
class InProcTransport(Transport):
    """Reference transport: lossless ordered in-process delivery."""

    def listen(self, handle_comm: HandleComm,
               address: Optional[str] = None) -> Listener:
        if address is None:
            address = f"inproc://{next(_ADDRESS_COUNTER)}"
        return InProcListener(address, handle_comm)

    async def connect(self, address: str) -> Comm:
        listener = _LISTENERS.get(address)
        if listener is None or not listener._started:
            raise CommClosedError(f"no inproc listener at {address!r}")
        return listener._accept()


__all__ = ["InProcComm", "InProcListener", "InProcTransport"]
