"""Live worker: executes assigned unit queues over a transport Comm.

One ``Worker`` connects to the coordinator's listener, announces itself
with a ``hello`` push, then serves RPCs sequentially:

``assign``
    Start a round: draw per-unit service times from the worker's
    Exp(1/lambda_k) model clock, run the REAL jitted matmul for the
    whole queue (one call), then sleep out the remainder of the drawn
    wall-time budget; push ``round_done`` when the clock runs out.
``poll``
    Report instantaneous progress: how many units of the current queue
    are complete *right now* (``searchsorted`` on the drawn cumulative
    unit clocks -- the exact Poisson-process count at the poll instant).
``stop``
    Freeze the round at the stop instant and reply with the final done
    count (the paper's stop-flag message).
``shutdown``
    Acknowledge and exit the serve loop.

Replies echo the request's ``seq``; a seq seen before is answered from
a reply cache, so coordinator retries over lossy transports are
idempotent (a retried ``stop`` gets the count frozen by the first one).

Fault injection: ``die_after`` seconds after starting, the worker
silently cancels its serve loop WITHOUT closing the comm -- from the
coordinator's side it just stops answering, which is what exercises the
timeout/retry/mark-lost path rather than a clean close.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional

import numpy as np

from .compute import MatmulPayload
from .telemetry import Telemetry
from .transport import CommClosedError, Transport


class Worker:
    """One live worker endpoint (its own asyncio task)."""

    def __init__(self, transport: Transport, address: str, wid: int,
                 rate: float, time_scale: float, payload: MatmulPayload,
                 seed: int = 0, telemetry: Optional[Telemetry] = None,
                 die_after: Optional[float] = None):
        self.transport = transport
        self.address = address
        self.wid = int(wid)
        self.rate = float(rate)
        self.time_scale = float(time_scale)
        self.payload = payload
        self.telemetry = telemetry
        self.die_after = die_after
        self._rng = np.random.default_rng(seed)
        self._replies: Dict[int, Dict] = {}     # seq -> reply (dedup)
        self._round = -1
        self._units: List[int] = []
        self._cum = np.zeros(0)                 # per-unit wall deadlines
        self._round_t0 = 0.0
        self._running = False
        self._frozen_done = 0
        self._round_task: Optional[asyncio.Future] = None
        self._dead = False
        self.comm = None

    # -- progress accounting ------------------------------------------------

    def _done_now(self) -> int:
        """Units of the current queue complete at this wall instant."""
        if self._round < 0:
            return 0
        if not self._running:
            return self._frozen_done
        t = time.perf_counter() - self._round_t0
        return int(np.searchsorted(self._cum, t, side="right"))

    def _freeze(self) -> int:
        done = self._done_now()
        self._running = False
        self._frozen_done = done
        if self._round_task is not None and not self._round_task.done():
            self._round_task.cancel()
        if self.telemetry is not None:
            self.telemetry.span_close(self.wid, units=done)
            self.telemetry.span_open(self.wid, "idle")
        return done

    # -- round execution ----------------------------------------------------

    def _start_round(self, rnd: int, units: List[int]) -> None:
        self._round = int(rnd)
        self._units = list(units)
        times = (self._rng.exponential(1.0 / self.rate, len(units))
                 if units else np.zeros(0))
        self._cum = np.cumsum(times) * self.time_scale
        self._round_t0 = time.perf_counter()
        self._running = True
        self._frozen_done = 0
        if self.telemetry is not None:
            self.telemetry.span_open(self.wid, "busy", round=self._round)
        self._round_task = asyncio.ensure_future(self._run_round())

    async def _run_round(self) -> None:
        rnd, units = self._round, self._units
        # real FLOPs first (one jitted call for the whole queue), then
        # sleep out the drawn service clock's remainder
        self.payload.compute(units)
        target = float(self._cum[-1]) if len(units) else 0.0
        remain = target - (time.perf_counter() - self._round_t0)
        if remain > 0:
            await asyncio.sleep(remain)
        self._running = False
        self._frozen_done = len(units)
        if self.telemetry is not None:
            self.telemetry.span_close(self.wid, units=len(units))
            self.telemetry.span_open(self.wid, "idle")
        try:
            await self.comm.send({"type": "round_done", "worker": self.wid,
                                  "round": rnd, "done": len(units)})
        except CommClosedError:
            pass

    # -- RPC dispatch -------------------------------------------------------

    def _handle(self, msg: Dict) -> Dict:
        kind = msg.get("type")
        if kind == "assign":
            self._start_round(msg["round"], msg["units"])
            return {"ok": True, "n": len(self._units)}
        if kind == "poll":
            return {"round": self._round, "done": self._done_now(),
                    "running": self._running}
        if kind == "stop":
            done = self._freeze() if self._running else self._frozen_done
            return {"round": self._round, "done": done}
        if kind == "shutdown":
            return {"ok": True}
        return {"error": f"unknown rpc {kind!r}"}

    async def _serve(self) -> None:
        while True:
            msg = await self.comm.recv()
            seq = msg.get("seq")
            if seq in self._replies:
                reply = self._replies[seq]       # retried rpc: idempotent
            else:
                reply = {"type": "reply", "seq": seq, **self._handle(msg)}
                self._replies[seq] = reply
            await self.comm.send(reply)
            if msg.get("type") == "shutdown":
                return

    async def _die(self) -> None:
        await asyncio.sleep(self.die_after)
        self._dead = True
        if self.telemetry is not None:
            self.telemetry.event("worker_died", worker=self.wid)
            self.telemetry.span_close(self.wid)
        if self._round_task is not None and not self._round_task.done():
            self._round_task.cancel()
        self._serve_task.cancel()

    async def run(self) -> None:
        self.comm = await self.transport.connect(self.address)
        if self.telemetry is not None:
            self.telemetry.span_open(self.wid, "idle")
        await self.comm.send({"type": "hello", "worker": self.wid})
        self._serve_task = asyncio.ensure_future(self._serve())
        killer = (asyncio.ensure_future(self._die())
                  if self.die_after is not None else None)
        try:
            await self._serve_task
        except (asyncio.CancelledError, CommClosedError):
            pass
        finally:
            if killer is not None:
                killer.cancel()
            if self._round_task is not None and not self._round_task.done():
                self._round_task.cancel()
            if not self._dead and self.comm is not None:
                # a DEAD worker leaves its comm open: silence, not a
                # clean close, is what the coordinator must survive
                await self.comm.close()


__all__ = ["Worker"]
