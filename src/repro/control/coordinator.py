"""Live coordinator: executes registry schemes over a real transport.

The ``Coordinator`` is the asyncio master of one live episode.  It owns
a transport ``Listener``, handshakes K in-process ``Worker`` tasks, and
then drives one of two execution paths -- BOTH reusing the existing
schemes' planning logic, with zero new policy code:

* **exchange path** -- any scheme with ``make_scheduler`` (work_exchange,
  work_exchange_unknown, fixed, uniform, trace_replay): the paper's
  stop-flag protocol over real messages.  Each round, the
  ``MasterScheduler``'s queues are shipped via ``assign`` RPCs; the
  coordinator waits for the first ``round_done`` push (all of them when
  ``wait_all``), broadcasts ``stop``, collects per-worker done counts,
  and feeds them back through ``sched.report`` -- so estimation,
  thresholds, and N_comm accounting are exactly the simulated
  protocol's.
* **coded path** -- redundant schemes flagged ``live_cover`` (mds,
  het_mds, hedged): one shot of ``scheme.plan``'s queues, complete at
  the earliest instant the fully-finished workers' assigned sizes cover
  N (het_mds's cover rule; equals hedged's replica race exactly, and
  MDS's L-th order statistic whenever ceil(N/m) == L).

Fault handling: every RPC retries with exponential backoff
(``timeout_s * backoff**attempt``); a worker that exhausts its budget is
declared lost, its last polled done count stands as its contribution,
and ``sched.mark_failed`` returns its leftover units to the pool for
reassignment -- the episode completes degraded rather than hanging.

``run_live``/``run_live_grid`` are the synchronous entry points: one
fresh event loop per episode, ``MCReport`` out, with the telemetry
timeline and the conservation ledger in ``extra["control_plane"]``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.exchange import Assignment, MasterScheduler
from repro.core.types import HetSpec
from repro.core.schemes import MCReport, _report, get_scheme

from .compute import HAVE_JAX, MatmulPayload
from .config import LiveConfig
from .telemetry import Telemetry
from .transport import Comm, CommClosedError
from .worker import Worker


class WorkerLost(Exception):
    """An RPC to this worker exhausted its timeout/retry budget."""

    def __init__(self, wid: int):
        super().__init__(f"worker {wid} lost (retries exhausted)")
        self.wid = wid


class WorkerProxy:
    """Coordinator-side handle for one worker's comm."""

    def __init__(self, wid: int, comm: Comm, cfg: LiveConfig,
                 telemetry: Telemetry, push_sink: "asyncio.Queue",
                 seq_counter):
        self.wid = wid
        self.comm = comm
        self.cfg = cfg
        self.tel = telemetry
        self.push_sink = push_sink
        self.seq = seq_counter
        self.lost = False
        self.last_done = 0            # freshest progress seen via poll
        self._pending: Dict[int, asyncio.Future] = {}
        self._recv_task = asyncio.ensure_future(self._recv_loop())

    async def _recv_loop(self) -> None:
        try:
            while True:
                msg = await self.comm.recv()
                self.tel.count("messages_received")
                if msg.get("type") == "reply":
                    fut = self._pending.pop(msg.get("seq"), None)
                    if fut is not None and not fut.done():
                        fut.set_result(msg)
                else:
                    # stamp ARRIVAL time: round-end detection must not be
                    # skewed by how long the round loop took to drain
                    self.push_sink.put_nowait((self.wid, msg,
                                               self.tel.now()))
        except (CommClosedError, asyncio.CancelledError):
            pass

    async def rpc(self, msg: Dict) -> Dict:
        """Send, await the matching reply; retry with backoff; raise
        ``WorkerLost`` when the budget is gone."""
        if self.lost:
            raise WorkerLost(self.wid)
        seq = next(self.seq)
        msg = {**msg, "seq": seq}
        fut = asyncio.get_event_loop().create_future()
        self._pending[seq] = fut
        timeout = float(self.cfg.timeout_s)
        try:
            for attempt in range(int(self.cfg.retries) + 1):
                if attempt:
                    self.tel.count("rpc_retries")
                    self.tel.event("rpc_retry", worker=self.wid,
                                   rpc=msg["type"], attempt=attempt)
                try:
                    await self.comm.send(msg)
                    self.tel.count("messages_sent")
                except CommClosedError:
                    break
                try:
                    # shield: a reply raced from an earlier attempt must
                    # still be able to land on this future
                    return await asyncio.wait_for(asyncio.shield(fut),
                                                  timeout)
                except asyncio.TimeoutError:
                    timeout *= float(self.cfg.backoff)
        finally:
            self._pending.pop(seq, None)
        self.lost = True
        self.tel.event("worker_lost", worker=self.wid, rpc=msg["type"])
        self.tel.count("workers_lost")
        raise WorkerLost(self.wid)

    async def close(self) -> None:
        self._recv_task.cancel()
        try:
            await self.comm.close()
        except CommClosedError:
            pass


@dataclasses.dataclass
class EpisodeStats:
    """One live episode's measured outcome (model units + wall split)."""
    t_comp: float                 # measured, model seconds
    iterations: int
    n_comm: float
    episode_wall_s: float         # first dispatch -> episode complete
    rounds_wall_s: float          # sum of in-round walls
    lost_workers: List[int]
    ledger: Dict[str, int]

    @property
    def coordination_wall_s(self) -> float:
        return max(self.episode_wall_s - self.rounds_wall_s, 0.0)


class Coordinator:
    """Master of one live episode over a pluggable transport."""

    def __init__(self, het: HetSpec, cfg: LiveConfig, time_scale: float,
                 payload: MatmulPayload, telemetry: Telemetry,
                 seed: int = 0, expected_wall_s: Optional[float] = None):
        self.het = het
        self.K = het.K
        self.cfg = cfg
        self.time_scale = float(time_scale)
        self.payload = payload
        self.tel = telemetry
        self.seed = int(seed)
        self.expected_wall_s = (float(expected_wall_s)
                                if expected_wall_s is not None
                                else float(cfg.target_wall_s))
        self.transport = cfg.build_transport()
        self.proxies: Dict[int, WorkerProxy] = {}
        self.pushes: asyncio.Queue = asyncio.Queue()
        self._seq = itertools.count()
        self._hello_done: Optional[asyncio.Future] = None
        self._worker_tasks: List[asyncio.Future] = []
        self.listener = None

    # -- lifecycle ----------------------------------------------------------

    async def _handle_comm(self, comm: Comm) -> None:
        msg = await comm.recv()
        if msg.get("type") != "hello":
            await comm.close()
            return
        wid = int(msg["worker"])
        self.proxies[wid] = WorkerProxy(wid, comm, self.cfg, self.tel,
                                        self.pushes, self._seq)
        self.tel.event("hello", worker=wid)
        if (self._hello_done is not None and not self._hello_done.done()
                and len(self.proxies) == self.K):
            self._hello_done.set_result(None)

    async def start(self) -> None:
        self._hello_done = asyncio.get_event_loop().create_future()
        self.listener = self.transport.listen(self._handle_comm)
        await self.listener.start()
        for wid in range(self.K):
            die_after = None
            if (self.cfg.kill_worker is not None
                    and int(self.cfg.kill_worker) == wid):
                die_after = (float(self.cfg.kill_after_frac)
                             * self.expected_wall_s)
            w = Worker(self.transport, self.listener.address, wid,
                       rate=float(self.het.lambdas[wid]),
                       time_scale=self.time_scale, payload=self.payload,
                       seed=self.seed * 100003 + wid, telemetry=self.tel,
                       die_after=die_after)
            self._worker_tasks.append(asyncio.ensure_future(w.run()))
        # hellos ride the (possibly flaky) transport too: bound the wait
        await asyncio.wait_for(self._hello_done,
                               10.0 * self.cfg.timeout_s * self.K)

    async def shutdown(self) -> None:
        for proxy in self.proxies.values():
            if not proxy.lost:
                try:
                    await proxy.rpc({"type": "shutdown"})
                except WorkerLost:
                    pass
        for proxy in self.proxies.values():
            await proxy.close()
        if self.listener is not None:
            await self.listener.stop()
        for t in self._worker_tasks:
            t.cancel()
        await asyncio.gather(*self._worker_tasks, return_exceptions=True)

    # -- round machinery ----------------------------------------------------

    async def _dispatch(self, rnd: int, queues: List[List[int]],
                        ledger: Dict[str, int]) -> Tuple[Set[int], Set[int]]:
        """Assign nonempty queues; returns (participants, lost_at_assign)."""
        participants = {k for k, q in enumerate(queues)
                        if q and k in self.proxies
                        and not self.proxies[k].lost}
        for k in participants:
            # a stale count from an earlier round must never be credited
            # to this one (a worker lost at assign contributes zero)
            self.proxies[k].last_done = 0
        results = await asyncio.gather(
            *(self.proxies[k].rpc({"type": "assign", "round": rnd,
                                   "units": list(queues[k])})
              for k in sorted(participants)),
            return_exceptions=True)
        lost = {k for k, res in zip(sorted(participants), results)
                if isinstance(res, WorkerLost)}
        for k in sorted(participants):
            ledger["units_dispatched"] += len(queues[k])
        self.tel.event("round_start", round=rnd,
                       sizes=[len(q) for q in queues])
        return participants, lost

    async def _await_round(self, rnd: int, queues: List[List[int]],
                           pending: Set[int], wait_all: bool,
                           cover_target: Optional[int] = None,
                           sizes: Optional[np.ndarray] = None
                           ) -> Tuple[Set[int], Set[int], float]:
        """Wait until the round's end condition; returns
        ``(finished, lost, t_end)`` with ``t_end`` the detection time.

        End conditions: first finisher (exchange round), all finishers
        (``wait_all``), or -- when ``cover_target`` is set -- the first
        instant the finished workers' ``sizes`` sum to the target."""
        finished: Set[int] = set()
        lost: Set[int] = set()
        t_end = self.tel.now()

        def end_reached() -> bool:
            if not (pending - finished - lost):
                return True          # nobody left running
            if cover_target is not None:
                return sum(int(sizes[k]) for k in finished) >= cover_target
            if wait_all:
                return False
            return bool(finished)

        while not end_reached():
            try:
                wid, msg, t_arrived = await asyncio.wait_for(
                    self.pushes.get(), self.cfg.poll_s)
                if (msg.get("type") == "round_done"
                        and msg.get("round") == rnd and wid in pending):
                    finished.add(wid)
                    self.proxies[wid].last_done = int(msg["done"])
                    t_end = t_arrived
                    self.tel.event("round_done", worker=wid, round=rnd,
                                   done=int(msg["done"]))
                else:
                    self.tel.count("stale_pushes")
                continue             # drain pushes before polling again
            except asyncio.TimeoutError:
                pass
            # poll survivors in parallel: liveness probe + dropped-push
            # recovery, bounded by ONE rpc budget rather than K of them
            targets = sorted(pending - finished - lost)
            replies = await asyncio.gather(
                *(self.proxies[k].rpc({"type": "poll"}) for k in targets),
                return_exceptions=True)
            for k, r in zip(targets, replies):
                if isinstance(r, WorkerLost):
                    lost.add(k)
                    continue
                if isinstance(r, BaseException):
                    raise r
                if r.get("round") != rnd:
                    continue
                self.proxies[k].last_done = int(r["done"])
                if not r.get("running") and int(r["done"]) == len(queues[k]):
                    finished.add(k)
                    t_end = self.tel.now()
                    self.tel.event("round_done_via_poll", worker=k,
                                   round=rnd, done=int(r["done"]))
        return finished, lost, t_end

    async def _collect(self, rnd: int, queues: List[List[int]],
                       pending: Set[int], finished: Set[int],
                       lost: Set[int]) -> np.ndarray:
        """Stop still-running workers; per-worker final done counts."""
        done = np.zeros(self.K, dtype=np.int64)
        for k in finished:
            done[k] = len(queues[k])
        for k in sorted(pending - finished - lost):
            try:
                r = await self.proxies[k].rpc({"type": "stop"})
                done[k] = (int(r["done"]) if r.get("round") == rnd
                           else self.proxies[k].last_done)
            except WorkerLost:
                lost.add(k)
        for k in lost:
            done[k] = min(self.proxies[k].last_done, len(queues[k]))
        return done

    # -- execution paths ----------------------------------------------------

    async def run_exchange(self, sched: MasterScheduler) -> EpisodeStats:
        """The stop-flag protocol: MasterScheduler plans, workers run."""
        ledger = {"units_dispatched": 0, "units_completed": 0,
                  "units_reassigned": 0}
        lost_workers: List[int] = []
        rounds_wall = 0.0
        rnd = 0
        t_episode0 = None
        while not sched.finished:
            a = sched.next_assignment()
            if a is None:
                break
            t0 = self.tel.now()
            if t_episode0 is None:
                t_episode0 = t0
            participants, lost = await self._dispatch(rnd, a.queues, ledger)
            finished, lost2, t_end = await self._await_round(
                rnd, a.queues, participants - lost, a.wait_all)
            lost |= lost2
            done = await self._collect(rnd, a.queues,
                                       participants - lost, finished, lost)
            elapsed_wall = max(t_end - t0, 0.0)
            rounds_wall += elapsed_wall
            sched.report(done, elapsed_wall / self.time_scale)
            for k in sorted(lost):
                sched.mark_failed(k)
                lost_workers.append(k)
            ledger["units_completed"] += int(done.sum())
            ledger["units_reassigned"] += int(
                sum(len(a.queues[k]) for k in range(self.K)) - done.sum())
            self.tel.event("round_report", round=rnd,
                           done=[int(d) for d in done],
                           elapsed_model=round(
                               elapsed_wall / self.time_scale, 6))
            rnd += 1
            if rnd > 100_000:
                raise RuntimeError("live exchange failed to converge")
        episode_wall = (self.tel.now() - t_episode0
                        if t_episode0 is not None else 0.0)
        return EpisodeStats(
            t_comp=sched.t_comp, iterations=sched.iterations,
            n_comm=float(sched.n_comm), episode_wall_s=episode_wall,
            rounds_wall_s=rounds_wall, lost_workers=lost_workers,
            ledger=ledger)

    async def run_coded(self, plan: Assignment, N: int) -> EpisodeStats:
        """One-shot redundant run, complete at size-cover >= N."""
        ledger = {"units_dispatched": 0, "units_completed": 0,
                  "units_reassigned": 0}
        sizes = plan.sizes
        t0 = self.tel.now()
        participants, lost = await self._dispatch(0, plan.queues, ledger)
        finished, lost2, t_end = await self._await_round(
            0, plan.queues, participants - lost, wait_all=False,
            cover_target=N, sizes=sizes)
        lost |= lost2
        covered = sum(int(sizes[k]) for k in finished) >= N
        done = await self._collect(0, plan.queues, participants - lost,
                                   finished, lost)
        elapsed_wall = max(t_end - t0, 0.0)
        ledger["units_completed"] += int(done.sum())
        ledger["units_reassigned"] += int(sizes.sum() - done.sum())
        if not covered:
            self.tel.event("cover_incomplete", covered=int(
                sum(int(sizes[k]) for k in finished)), target=N)
        episode_wall = self.tel.now() - t0
        return EpisodeStats(
            t_comp=elapsed_wall / self.time_scale, iterations=1,
            n_comm=float(int(sizes.sum()) - N),
            episode_wall_s=episode_wall, rounds_wall_s=elapsed_wall,
            lost_workers=sorted(lost), ledger=ledger)


# ---------------------------------------------------------------------------
# synchronous entry points
# ---------------------------------------------------------------------------

def live_supported(scheme) -> str:
    """Which live path a scheme instance runs on: ``"exchange"`` (it has
    an executable master protocol) or ``"coded"`` (redundant with the
    size-cover rule).  Raises ``ValueError`` -- at compile time, not
    mid-episode -- for schemes with neither."""
    if getattr(scheme, "live_cover", False):
        return "coded"
    if getattr(scheme, "cover_scheduler", False):
        # the training subsystem's one-shot CoverScheduler takes
        # whole-queue finish-time feedback, which the live round-trip
        # loop cannot provide
        raise ValueError(
            f"scheme {scheme.name!r} cannot run live: its scheduler is a "
            f"one-shot cover protocol (training-only), and it declares "
            f"no live cover rule (live_cover)")
    try:
        scheme.make_scheduler([0], rates=np.ones(1))
        return "exchange"
    except NotImplementedError:
        raise ValueError(
            f"scheme {scheme.name!r} cannot run live: no executable "
            f"master protocol (make_scheduler) and no cover rule "
            f"(live_cover)") from None


def _expected_model_seconds(scheme, het: HetSpec, N: int) -> float:
    """Cheap per-episode duration estimate used only for wall scaling."""
    sizes = np.asarray(scheme.initial_sizes(het, N), dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        per = np.where(sizes > 0, sizes / het.lambdas, 0.0)
    return float(max(per.max(), 1e-9))


async def _episode(scheme, het: HetSpec, N: int, cfg: LiveConfig,
                   time_scale: float, expected_model_s: float,
                   telemetry: Telemetry, seed: int) -> EpisodeStats:
    if live_supported(scheme) == "exchange":
        sched = scheme.make_scheduler(range(N), rates=het.lambdas)
        plan = None
    else:
        sched = None
        plan = scheme.plan(het, N)
    units = N if plan is None else int(plan.sizes.sum())
    payload = MatmulPayload(units, cfg.unit_rows, cfg.unit_dim, seed=seed)
    max_q = units if plan is None else int(plan.sizes.max())
    payload.warmup(max_q)           # compile outside the measured episode
    telemetry.start()
    coord = Coordinator(het, cfg, time_scale, payload, telemetry,
                        seed=seed,
                        expected_wall_s=expected_model_s * time_scale)
    await coord.start()
    try:
        if sched is not None:
            stats = await coord.run_exchange(sched)
        else:
            stats = await coord.run_coded(plan, N)
    finally:
        await coord.shutdown()
    telemetry.close_all()
    stats.ledger["payload_flops"] = int(payload.flops)
    stats.ledger["payload_verified"] = bool(payload.verify())
    return stats


def run_live(scheme_name: str, params: Dict[str, Any], het: HetSpec,
             N: int, cfg: LiveConfig, trials: int,
             seed: int = 0) -> MCReport:
    """``trials`` live episodes of one scheme at one grid point."""
    scheme = get_scheme(scheme_name, **params)
    expected = _expected_model_seconds(scheme, het, N)
    time_scale = cfg.resolve_time_scale(expected)
    ts = np.empty(trials)
    its = np.empty(trials)
    cs = np.empty(trials)
    walls = np.empty(trials)
    coord_walls = np.empty(trials)
    ledger = {"units_dispatched": 0, "units_completed": 0,
              "units_reassigned": 0, "payload_flops": 0}
    lost: List[int] = []
    tel = Telemetry()
    for t in range(trials):
        tel = Telemetry()
        stats = asyncio.run(
            _episode(scheme, het, N, cfg, time_scale, expected, tel,
                     seed=seed * 1009 + t))
        ts[t], its[t], cs[t] = stats.t_comp, stats.iterations, stats.n_comm
        walls[t] = stats.episode_wall_s
        coord_walls[t] = stats.coordination_wall_s
        for key in ("units_dispatched", "units_completed",
                    "units_reassigned", "payload_flops"):
            ledger[key] += stats.ledger[key]
        lost.extend(stats.lost_workers)
        if not stats.ledger["payload_verified"]:
            raise RuntimeError(f"live payload verification failed for "
                               f"{scheme_name} trial {t}")
    control = {
        "transport": cfg.transport,
        "time_scale": float(time_scale),
        "expected_model_s": float(expected),
        "measured_t_comp": float(ts.mean()),
        "episode_wall_s": float(walls.mean()),
        "coordination_wall_s": float(coord_walls.mean()),
        "coordination_frac": float(
            coord_walls.mean() / max(walls.mean(), 1e-12)),
        "workers_lost": sorted(set(lost)),
        "ledger": ledger,
        "payload_backend": "jax" if HAVE_JAX else "numpy",
        "timeline": tel.to_dict(),     # last episode, representative
    }
    return _report(scheme.name, ts, its, cs,
                   extra={"control_plane": control})


def run_live_grid(scheme_name: str, params: Dict[str, Any],
                  het_specs: Sequence[HetSpec], N: int, cfg: LiveConfig,
                  trials: int, seed: int = 0,
                  rate_schedules=None) -> List[MCReport]:
    """``run_live`` across a scenario grid, one MCReport per spec.

    Live episodes always execute at each grid point's *nominal* rates;
    when the scenario family supplies per-round ``rate_schedules`` the
    reports are stamped ``nominal_rates_only`` (the mc-engine
    convention for schemes that cannot follow a schedule)."""
    out = []
    for g, het in enumerate(het_specs):
        rep = run_live(scheme_name, params, het, N, cfg, trials,
                       seed=seed + g)
        if rate_schedules is not None and rate_schedules[g] is not None:
            rep.extra["nominal_rates_only"] = 1     # mc-engine convention
        out.append(rep)
    return out


__all__ = [
    "Coordinator", "WorkerProxy", "WorkerLost", "EpisodeStats",
    "live_supported", "run_live", "run_live_grid",
]
