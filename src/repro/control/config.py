"""``LiveConfig``: the live-execution axis of an ExperimentSpec.

A frozen, JSON-lossless value (the ``ServingConfig`` discipline) that
turns a Monte-Carlo experiment into a *live* one: set
``ExperimentSpec(execution="live", live=LiveConfig(...))`` and every
scheme task runs through the asyncio control plane
(``repro.control.coordinator``) over the configured transport -- real
message round-trips, real jitted matmul shards, measured wall-clock
coordination cost -- instead of through ``Scheme.mc_grid``.  One
``MCReport`` per grid point, ``spec.trials`` live episodes each, with
the telemetry timeline in ``extra["control_plane"]``.

Specs WITHOUT live execution serialize exactly as before (both the
``execution`` and ``live`` keys are omitted at their defaults), so
every pre-PR-7 ``spec_hash`` and store address survives.

Knobs:

``transport`` / ``transport_params``
    A registered transport (``repro.control.list_transports()``) and
    its constructor params -- ``("flaky", {"drop": 0.2, "seed": 7})``
    injects message loss to exercise retries.
``time_scale`` / ``target_wall_s``
    Wall seconds per model second.  Service times are drawn per unit
    from the worker's Exp(1/lambda_k) model clock and realized as wall
    time through this factor; ``None`` auto-scales each grid point so
    one episode's expected compute is ``target_wall_s``.
``unit_rows`` / ``unit_dim``
    The real payload: unit u is the row block ``A[u*rows:(u+1)*rows]``
    of one shared ``A @ x`` matmul (jitted when jax is available), so a
    live run computes an actual sharded product while the drawn service
    clock governs pacing.
``timeout_s`` / ``retries`` / ``backoff``
    Coordinator-side RPC discipline: each request waits ``timeout_s *
    backoff**attempt`` for its reply and is re-sent up to ``retries``
    times; a worker that exhausts the budget is declared lost and its
    leftover units are reassigned.
``poll_s``
    Progress-poll period while waiting on a round (also the worker
    liveness probe).
``kill_worker`` / ``kill_after_frac``
    Fault injection: silently halt worker ``kill_worker`` after that
    fraction of the episode's expected wall time (``None`` = no fault).
    Part of the config -- and hence the spec hash -- so fault runs are
    content-addressed like any other.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

from .transport import get_transport, list_transports


@dataclasses.dataclass(frozen=True)
class LiveConfig:
    """The live-execution axis as one hashable value."""

    transport: str = "inproc"
    transport_params: Tuple[Tuple[str, Any], ...] = ()
    time_scale: Optional[float] = None
    target_wall_s: float = 1.0
    unit_rows: int = 4
    unit_dim: int = 64
    timeout_s: float = 1.0
    retries: int = 2
    backoff: float = 1.5
    poll_s: float = 0.05
    kill_worker: Optional[int] = None
    kill_after_frac: float = 0.25

    def __post_init__(self):
        if isinstance(self.transport_params, Mapping):
            items = self.transport_params.items()
        else:
            items = tuple(self.transport_params)
        object.__setattr__(self, "transport_params",
                           tuple(sorted((str(k), v) for k, v in items)))
        if self.time_scale is not None and float(self.time_scale) <= 0:
            raise ValueError("time_scale must be positive (or None for "
                             "auto)")
        if float(self.target_wall_s) <= 0:
            raise ValueError("target_wall_s must be positive")
        if int(self.unit_rows) <= 0 or int(self.unit_dim) <= 0:
            raise ValueError("unit_rows and unit_dim must be positive")
        if float(self.timeout_s) <= 0 or float(self.poll_s) <= 0:
            raise ValueError("timeout_s and poll_s must be positive")
        if int(self.retries) < 0:
            raise ValueError("retries must be >= 0")
        if float(self.backoff) < 1.0:
            raise ValueError("backoff must be >= 1")
        if self.kill_worker is not None and int(self.kill_worker) < 0:
            raise ValueError("kill_worker must be a worker index or None")
        if not 0.0 < float(self.kill_after_frac) <= 1.0:
            raise ValueError("kill_after_frac must be in (0, 1]")
        # fail at construction, not mid-run: unknown transport names or
        # params raise KeyError listing the registry
        get_transport(self.transport, **self.transport_params_dict)

    @property
    def transport_params_dict(self) -> Dict[str, Any]:
        return dict(self.transport_params)

    def build_transport(self):
        return get_transport(self.transport, **self.transport_params_dict)

    def resolve_time_scale(self, expected_model_s: float) -> float:
        """Wall seconds per model second for a grid point whose expected
        compute span is ``expected_model_s`` model seconds."""
        if self.time_scale is not None:
            return float(self.time_scale)
        return float(self.target_wall_s) / max(expected_model_s, 1e-9)

    # -- serialization (every knob appears: the dict is the hash input) -----

    def to_dict(self) -> Dict[str, Any]:
        return {
            "transport": self.transport,
            "transport_params": self.transport_params_dict,
            "time_scale": (None if self.time_scale is None
                           else float(self.time_scale)),
            "target_wall_s": float(self.target_wall_s),
            "unit_rows": int(self.unit_rows),
            "unit_dim": int(self.unit_dim),
            "timeout_s": float(self.timeout_s),
            "retries": int(self.retries),
            "backoff": float(self.backoff),
            "poll_s": float(self.poll_s),
            "kill_worker": (None if self.kill_worker is None
                            else int(self.kill_worker)),
            "kill_after_frac": float(self.kill_after_frac),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "LiveConfig":
        allowed = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - allowed
        if unknown:
            raise KeyError(f"unknown live key(s) {sorted(unknown)}; "
                           f"allowed {sorted(allowed)} (registered "
                           f"transports: {list_transports()})")
        kwargs = dict(d)
        if "transport_params" in kwargs:
            kwargs["transport_params"] = tuple(kwargs["transport_params"]
                                               .items())
        return cls(**kwargs)


__all__ = ["LiveConfig"]
