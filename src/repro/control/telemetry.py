"""Structured telemetry timeline for live control-plane episodes.

One ``Telemetry`` per episode collects three shapes of evidence, all
stamped with wall-clock seconds since ``start()``:

* **events** -- per-message coordination records (rpc sends, replies,
  retries, drops detected, worker loss, exchange-round markers), capped
  so a pathological run can't bloat a report;
* **counters** -- monotone tallies (units dispatched / completed /
  reassigned, rpc retries, messages); the conservation identity
  ``dispatched == completed + reassigned`` is checked from these;
* **spans** -- per-worker occupancy intervals (busy computing a round
  vs. idle awaiting assignment), from which per-worker occupancy and
  throughput summaries are derived.

``to_dict()`` renders the whole timeline JSON-safe for
``MCReport.extra["control_plane"]``.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Any, Dict, List, Optional

MAX_EVENTS = 2000


class Telemetry:
    """Append-only episode timeline (events, counters, worker spans)."""

    def __init__(self, max_events: int = MAX_EVENTS):
        self.max_events = int(max_events)
        self.events: List[Dict[str, Any]] = []
        self.dropped_events = 0
        self.counters: Dict[str, int] = defaultdict(int)
        self.spans: Dict[int, List[Dict[str, float]]] = defaultdict(list)
        self._open: Dict[int, Dict[str, Any]] = {}
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        if self._t0 is None:
            return 0.0
        return time.perf_counter() - self._t0

    def event(self, kind: str, **fields: Any) -> None:
        if len(self.events) >= self.max_events:
            self.dropped_events += 1
            return
        rec = {"t": round(self.now(), 6), "kind": kind}
        rec.update(fields)
        self.events.append(rec)

    def count(self, key: str, n: int = 1) -> None:
        self.counters[key] += int(n)

    def span_open(self, worker: int, state: str, **fields: Any) -> None:
        self.span_close(worker)
        self._open[worker] = {"state": state, "t0": self.now(), **fields}

    def span_close(self, worker: int, **fields: Any) -> None:
        rec = self._open.pop(worker, None)
        if rec is None:
            return
        rec.update(fields)
        t0 = rec.pop("t0")
        t1 = self.now()
        self.spans[worker].append(
            {"t0": round(t0, 6), "t1": round(t1, 6), **rec})

    def close_all(self) -> None:
        for worker in list(self._open):
            self.span_close(worker)

    # -- summaries ----------------------------------------------------------

    def occupancy(self) -> Dict[int, Dict[str, float]]:
        """Per-worker busy/idle wall seconds and units-per-wall-second
        throughput, from the recorded spans."""
        out: Dict[int, Dict[str, float]] = {}
        for worker, spans in sorted(self.spans.items()):
            busy = sum(s["t1"] - s["t0"] for s in spans
                       if s["state"] == "busy")
            idle = sum(s["t1"] - s["t0"] for s in spans
                       if s["state"] == "idle")
            units = sum(int(s.get("units", 0)) for s in spans
                        if s["state"] == "busy")
            out[worker] = {
                "busy_s": round(busy, 6),
                "idle_s": round(idle, 6),
                "units_done": units,
                "throughput_units_per_s":
                    round(units / busy, 3) if busy > 0 else 0.0,
            }
        return out

    def to_dict(self, events_tail: int = 200) -> Dict[str, Any]:
        """JSON-safe timeline; only the last ``events_tail`` events are
        embedded verbatim (the counters and spans carry the totals).
        Raw per-worker spans ride along under ``"spans"`` -- what the
        occupancy-timeline figure (``benchmarks/fig_timeline``) renders."""
        self.close_all()
        return {
            "counters": dict(sorted(self.counters.items())),
            "occupancy": {str(k): v for k, v in self.occupancy().items()},
            "spans": {str(k): list(v)
                      for k, v in sorted(self.spans.items())},
            "n_events": len(self.events) + self.dropped_events,
            "events": self.events[-int(events_tail):],
        }


__all__ = ["Telemetry", "MAX_EVENTS"]
