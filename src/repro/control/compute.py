"""The live workers' real payload: row-block shards of one A @ x.

Unit ``u`` is the row block ``A[u*rows:(u+1)*rows]``; a worker assigned
a queue of units computes the concatenated block's matvec in ONE jitted
call per round (padded to a power-of-two unit count so a handful of
traces serve every queue length).  Without jax the same contract runs
on numpy -- the control plane never hard-depends on an accelerator
stack.

The drawn Exp(1/lambda_k) service clock -- not the matmul wall time --
governs pacing (the worker sleeps out the remainder), so the executed
run matches the paper's service model statistically while still doing
real FLOPs whose throughput the telemetry records.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

try:                                    # optional accelerator path
    import jax
    import jax.numpy as jnp

    @jax.jit
    def _matvec(a, x):
        return a @ x

    HAVE_JAX = True
except Exception:                       # pragma: no cover - numpy-only host
    HAVE_JAX = False


def _bucket(n: int) -> int:
    """Next power-of-two unit count: few shapes, few (re)traces."""
    return 1 << max(int(n) - 1, 0).bit_length()


class MatmulPayload:
    """One shared ``A @ x`` product, computed live in unit row-blocks."""

    def __init__(self, units: int, unit_rows: int, unit_dim: int,
                 seed: int = 0):
        self.units = int(units)
        self.unit_rows = int(unit_rows)
        self.unit_dim = int(unit_dim)
        rng = np.random.default_rng(seed)
        rows = self.units * self.unit_rows
        self.A = rng.standard_normal((rows, self.unit_dim)).astype(
            np.float32)
        self.x = rng.standard_normal(self.unit_dim).astype(np.float32)
        self.y = np.zeros(rows, dtype=np.float32)
        self.done = np.zeros(self.units, dtype=bool)
        self.flops = 0              # multiply-adds issued so far
        self.backend = "jax" if HAVE_JAX else "numpy"

    def _rows_for(self, unit_ids: Sequence[int]) -> np.ndarray:
        ids = np.asarray(unit_ids, dtype=np.int64)
        return (ids[:, None] * self.unit_rows
                + np.arange(self.unit_rows)[None, :]).reshape(-1)

    def compute(self, unit_ids: Sequence[int]) -> Tuple[int, int]:
        """Compute the blocks for ``unit_ids``; returns (units, rows)."""
        if len(unit_ids) == 0:
            return 0, 0
        rows = self._rows_for(unit_ids)
        block = self.A[rows]
        pad_units = _bucket(len(unit_ids))
        pad_rows = pad_units * self.unit_rows
        if pad_rows > block.shape[0]:
            block = np.concatenate(
                [block, np.zeros((pad_rows - block.shape[0],
                                  self.unit_dim), dtype=np.float32)])
        if HAVE_JAX:
            y = np.asarray(_matvec(jnp.asarray(block),
                                   jnp.asarray(self.x)))
        else:
            y = block @ self.x
        self.y[rows] = y[: rows.size]
        self.done[np.asarray(unit_ids, dtype=np.int64)
                  % self.units] = True
        self.flops += rows.size * self.unit_dim
        return len(unit_ids), int(rows.size)

    def warmup(self, max_units: int) -> None:
        """Trace/compile every bucket up to ``max_units`` ahead of the
        episode clock, so compile time never pollutes measured spans."""
        n = 1
        while True:
            ids = list(range(min(n, self.units)))
            self.compute(ids)
            if n >= max_units:
                break
            n *= 2
        self.done[:] = False
        self.flops = 0

    def verify(self) -> bool:
        """Every computed block matches the reference product."""
        if not self.done.any():
            return True
        rows = self._rows_for(np.nonzero(self.done)[0])
        ref = (self.A[rows] @ self.x).astype(np.float32)
        return bool(np.allclose(self.y[rows], ref, rtol=1e-4, atol=1e-4))


__all__ = ["MatmulPayload", "HAVE_JAX"]
