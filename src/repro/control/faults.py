"""Fault-injection wrapper transport: latency, jitter, seeded drops.

``flaky`` wraps any inner transport (default ``inproc``) and perturbs
every ``send`` on both sides of every channel:

* ``delay`` + ``jitter``: per-message latency ``delay + U(0, jitter)``
  seconds, applied inline before handing the message to the inner comm
  (so per-channel FIFO order is preserved -- latency, not reordering);
* ``drop``: with probability ``drop`` the message is silently lost (the
  paper's control messages are tiny; loss, not corruption, is the
  realistic failure) -- which is exactly what exercises the
  coordinator's timeout + retry-with-backoff path and the worker-side
  seq dedup.

Draws come from one seeded ``default_rng`` per transport instance, so a
given message sequence sees a reproducible fault pattern.
"""
from __future__ import annotations

import asyncio
from typing import Dict, Optional

import numpy as np

from .transport import (Comm, HandleComm, Listener, Transport,
                        get_transport, register_transport)


class FlakyComm(Comm):
    def __init__(self, inner: Comm, rng: np.random.Generator,
                 delay: float, jitter: float, drop: float):
        self._inner = inner
        self._rng = rng
        self._delay = delay
        self._jitter = jitter
        self._drop = drop
        self.dropped = 0          # messages this side silently lost
        self._sent = 0

    async def send(self, msg: Dict) -> None:
        # the first message each side sends is its connection handshake
        # (hello / first reply): delivered faithfully, like a TCP accept
        # -- faults apply to the conversation, not to establishment
        self._sent += 1
        if self._sent == 1:
            await self._inner.send(msg)
            return
        if self._drop > 0.0 and self._rng.random() < self._drop:
            self.dropped += 1
            return
        lag = self._delay + (self._jitter * float(self._rng.random())
                             if self._jitter > 0.0 else 0.0)
        if lag > 0.0:
            await asyncio.sleep(lag)
        await self._inner.send(msg)

    async def recv(self, timeout: Optional[float] = None) -> Dict:
        return await self._inner.recv(timeout)

    async def close(self) -> None:
        await self._inner.close()

    @property
    def closed(self) -> bool:
        return self._inner.closed


@register_transport("flaky", aliases=("faulty",))
class FlakyTransport(Transport):
    """Latency/jitter/drop wrapper around an inner transport."""

    def __init__(self, inner: str = "inproc", delay: float = 0.0,
                 jitter: float = 0.0, drop: float = 0.0, seed: int = 0):
        if not 0.0 <= float(drop) < 1.0:
            raise ValueError(f"drop must be in [0, 1); got {drop}")
        if float(delay) < 0.0 or float(jitter) < 0.0:
            raise ValueError("delay and jitter must be >= 0")
        self._inner = get_transport(inner)
        self.delay = float(delay)
        self.jitter = float(jitter)
        self.drop = float(drop)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)

    def _wrap(self, comm: Comm) -> FlakyComm:
        return FlakyComm(comm, self._rng, self.delay, self.jitter,
                         self.drop)

    def listen(self, handle_comm: HandleComm,
               address: Optional[str] = None) -> Listener:
        async def handle_wrapped(comm: Comm) -> None:
            await handle_comm(self._wrap(comm))
        return self._inner.listen(handle_wrapped, address)

    async def connect(self, address: str) -> Comm:
        return self._wrap(await self._inner.connect(address))


__all__ = ["FlakyComm", "FlakyTransport"]
