"""Optimized-HLO text analysis: collective bytes + dot FLOPs, trip-count aware.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
empirically, DESIGN §5.3), and collective traffic is not in cost_analysis
at all.  This module parses ``compiled.as_text()`` (post-SPMD partitioning:
per-device shapes, explicit collective ops) and:

  * tabulates per-device wire bytes of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, using ring-algorithm
    cost factors and the op's replica-group size;
  * computes dot FLOPs from shapes + contracting dims;
  * multiplies anything inside a `while` body by its
    backend_config.known_trip_count, recursively through call/fusion sites.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
# op lines are `%name = <type> <op>(...)`; <type> may be a tuple containing
# spaces, commas and /*index=N*/ comments, so locate the first ` op(` token
# after the `=` instead of pattern-matching the type directly.
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_OPNAME_RE = re.compile(r"(?:^|\s)([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_type_bytes(t: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(t: str) -> Optional[tuple]:
    m = _SHAPE_RE.search(t)
    if not m:
        return None
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class OpRecord:
    kind: str
    bytes_wire: float = 0.0
    flops: float = 0.0


@dataclasses.dataclass
class Computation:
    name: str
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, int]
    dot_flops: float
    # (callee, multiplier) pairs: while bodies carry trip counts
    calls: List[tuple]
    is_entry: bool = False
    # f32-shipped wire bytes: the CPU host backend promotes bf16 matmuls
    # to f32, so collectives adjacent to them ship f32; on the real bf16
    # TPU target those flows are half as wide.  Tracked separately so the
    # roofline can report a bf16-normalized collective term.
    f32_bytes: float = 0.0
    # HBM-traffic estimate: sum of operand+result bytes at FUSION
    # boundaries (XLA's memory-traffic unit) and unfused ops; fusion
    # interiors are excluded.  Gives a trip-count-aware memory term from
    # scan-mode compiles (cost_analysis counts loop bodies once).
    mem_bytes: float = 0.0


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))           # [num_groups, group_size]
    m = _GROUPS_EXPL.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(kind: str, line: str, result_type: str,
                operand_shapes: List[int], n: int) -> float:
    """Per-device wire bytes under ring algorithms."""
    out_b = parse_type_bytes(result_type)
    in_b = sum(operand_shapes) if operand_shapes else out_b
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-gather":
        return out_b * f                 # receives (n-1)/n of the output
    if kind == "all-reduce":
        return 2.0 * in_b * f            # reduce-scatter + all-gather
    if kind == "reduce-scatter":
        return in_b * f
    if kind == "all-to-all":
        return in_b * f
    if kind == "collective-permute":
        return in_b
    return 0.0


def parse_hlo(text: str, n_devices: int) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    shapes: Dict[str, str] = {}
    pending_starts: Dict[str, tuple] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(1), defaultdict(float),
                              defaultdict(int), 0.0, [],
                              is_entry=line.startswith("ENTRY"))
            comps[cur.name] = cur
            shapes = {}
            # parameter shapes from the signature
            for pm in re.finditer(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+))",
                                  hdr.group(2)):
                shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, body = m.groups()
        om = _OPNAME_RE.search(body)
        if not om:
            continue
        rtype = body[: om.start()].strip()
        op = om.group(1)
        rest = body[om.end():]
        # keep operand scanning away from metadata/backend_config noise
        meta_at = rest.find("metadata=")
        if meta_at >= 0:
            rest = rest[:meta_at]
        shapes[name] = rtype
        if op in ("while",):
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            bm = _BODY_RE.search(line)
            cm = _COND_RE.search(line)
            if bm:
                cur.calls.append((bm.group(1), trip, "while"))
            if cm:
                cur.calls.append((cm.group(1), trip + 1, "while"))
            continue
        if op in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                  "conditional", "scatter", "select-and-scatter",
                  "reduce-window", "async-start"):
            kind = "call" if op in ("call", "conditional") else "fusion"
            for cm in _CALLS_RE.finditer(line):
                cur.calls.append((cm.group(1), 1, kind))
        # HBM traffic at this op boundary (skip pure control/layout ops)
        if op not in ("tuple", "get-tuple-element", "parameter", "bitcast",
                      "constant", "after-all"):
            b = parse_type_bytes(rtype)
            for om in re.finditer(r"%([\w\.\-]+)", rest):
                t = shapes.get(om.group(1))
                if t:
                    b += parse_type_bytes(t)
            cur.mem_bytes += b
        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES and not op.endswith("-done"):
            operand_bytes = []
            for om in re.finditer(r"%([\w\.\-]+)", rest):
                t = shapes.get(om.group(1))
                if t:
                    operand_bytes.append(parse_type_bytes(t))
            n = _group_size(line, n_devices)
            wire = _wire_bytes(base, line, rtype, operand_bytes, n)
            cur.collective_bytes[base] += wire
            cur.collective_counts[base] += 1
            if "f32[" in rtype:
                cur.f32_bytes += wire
        elif op == "dot":
            out_dims = _shape_dims(rtype) or ()
            lhs = re.search(r"%([\w\.\-]+)", rest)
            cdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if lhs and cdim and shapes.get(lhs.group(1)):
                ldims = _shape_dims(shapes[lhs.group(1)]) or ()
                for ci in cdim.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        k *= ldims[int(ci)]
            flops = 2.0 * k
            for d in out_dims:
                flops *= d
            cur.dot_flops += flops
    return comps


def aggregate(comps: Dict[str, Computation], entry: Optional[str] = None):
    """Roll up from the entry computation with while-trip multipliers."""
    if entry is None:
        marked = [n for n, c in comps.items() if c.is_entry]
        if marked:
            entry = marked[0]
        else:   # fallback: a computation nobody calls
            called = {c for comp in comps.values() for c, _ in comp.calls}
            roots = [n for n in comps if n not in called]
            entry = roots[0] if roots else next(iter(comps))

    memo: Dict[str, tuple] = {}

    def visit(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 50:
            return {}, {}, 0.0, 0.0, 0.0
        coll = dict(comp.collective_bytes)
        counts = dict(comp.collective_counts)
        flops = comp.dot_flops
        f32b = comp.f32_bytes
        memb = comp.mem_bytes
        for call in comp.calls:
            callee, mult = call[0], call[1]
            kind = call[2] if len(call) > 2 else "call"
            c2, n2, f2, fb2, mb2 = visit(callee, depth + 1)
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for k, v in n2.items():
                counts[k] = counts.get(k, 0) + mult * v
            flops += mult * f2
            f32b += mult * fb2
            if kind != "fusion":      # fusion interiors are not HBM traffic
                memb += mult * mb2
        memo[name] = (coll, counts, flops, f32b, memb)
        return memo[name]

    coll, counts, flops, f32b, memb = visit(entry)
    total = float(sum(coll.values()))
    return {"collective_bytes": coll, "collective_counts": counts,
            "dot_flops": flops, "entry": entry,
            "f32_collective_bytes": f32b,
            # bf16-normalized: f32 flows halve on the bf16 TPU target
            "collective_bytes_bf16norm": total - 0.5 * f32b,
            "mem_bytes": memb}


def analyze_compiled(compiled, n_devices: int) -> dict:
    text = compiled.as_text()
    comps = parse_hlo(text, n_devices)
    agg = aggregate(comps)
    agg["total_collective_bytes"] = float(
        sum(agg["collective_bytes"].values()))
    return agg
