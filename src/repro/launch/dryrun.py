import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices
# to build the production meshes.  (Do NOT set this in conftest/pyproject:
# smoke tests and benches must see 1 device.)

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import (SHAPES, get_config, list_configs, resolve_for_tp,
                           shape_applicable)
from repro.distributed import sharding as shd
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import build_model
from repro.optim import AdamW
from repro.train.loop import make_train_step

TP = 16


def _dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mode: str = "unroll", remat: str = "full",
               fsdp: bool = True, donate: bool = True, accum: int = 1):
    """Lower + compile one (arch x shape x mesh) cell; return result dict."""
    from repro.models import attention as attn_mod
    # NOTE (§Perf prefill iteration): statically-unrolled attention chunks
    # with causal block skipping cut prefill dot-FLOPs ~31% (phi3: 8.25e13
    # -> 5.69e13/dev) but all chunks' intermediates stay live until the
    # final stack (peak 5.5 -> 21 GiB) -- net refuted on the XLA path; the
    # Pallas flash kernel provides the skip without the blowup on TPU.
    attn_mod.UNROLL_CHUNKS = (mode == "unroll")

    shape = SHAPES[shape_name]
    cfg = resolve_for_tp(get_config(arch), TP)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch: long_500k inapplicable"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    model = build_model(cfg)
    dp = dp_axes(mesh)
    dp_total = _dp_size(mesh)
    B = shape.global_batch
    shardable = B % dp_total == 0
    dp_spec = dp if shardable else None

    t0 = time.time()
    pshape = model.param_specs()
    pspecs = shd.param_specs(cfg, pshape, TP, fsdp=fsdp and shape.is_train)
    in_specs = model.input_specs(shape)
    bspecs = shd.batch_specs(in_specs, dp_spec)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt = AdamW(lr=3e-4)
            oshape = jax.eval_shape(opt.init, pshape)
            ospecs = shd.opt_specs(cfg, oshape, pspecs)
            step = make_train_step(model, opt, mode=mode,
                                   remat=remat != "none", accum=accum)
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(pshape, oshape, in_specs)
        elif shape.kind == "prefill":
            cshape = model.cache_specs(B, shape.seq_len)
            cspecs = shd.cache_specs(cfg, cshape, dp_spec, TP, shardable)

            def prefill_step(params, batch, cache):
                return model.prefill(params, batch, cache, mode=mode)

            jitted = jax.jit(prefill_step,
                             in_shardings=(pspecs, bspecs, cspecs),
                             out_shardings=(None, cspecs),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(pshape, in_specs, cshape)
        else:  # decode
            # serving layout: per-layer (unstacked) cache buffers, unrolled
            # execution -- in-place donated updates instead of whole-stack
            # copies (EXPERIMENTS §Perf decode iteration)
            cshape = model.cache_specs(B, shape.seq_len, stacked=False)
            cspecs = shd.cache_specs(cfg, cshape, dp_spec, TP, shardable)

            def decode_step(params, cache, tokens):
                return model.decode_step(params, cache, tokens,
                                         mode="unroll")

            jitted = jax.jit(decode_step,
                             in_shardings=(pspecs, cspecs, bspecs["tokens"]),
                             out_shardings=(None, cspecs),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(pshape, cshape, in_specs["tokens"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = analyze_compiled(compiled, n_dev)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev, "mode": mode, "remat": remat, "fsdp": fsdp,
        "accum": accum,
        "batch_shardable": shardable,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "hlo": hlo,
    }
    print(f"[dryrun] {arch} x {shape_name} x "
          f"{'multi' if multi_pod else 'single'}: "
          f"compile {t_compile:.1f}s, "
          f"peak/dev {result['memory']['peak_bytes_est']/2**30:.2f} GiB, "
          f"flops/dev {result['cost_analysis']['flops']:.3e}, "
          f"dot_flops/dev {hlo['dot_flops']:.3e}, "
          f"coll/dev {hlo['total_collective_bytes']/2**20:.1f} MiB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="unroll", choices=["unroll", "scan"])
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            tag = f"__{args.tag}" if args.tag else ""
            fn = outdir / f"{args.mesh}__{arch}__{shape}{tag}.json"
            if fn.exists() and not args.force:
                print(f"[dryrun] skip existing {fn}")
                continue
            try:
                res = lower_cell(arch, shape, args.mesh == "multi",
                                 args.mode, args.remat,
                                 fsdp=not args.no_fsdp,
                                 donate=not args.no_donate,
                                 accum=args.accum)
                fn.write_text(json.dumps(res, indent=1))
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, str(e)[-300:]))
    if failures:
        print(f"[dryrun] FAILURES: {len(failures)}")
        for f in failures:
            print("  ", f[0], f[1], f[2][:160])
        raise SystemExit(1)
    print("[dryrun] all requested cells OK")


if __name__ == "__main__":
    main()
