"""Production training launcher.

On a real fleet this binary runs once per process (pod) under
``jax.distributed.initialize``; here it sizes the mesh to the local
devices.  Wires together: config -> model -> sharding specs -> jitted
train step -> het-aware schedule -> checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --steps 20 --policy work_exchange_online --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config, list_configs, resolve_for_tp, smoke_config
from repro.data import UnitStore
from repro.distributed.hetsched import POLICIES, HetTrainer
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(),
                    default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--policy", choices=POLICIES, default="work_exchange")
    ap.add_argument("--units", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--unit-batch", type=int, default=2)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--het-sigma", type=float, default=0.5,
                    help="relative rate spread of the simulated fleet")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published architecture size (pod-scale); "
                         "default uses the reduced smoke config on CPU")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_config(cfg)
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={args.arch} params={n_params/1e6:.1f}M "
          f"policy={args.policy}")

    rng = np.random.default_rng(0)
    mu = 5.0
    spread = args.het_sigma * mu
    rates = np.clip(rng.normal(mu, spread, args.workers), 0.5, None)
    store = UnitStore(unit_batch=args.unit_batch, seq_len=args.seq,
                      vocab=cfg.vocab_size, structured=True)
    opt = AdamW(lr=warmup_cosine(args.lr, 10, args.steps))
    trainer = HetTrainer(model, opt, rates, store, policy=args.policy,
                         units_per_step=args.units)

    opt_state = opt.init(params)
    start = 0
    if args.ckpt:
        ck = latest_checkpoint(args.ckpt)
        if ck:
            (params, opt_state), extra = restore_checkpoint(
                ck, (params, opt_state))
            start = extra["step"] + 1
            print(f"[train] resumed from {ck}")
    t0 = time.time()
    for s in range(start, args.steps):
        params, opt_state, rep = trainer.step(params, opt_state, s)
        print(f"[train] step {s}: loss={rep.loss:.4f} "
              f"T_virtual={rep.t_virtual:.3f}s I={rep.iterations} "
              f"moved={rep.n_comm_units}")
        if args.ckpt and (s % args.save_every == args.save_every - 1
                          or s == args.steps - 1):
            save_checkpoint(args.ckpt, s, (params, opt_state),
                            extra={"step": s})
    print(f"[train] done in {time.time()-t0:.1f}s wall")


if __name__ == "__main__":
    main()
