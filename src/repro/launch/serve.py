"""Serving launcher: batched greedy generation with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --steps 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs, smoke_config
from repro.models import build_model
from repro.train.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(),
                    default="phi4-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = dataclasses.replace(smoke_config(cfg), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B = args.batch
    if cfg.family == "encdec":
        batch = {"frame_embeds": jnp.asarray(
                     rng.normal(size=(B, args.prompt_len, cfg.d_model)),
                     jnp.dtype(cfg.dtype)),
                 "tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, 4)), jnp.int32)}
    else:
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, args.prompt_len)),
            jnp.int32)}
        if cfg.frontend == "vision":
            batch["image_embeds"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model)),
                jnp.dtype(cfg.dtype))
    cache = model.init_cache(B, args.prompt_len + args.steps
                             + cfg.n_frontend_tokens)
    t0 = time.time()
    toks, _ = greedy_generate(model, params, batch, cache, args.steps)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: {B} x {args.steps} tokens "
          f"in {dt:.2f}s ({B * args.steps / dt:.1f} tok/s)")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
