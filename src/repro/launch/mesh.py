"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data parallelism and the work-exchange/failure domain (DESIGN §3).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax >= 0.6 wants explicit AxisType; older jax has neither the enum
    nor the kwarg.  Auto is the default semantic either way."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Whatever fits the local devices (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))


def dp_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') when pod exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
