"""Unit tests for the trip-count-aware HLO analyzer (string fixtures +
a live compile on a small forced-multi-device mesh)."""
import textwrap

import pytest

from repro.launch.hlo_analysis import (aggregate, parse_hlo,
                                       parse_type_bytes)

FIXTURE = textwrap.dedent("""
    HloModule jit_step

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16] get-tuple-element(%p), index=1
      %w = f32[16,16] constant({...})
      %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16] all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16] parameter(0)
      %ag = f32[64,16] all-gather(%x), replica_groups=[1,8]<=[8], dimensions={0}
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %x)
      %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[8,16] get-tuple-element(%w), index=1
    }
""")


class TestParser:
    def test_type_bytes(self):
        assert parse_type_bytes("f32[8,16]") == 8 * 16 * 4
        assert parse_type_bytes("bf16[2,3]{1,0}") == 12
        assert parse_type_bytes("(s32[], f32[4])") == 4 + 16
        assert parse_type_bytes("pred[]") == 1

    def test_entry_detection_and_trip_count(self):
        comps = parse_hlo(FIXTURE, n_devices=8)
        agg = aggregate(comps)
        assert agg["entry"] == "main"
        # dot: 2 * 8 * 16 * 16 flops, x10 trips
        assert agg["dot_flops"] == pytest.approx(2 * 8 * 16 * 16 * 10)

    def test_collective_ring_bytes(self):
        comps = parse_hlo(FIXTURE, n_devices=8)
        agg = aggregate(comps)
        b = agg["collective_bytes"]
        # all-gather: output 64*16*4 bytes * (8-1)/8, once
        assert b["all-gather"] == pytest.approx(64 * 16 * 4 * 7 / 8)
        # all-reduce inside the loop: 2 * in_bytes * (4-1)/4 * 10 trips
        assert b["all-reduce"] == pytest.approx(
            2 * (8 * 16 * 4) * 3 / 4 * 10)
        assert agg["collective_counts"]["all-reduce"] == 10

    def test_f32_normalization_tracks_f32_flows(self):
        comps = parse_hlo(FIXTURE, n_devices=8)
        agg = aggregate(comps)
        total = sum(agg["collective_bytes"].values())
        # everything in the fixture is f32 => normalized = half
        assert agg["collective_bytes_bf16norm"] == pytest.approx(total / 2)

    def test_mem_bytes_counts_loop_body_with_trips(self):
        comps = parse_hlo(FIXTURE, n_devices=8)
        agg = aggregate(comps)
        # dot in the body alone contributes (in+in+out) * 10
        dot_traffic = (8 * 16 * 4 + 16 * 16 * 4 + 8 * 16 * 4) * 10
        assert agg["mem_bytes"] >= dot_traffic


class TestLiveCompile:
    @pytest.mark.skipif(
        not hasattr(__import__("jax").sharding, "AxisType"),
        reason="requires jax >= 0.6 sharding API (AxisType / set_mesh)")
    def test_matches_cost_analysis_on_unrolled(self):
        """Parser dot flops == XLA cost_analysis on a loop-free program."""
        import subprocess
        import sys
        import os
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys
            sys.path.insert(0, %r)
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.launch.hlo_analysis import analyze_compiled
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            def f(x, w1, w2):
                return jnp.sum((x @ w1) @ w2)
            x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
            w1 = jax.ShapeDtypeStruct((64, 128), jnp.float32)
            w2 = jax.ShapeDtypeStruct((128, 64), jnp.float32)
            with jax.set_mesh(mesh):
                c = jax.jit(f, in_shardings=(P("data", None),
                                             P(None, "model"),
                                             P("model", None)),
                            out_shardings=P()).lower(x, w1, w2).compile()
            agg = analyze_compiled(c, 8)
            ca = c.cost_analysis()
            print(agg["dot_flops"], ca["flops"])
        """ % os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"))
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        dot, cost = map(float, out.stdout.split())
        # dots dominate this program; parser must be within the elementwise
        # share of cost_analysis
        assert dot == pytest.approx(cost, rel=0.2)
