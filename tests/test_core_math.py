"""Validation of the paper's math layer: Theorem 1, eqs. 4-5, Section 3."""
import numpy as np
import pytest

from repro.core import erlang, mds, oracle, simulator
from repro.core.types import ExchangeConfig, HetSpec


RNG = lambda s=0: np.random.default_rng(s)


class TestOracle:
    def test_theorem1_closed_form(self):
        het = HetSpec(np.array([1.0, 3.0, 6.0]))
        assert oracle.oracle_mean_time(het, 200) == pytest.approx(20.0)

    def test_theorem1_vs_enumeration(self):
        """Eqs. (8)-(12) telescoping: enumerated sum == N/lambda_sum."""
        het = HetSpec(np.array([0.7, 2.3, 1.1]))
        for N in (1, 2, 5):
            exact = oracle.oracle_mean_time_enumerated(het, N)
            assert exact == pytest.approx(N / het.lambda_sum, rel=1e-12)

    def test_theorem1_vs_mc(self):
        het = HetSpec(np.array([1.0, 4.0, 2.5, 0.5]))
        N = 500
        samples = oracle.oracle_time_samples(het, N, 20000, RNG(1))
        assert samples.mean() == pytest.approx(N / het.lambda_sum, rel=0.01)

    def test_corollary2(self):
        het = HetSpec(np.array([1.0, 3.0, 6.0]))
        np.testing.assert_allclose(oracle.oracle_expected_done(het, 200),
                                   [20.0, 60.0, 120.0])


class TestErlang:
    @pytest.mark.parametrize("ell", [1, 2, 3])
    def test_recursion_vs_mc(self, ell):
        het = HetSpec(np.array([1.0, 2.0, 3.5]))
        m = 6
        exact = erlang.erlang_order_stat_mean(het, m, ell)
        mc = erlang.erlang_order_stat_mean_mc(het, m, ell, 200_000, RNG(2))
        assert exact == pytest.approx(mc, rel=0.02)

    def test_homogeneous_max_known_identity(self):
        """K homogeneous Exp(lam) (m=1): E[max] = H_K / lam."""
        K, lam = 4, 2.0
        het = HetSpec(np.full(K, lam))
        exact = erlang.erlang_order_stat_mean(het, 1, K)
        harmonic = sum(1.0 / i for i in range(1, K + 1)) / lam
        assert exact == pytest.approx(harmonic, rel=1e-9)

    def test_min_of_exponentials(self):
        """m=1, ell=1: E[min] = 1/lambda_sum."""
        het = HetSpec(np.array([1.0, 2.0, 3.0, 4.0]))
        exact = erlang.erlang_order_stat_mean(het, 1, 1)
        assert exact == pytest.approx(1.0 / het.lambda_sum, rel=1e-9)


class TestMDS:
    def test_exact_vs_mc(self):
        het = HetSpec(np.array([1.0, 2.0, 4.0]))
        N, L = 12, 2
        exact = mds.mds_mean_time_exact(het, N, L)
        mc = simulator.mds_mean_time(het, N, L, 300_000, RNG(3))
        assert exact == pytest.approx(mc, rel=0.02)

    def test_paper_example_figure1(self):
        """Intro example: (3,2) MDS on rates (1,3,6)/100-row units -> 33.3s;
        het-aware split -> 20s.  In paper units: A has 200 rows, worker rates
        d,3d,6d ops/s == 1,3,6 rows/s."""
        het = HetSpec(np.array([1.0, 3.0, 6.0]))
        # deterministic version of the example (paper uses deterministic rates):
        # MDS (L=2): each worker gets 100 rows; finish times 100, 33.3, 16.7
        # -> 2nd fastest = 33.33
        t_mds = np.sort(100.0 / het.lambdas)[1]
        assert t_mds == pytest.approx(33.333, rel=1e-3)
        # het-aware: 20/60/120 rows -> all finish at 20s = oracle
        assert oracle.oracle_mean_time(het, 200) == pytest.approx(20.0)

    def test_optimize_picks_K_when_homogeneous_large_N(self):
        """Paper: for sigma^2=0, L=K is optimal (no redundancy needed)."""
        K = 8
        het = HetSpec(np.full(K, 5.0))
        L, _ = simulator.mds_optimize(het, 4000, 300, RNG(4))
        assert L >= K - 1   # MC noise tolerance: optimum is at/near K
