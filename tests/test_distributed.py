"""Distributed correctness on forced multi-device host meshes.

jax pins the device count at first init, so these tests run pinned
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8.
They verify:
  * sharded-vs-single-device train step equivalence (GSPMD correctness of
    our spec rules),
  * MoE all-to-all dispatch == scatter dispatch numerics,
  * cache spec / param spec trees are structurally valid for every arch.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# these subprocess bodies are written against the explicit-sharding API
# (jax.sharding.AxisType / jax.set_mesh), absent from older jax
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="requires jax >= 0.6 sharding API (AxisType / set_mesh)")


def _run(body: str) -> dict:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, sys
        sys.path.insert(0, %r)
        import jax, dataclasses
        import jax.numpy as jnp
        import numpy as np
    """ % os.path.join(REPO, "src")) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = _run("""
        from repro.configs import get_config, smoke_config, resolve_for_tp
        from repro.distributed import sharding as shd
        from repro.models import build_model
        from repro.optim import AdamW
        from repro.train.loop import make_train_step
        from jax.sharding import PartitionSpec as P

        cfg = dataclasses.replace(
            smoke_config(get_config("phi4-mini-3.8b")), dtype="float32",
            d_model=64, n_heads=4, head_dim=16, n_kv_heads=2)
        cfg = resolve_for_tp(cfg, 2)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)))}
        step = make_train_step(model, opt, mode="scan", remat=True)

        # single device reference
        p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        pspecs = shd.param_specs(cfg, jax.eval_shape(lambda: params), tp=2)
        ospecs = shd.opt_specs(cfg, None, pspecs)
        bspecs = shd.batch_specs(batch, ("data",))
        with jax.set_mesh(mesh):
            p2, o2, m2 = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                                 out_shardings=(pspecs, ospecs, None))(
                params, opt_state, batch)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                          "max_param_diff": diff}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 2e-4, res
    assert res["max_param_diff"] < 2e-3, res


def test_moe_a2a_matches_scatter():
    res = _run("""
        from repro.configs import get_config, smoke_config
        from repro.models import moe as moe_mod
        from jax.sharding import PartitionSpec as P

        cfg = dataclasses.replace(
            smoke_config(get_config("qwen3-moe-30b-a3b")), dtype="float32",
            d_model=32, n_experts=8, experts_per_token=2, d_ff=16,
            capacity_factor=8.0)
        key = jax.random.key(1)
        p = moe_mod.moe_init(key, cfg)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 16, 32)), jnp.float32)

        ref, aux_ref = jax.jit(
            lambda p, x: moe_mod.moe_apply_scatter(p, cfg, x))(p, x)

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            out, aux = jax.jit(
                lambda p, x: moe_mod.moe_apply_a2a(
                    p, cfg, x, jax.sharding.get_abstract_mesh()))(p, x)
        diff = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"diff": diff, "aux_ref": float(aux_ref),
                          "aux": float(aux)}))
    """)
    assert res["diff"] < 1e-4, res
    assert abs(res["aux"] - res["aux_ref"]) < 1e-4, res


def test_moe_a2a_matches_scatter_nondivisible_experts():
    """granite case: E=5 not divisible by tp=2 -> padded dummy experts."""
    res = _run("""
        from repro.configs import get_config, smoke_config
        from repro.models import moe as moe_mod
        cfg = dataclasses.replace(
            smoke_config(get_config("granite-moe-3b-a800m")), dtype="float32",
            d_model=32, n_experts=5, experts_per_token=2, d_ff=16,
            capacity_factor=5.0)
        key = jax.random.key(2)
        p = moe_mod.moe_init(key, cfg)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(4, 16, 32)), jnp.float32)
        ref, _ = jax.jit(lambda p, x: moe_mod.moe_apply_scatter(p, cfg, x))(p, x)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            out, _ = jax.jit(
                lambda p, x: moe_mod.moe_apply_a2a(
                    p, cfg, x, jax.sharding.get_abstract_mesh()))(p, x)
        import json as j
        print(j.dumps({"diff": float(jnp.max(jnp.abs(out - ref)))}))
    """)
    assert res["diff"] < 1e-4, res


def test_multipod_mesh_and_grad_equivalence():
    """(2,2,2) pod mesh: train step == single device (pod axis pure DP)."""
    res = _run("""
        from repro.configs import get_config, smoke_config, resolve_for_tp
        from repro.distributed import sharding as shd
        from repro.launch.mesh import dp_axes
        from repro.models import build_model
        from repro.optim import AdamW
        from repro.train.loop import make_train_step

        cfg = dataclasses.replace(
            smoke_config(get_config("h2o-danube-3-4b")), dtype="float32",
            d_model=64, n_heads=4, head_dim=16, n_kv_heads=2, window=8)
        cfg = resolve_for_tp(cfg, 2)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)))}
        step = make_train_step(model, opt, mode="scan", remat=False)
        p1, o1, m1 = jax.jit(step)(params, opt_state, batch)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        pspecs = shd.param_specs(cfg, jax.eval_shape(lambda: params), tp=2)
        ospecs = shd.opt_specs(cfg, None, pspecs)
        bspecs = shd.batch_specs(batch, ("pod", "data"))
        with jax.set_mesh(mesh):
            p2, o2, m2 = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                                 out_shardings=(pspecs, ospecs, None))(
                params, opt_state, batch)
        print(json.dumps({"loss1": float(m1["loss"]),
                          "loss2": float(m2["loss"])}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 2e-4, res
