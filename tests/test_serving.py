"""The streaming-arrival serving subsystem (``repro.serving``).

Engine physics (exact work conservation, the M/M/K closed-form anchor,
seed determinism), the arrival registry, every registered scheme as a
dispatch policy, the ``SERVING_BACKENDS`` registry and the
backend-conformance battery (the jax ``lax.scan`` engine against the
numpy slot-loop oracle: conservation, determinism, 6-SE latency /
goodput / SLO agreement, bucketing, censoring parity, sharding), the
``ServingConfig`` value discipline, the Experiment API integration
(spec-hash back-compat, store round trip), and the CLI rendering of
serving rows.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.schemes import MCReport, list_schemes
from repro.core.types import HetSpec
from repro.serving import (SERVING_BACKENDS, SERVING_ENV, ServingConfig,
                           erlang_b, erlang_c, get_arrival, list_arrivals,
                           list_serving_backends, lr_round_rows,
                           mm1_sojourn, mmk_sojourn,
                           resolve_serving_backend, run_serving_grid,
                           serving_backend_available, simulate_serving)

RNG = np.random.default_rng


def small_het(K=6, mu=20.0, seed=3):
    return HetSpec.uniform_random(K, mu, mu * mu / 6.0, RNG(seed))


def quick_cfg(**kw):
    kw.setdefault("loads", (0.6,))
    kw.setdefault("slots", 300)
    return ServingConfig(**kw)


# ---------------------------------------------------------------------------
# censored-percentile telemetry
# ---------------------------------------------------------------------------

class TestCensoredLatency:
    def test_measured_rows_not_flagged(self):
        rep = simulate_serving(small_het(), "fixed", {}, quick_cfg(), N=30,
                               load=0.5, trials=6, rng=RNG(0))
        assert rep.extra["latency_censored"] == 0.0
        # a measured row's percentiles come from real completions
        assert rep.extra["completed_jobs"] > 0

    def test_saturated_rows_flag_horizon_bound(self):
        # jobs so large none can complete inside the window: the
        # percentile fallback reports the horizon and must say so
        # instead of silently posing as a measurement
        cfg = quick_cfg(slots=100, slot_dt=0.01)
        rep = simulate_serving(small_het(), "fixed", {}, cfg, N=1_000_000,
                               load=0.9, trials=4, rng=RNG(1))
        assert rep.extra["latency_censored"] == 1.0
        assert rep.extra["censored_frac"] == 1.0
        horizon = 100 * 0.01
        assert rep.extra["p50"] == rep.extra["p99"] == pytest.approx(horizon)
        assert rep.t_comp == pytest.approx(horizon)

    def test_knee_detection_counts_censored_rows(self):
        from benchmarks.fig_load import knees
        rows = [
            {"scenario": "s", "scheme": "a", "load": 0.5, "sojourn": 1.0,
             "latency_censored": 0.0},
            {"scenario": "s", "scheme": "a", "load": 0.9, "sojourn": 1.2,
             "latency_censored": 1.0},   # horizon bound, truly saturated
            {"scenario": "s", "scheme": "b", "load": 0.5, "sojourn": 1.0,
             "latency_censored": 0.0},
            {"scenario": "s", "scheme": "b", "load": 0.9, "sojourn": 1.2,
             "latency_censored": 0.0},
        ]
        out = knees(rows, factor=3.0)
        # the censored row IS the knee even though its bound sits far
        # below 3x base; the measured twin at the same ratio is not
        assert out[("s", "a")] == 0.9
        assert out[("s", "b")] is None


# ---------------------------------------------------------------------------
# closed forms + largest-remainder rounding
# ---------------------------------------------------------------------------

class TestQueueingClosedForms:
    def test_erlang_b_known_value(self):
        # B(1, a) = a / (1 + a)
        assert erlang_b(1, 0.5) == pytest.approx(0.5 / 1.5)

    def test_erlang_c_reduces_to_mm1(self):
        # K=1: probability of waiting is the utilization rho
        assert erlang_c(1, 0.4) == pytest.approx(0.4)
        assert mmk_sojourn(8.0, 20.0, 1) == pytest.approx(
            mm1_sojourn(8.0, 20.0))

    def test_erlang_c_requires_stability(self):
        with pytest.raises(ValueError):
            erlang_c(4, 4.0)
        assert mmk_sojourn(100.0, 20.0, 4) == np.inf

    def test_mmk_pooling_beats_parallel_mm1(self):
        # classic result: one shared queue over K servers beats K
        # independent M/M/1 queues at the same total load
        lam, mu, K = 60.0, 20.0, 4
        assert mmk_sojourn(lam, mu, K) < mm1_sojourn(lam / K, mu)


class TestLrRoundRows:
    def test_conserves_and_bounds_error(self):
        rng = RNG(0)
        w = rng.random((32, 7)) + 0.01
        tot = rng.integers(0, 500, size=32)
        out = lr_round_rows(w, tot)
        assert out.dtype == np.int64 and (out >= 0).all()
        np.testing.assert_array_equal(out.sum(axis=1), tot)
        exact = w / w.sum(axis=1, keepdims=True) * tot[:, None]
        assert np.abs(out - exact).max() < 1.0

    def test_zero_weight_rows_fall_back_to_uniform(self):
        out = lr_round_rows(np.zeros((2, 4)), np.array([8, 5]))
        np.testing.assert_array_equal(out.sum(axis=1), [8, 5])
        assert out.max() - out.min() <= 1 or (out[0] == 2).all()


# ---------------------------------------------------------------------------
# engine physics
# ---------------------------------------------------------------------------

class TestEnginePhysics:
    def test_mmk_sojourn_matches_erlang_c(self):
        """Homogeneous workers, 1-unit jobs, pooled work-exchange
        dispatch: the engine IS an M/M/K simulator up to O(slot_dt), so
        its mean sojourn must hit the closed form."""
        K, mu, load = 4, 20.0, 0.65
        het = HetSpec(np.full(K, mu))
        cfg = ServingConfig(loads=(load,), slots=4000, slot_dt=0.0025,
                            warmup_frac=0.25)
        rep = simulate_serving(het, "work_exchange", {}, cfg, N=1,
                               load=load, trials=16, rng=RNG(0))
        expected = mmk_sojourn(load * K * mu, mu, K)
        assert rep.t_comp == pytest.approx(expected, rel=0.15)

    def test_mm1_sojourn(self):
        mu, load = 20.0, 0.5
        het = HetSpec(np.array([mu]))
        cfg = ServingConfig(loads=(load,), slots=4000, slot_dt=0.0025,
                            warmup_frac=0.25)
        rep = simulate_serving(het, "work_exchange", {}, cfg, N=1,
                               load=load, trials=16, rng=RNG(1))
        assert rep.t_comp == pytest.approx(mm1_sojourn(load * mu, mu),
                                           rel=0.15)

    def test_conservation_ledger_in_extras(self):
        # the engine asserts shipped == served + cancelled + backlog
        # every slot; the report must expose the same closed ledger
        for name in ("work_exchange", "het_mds", "hedged"):
            rep = simulate_serving(small_het(), name, {}, quick_cfg(),
                                   N=30, load=0.6, trials=4, rng=RNG(2))
            e = rep.extra
            assert e["units_admitted"] == pytest.approx(
                e["units_served"] + e["units_cancelled"]
                + e["units_backlog"])

    def test_seed_determinism(self):
        args = (small_het(), "work_exchange", {}, quick_cfg(), 30, 0.6, 4)
        a = simulate_serving(*args, rng=RNG(7))
        b = simulate_serving(*args, rng=RNG(7))
        assert a.to_dict() == b.to_dict()
        c = simulate_serving(*args, rng=RNG(8))
        assert c.t_comp != a.t_comp

    def test_rate_schedule_moves_true_rates(self):
        # halving the TRUE rates (drift) at fixed believed rates must
        # hurt: effective load doubles
        het = small_het()
        cfg = quick_cfg(slots=600)
        base = simulate_serving(het, "fixed", {}, cfg, N=30, load=0.45,
                                trials=8, rng=RNG(3))
        sched = np.tile(het.lambdas * 0.5, (6, 1))
        slow = simulate_serving(het, "fixed", {}, cfg, N=30, load=0.45,
                                trials=8, rng=RNG(3), rate_schedule=sched)
        assert slow.t_comp > base.t_comp

    def test_grid_runner_tags_points_and_loads(self):
        specs = [small_het(seed=1), small_het(seed=2)]
        cfg = quick_cfg(loads=(0.5, 0.8))
        reps = run_serving_grid("work_exchange", {}, specs, cfg, N=30,
                                trials=3, seed=99)
        assert len(reps) == 4
        assert [r.extra["grid_point"] for r in reps] == [0, 0, 1, 1]
        assert [r.extra["offered_load"] for r in reps] == [0.5, 0.8] * 2


class TestPolicyBattery:
    """Every registered scheme runs as a dispatch policy with a sane,
    conservation-closed latency report."""

    @pytest.mark.parametrize("name", list_schemes())
    def test_scheme_serves(self, name):
        rep = simulate_serving(small_het(), name, {}, quick_cfg(),
                               N=30, load=0.6, trials=4, rng=RNG(11))
        e = rep.extra
        assert rep.trials == 4 and np.isfinite(rep.t_comp)
        assert rep.t_comp > 0
        assert e["completed_jobs"] > 0
        assert e["p50"] <= e["p95"] + 1e-12 <= e["p99"] + 2e-12
        assert 0.0 <= e["reject_rate"] <= 1.0
        assert e["units_admitted"] == pytest.approx(
            e["units_served"] + e["units_cancelled"] + e["units_backlog"])

    def test_oracle_at_least_as_good_as_uniform(self):
        het = small_het()
        kw = dict(N=30, load=0.6, trials=8)
        oracle = simulate_serving(het, "oracle", {}, quick_cfg(slots=600),
                                  rng=RNG(5), **kw)
        uniform = simulate_serving(het, "uniform", {}, quick_cfg(slots=600),
                                   rng=RNG(5), **kw)
        assert oracle.t_comp <= uniform.t_comp

    def test_unknown_scheme_fails_loudly(self):
        with pytest.raises(KeyError):
            simulate_serving(small_het(), "nope", {}, quick_cfg(), N=30,
                             load=0.5, trials=2, rng=RNG(0))


# ---------------------------------------------------------------------------
# arrival registry
# ---------------------------------------------------------------------------

class TestArrivals:
    def test_registry_contents(self):
        assert {"poisson", "trace", "closed_loop"} <= set(list_arrivals())

    def test_unknown_name_and_params_fail_loudly(self):
        with pytest.raises(KeyError, match="unknown arrival"):
            get_arrival("weibull")
        with pytest.raises(KeyError, match="allowed"):
            get_arrival("poisson", burst=3)

    def test_poisson_counts(self):
        arr = get_arrival("poisson")
        c = arr.job_counts(400, 50, 0.3, RNG(0))
        assert c.shape == (400, 50) and (c >= 0).all()
        assert c.mean() == pytest.approx(0.3, rel=0.1)

    def test_trace_profile_mean_one(self):
        arr = get_arrival("trace", epochs=12)
        prof = arr.profile(500)
        assert prof.shape == (500,)
        assert prof.mean() == pytest.approx(1.0)
        assert prof.std() > 0          # measured burstiness, not flat

    def test_closed_loop_population(self):
        arr = get_arrival("closed_loop")
        assert arr.closed_loop
        assert arr.population_for(0.75, 8) == 6
        assert arr.population_for(0.01, 8) == 1
        assert get_arrival("closed_loop",
                           population=5).population_for(9.9, 8) == 5
        np.testing.assert_array_equal(
            arr.job_counts(2, 5, 1.0, RNG(0)), np.zeros((2, 5)))

    def test_trace_arrivals_through_engine(self):
        cfg = quick_cfg(arrival="trace", arrival_params={"epochs": 8},
                        slots=400)
        rep = simulate_serving(small_het(), "work_exchange", {}, cfg,
                               N=30, load=0.6, trials=4, rng=RNG(4))
        assert rep.extra["completed_jobs"] > 0

    def test_closed_loop_through_engine(self):
        cfg = quick_cfg(arrival="closed_loop",
                        arrival_params={"think_slots": 2}, slots=400)
        rep = simulate_serving(small_het(), "work_exchange", {}, cfg,
                               N=30, load=0.5, trials=4, rng=RNG(4))
        assert rep.extra["completed_jobs"] > 0
        assert rep.extra["throughput_jobs"] > 0


# ---------------------------------------------------------------------------
# ServingConfig value discipline
# ---------------------------------------------------------------------------

class TestServingConfig:
    def test_round_trip(self):
        cfg = ServingConfig(loads=(0.5, 0.9), arrival="trace",
                            arrival_params={"epochs": 6},
                            job_units_dist="geometric", slots=500,
                            deadline_slo=3.0, admission="deadline")
        assert ServingConfig.from_dict(cfg.to_dict()) == cfg

    def test_from_dict_rejects_unknown_keys(self):
        with pytest.raises(KeyError, match="unknown serving key"):
            ServingConfig.from_dict({"loads": [0.5], "burst": 2})

    def test_params_sorted_for_hashing(self):
        a = ServingConfig(arrival="trace",
                          arrival_params={"epochs": 4, "epoch_start": 1})
        b = ServingConfig(arrival="trace",
                          arrival_params={"epoch_start": 1, "epochs": 4})
        assert a == b and a.arrival_params == b.arrival_params

    def test_validation(self):
        with pytest.raises(ValueError):
            ServingConfig(loads=())
        with pytest.raises(ValueError):
            ServingConfig(loads=(-0.5,))
        with pytest.raises(ValueError):
            ServingConfig(admission="deadline")       # needs deadline_slo
        with pytest.raises(ValueError):
            ServingConfig(warmup_frac=1.0)
        with pytest.raises(KeyError):
            ServingConfig(arrival="weibull")          # fails at construction
        with pytest.raises(KeyError):
            ServingConfig(arrival="poisson",
                          arrival_params={"burst": 2})


class TestDeadlineAdmission:
    def test_load_shedding_and_slo_accounting(self):
        het = small_het()
        cfg = quick_cfg(loads=(1.3,), slots=600, deadline_slo=1.5,
                        admission="deadline")
        rep = simulate_serving(het, "work_exchange", {}, cfg, N=30,
                               load=1.3, trials=6, rng=RNG(6))
        e = rep.extra
        assert e["reject_rate"] > 0           # overload is shed, not queued
        assert "slo_miss_rate" in e and 0.0 <= e["slo_miss_rate"] <= 1.0
        assert e["deadline_s"] == pytest.approx(
            1.5 * 30 / het.lambda_sum)

    def test_queue_admission_never_sheds_below_capacity(self):
        cfg = quick_cfg(loads=(0.4,), slots=400, deadline_slo=4.0)
        rep = simulate_serving(small_het(), "work_exchange", {}, cfg,
                               N=30, load=0.4, trials=4, rng=RNG(6))
        assert rep.extra["reject_rate"] == 0.0


# ---------------------------------------------------------------------------
# Experiment API integration
# ---------------------------------------------------------------------------

def serving_spec(tmp_name="serve-int", **serving_kw):
    from repro.experiments import (ExperimentSpec, ScenarioGrid,
                                   scheme_spec)
    serving_kw.setdefault("loads", (0.6,))
    serving_kw.setdefault("slots", 300)
    return ExperimentSpec(
        name=tmp_name,
        grid=ScenarioGrid(K=6, points=[(20.0, 20.0 ** 2 / 6, 3)]),
        schemes=(scheme_spec("work_exchange"), scheme_spec("fixed")),
        N=30, trials=4, seed=77,
        serving=ServingConfig(**serving_kw))


class TestExperimentIntegration:
    def test_spec_round_trip_and_hash(self):
        from repro.experiments import ExperimentSpec
        spec = serving_spec()
        again = ExperimentSpec.from_json(spec.to_json())
        assert again == spec
        assert again.spec_hash() == spec.spec_hash()
        # the serving axis is part of the address
        assert spec.replace(serving=None).spec_hash() != spec.spec_hash()

    def test_no_serving_key_preserves_pre_serving_hashes(self):
        spec = serving_spec().replace(serving=None)
        assert "serving" not in spec.to_dict()

    def test_compile_pins_numpy_serving_to_one_device(self):
        # the numpy oracle loop is sequential in time: it pins to one
        # device even when the SAMPLER backend is a sharded one
        from repro.experiments import compile_plan
        plan = compile_plan(serving_spec().replace(backend="jax",
                                                   devices="auto"))
        assert plan.spec.serving.backend == "numpy"
        assert plan.devices == 1

    def test_compile_resolves_serving_backend_env(self, monkeypatch):
        # $REPRO_SERVING_BACKEND lands in the RESOLVED spec: the store
        # address promises which engine produced the numbers
        from repro.experiments import compile_plan
        monkeypatch.delenv(SERVING_ENV, raising=False)
        base = compile_plan(serving_spec())
        assert base.spec.serving.backend == "numpy"
        monkeypatch.setenv(SERVING_ENV, "jax")
        plan = compile_plan(serving_spec())
        assert plan.spec.serving.backend == "jax"
        assert plan.devices >= 1          # scan shards; clamped to host
        assert plan.spec_hash != base.spec_hash

    def test_store_miss_then_hit_with_latency_rows(self, tmp_path):
        from repro.experiments import ResultsStore, run_experiment
        store = ResultsStore(tmp_path / "store")
        spec = serving_spec()
        first = run_experiment(spec, store=store)
        assert not first.cache_hit
        second = run_experiment(spec, store=store)
        assert second.cache_hit
        assert first.to_dict()["reports"] == second.to_dict()["reports"]
        for key in ("work_exchange", "fixed"):
            rows = second.report(key)
            assert len(rows) == 1           # 1 grid point x 1 load
            e = rows[0].extra
            for field in ("serving", "offered_load", "p50", "p95", "p99",
                          "throughput_jobs", "grid_point"):
                assert field in e, (key, field)

    def test_mcreport_serving_extras_round_trip(self):
        rep = simulate_serving(small_het(), "work_exchange", {},
                               quick_cfg(deadline_slo=3.0), N=30,
                               load=0.6, trials=4, rng=RNG(9))
        again = MCReport.from_dict(rep.to_dict())
        assert again.extra == rep.extra
        assert "slo_miss_rate" in again.extra
        assert again.to_dict() == rep.to_dict()


# ---------------------------------------------------------------------------
# subprocess helpers (CLI rendering + sharded probes)
# ---------------------------------------------------------------------------

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CLI_ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _cli(args, timeout=420):
    out = subprocess.run([sys.executable, "-m", "repro.experiments"]
                         + args, capture_output=True, text=True,
                         timeout=timeout, cwd=REPO, env=CLI_ENV)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# serving backends: registry surface + conformance battery
# ---------------------------------------------------------------------------

needs_jax = pytest.mark.skipif(not serving_backend_available("jax"),
                               reason="jax not importable")

# one shared cell for the whole battery: every test below reuses these
# rows, so the scan engine compiles each policy family exactly once
CELL_CFG = dict(loads=(0.7,), slots=600, deadline_slo=2.5)
CELL_N, CELL_TRIALS, CELL_SEED = 10, 8, 21


class TestServingBackendRegistry:
    def test_registry_contents(self):
        names = list_serving_backends()
        assert {"numpy", "jax"} <= set(names)
        assert not SERVING_BACKENDS.get("numpy").shards
        assert SERVING_BACKENDS.get("jax").shards
        for n in names:
            assert SERVING_BACKENDS.get(n).description

    def test_unknown_backend_fails_loudly(self):
        with pytest.raises(KeyError, match="unknown serving backend"):
            SERVING_BACKENDS.get("cuda")
        with pytest.raises(KeyError, match="unknown serving backend"):
            run_serving_grid("fixed", {}, [small_het()], quick_cfg(),
                             30, 2, 0, backend="cuda")

    def test_resolution_order(self, monkeypatch):
        # explicit non-default name wins; the "numpy" default defers to
        # the env var (the sampler-backend semantics)
        monkeypatch.delenv(SERVING_ENV, raising=False)
        assert resolve_serving_backend() == "numpy"
        assert resolve_serving_backend("jax") == "jax"
        monkeypatch.setenv(SERVING_ENV, "jax")
        assert resolve_serving_backend() == "jax"
        assert resolve_serving_backend("numpy") == "jax"
        assert ServingConfig().resolve_backend() == "jax"
        assert ServingConfig(backend="jax").resolve_backend() == "jax"

    def test_bad_env_value_fails_loudly(self, monkeypatch):
        monkeypatch.setenv(SERVING_ENV, "cuda")
        with pytest.raises(KeyError, match="unknown serving backend"):
            resolve_serving_backend()


@needs_jax
class TestBackendConformance:
    """The jitted scan engine against the numpy slot-loop oracle: same
    arrival streams (both build the identical per-load count matrices),
    independent service draws -- reports must close the same ledger and
    agree within the MC band on every latency/goodput/SLO metric."""

    @pytest.fixture(scope="class")
    def cell(self):
        cfg = ServingConfig(**CELL_CFG)
        het = small_het()
        out = {}
        for backend in list_serving_backends():
            if not serving_backend_available(backend):
                continue                                # pragma: no cover
            out[backend] = {
                name: run_serving_grid(name, {}, [het], cfg, CELL_N,
                                       CELL_TRIALS, CELL_SEED,
                                       backend=backend)[0]
                for name in list_schemes()}
        return out

    @pytest.mark.parametrize("name", list_schemes())
    def test_scan_report_closes_ledger(self, cell, name):
        rep = cell["jax"][name]
        e = rep.extra
        assert e["serving_backend"] == "jax"
        assert rep.trials == CELL_TRIALS and np.isfinite(rep.t_comp)
        assert e["completed_jobs"] > 0
        assert e["p50"] <= e["p95"] + 1e-12 <= e["p99"] + 2e-12
        assert 0.0 <= e["reject_rate"] <= 1.0
        assert 0.0 <= e["slo_miss_rate"] <= 1.0
        assert e["units_admitted"] == pytest.approx(
            e["units_served"] + e["units_cancelled"] + e["units_backlog"])

    @pytest.mark.parametrize("name", list_schemes())
    def test_backends_agree_within_band(self, cell, name):
        rn, rj = cell["numpy"][name], cell["jax"][name]
        # identical arrival streams: the offered demand must match
        # exactly, not statistically
        assert rn.extra["units_admitted"] == pytest.approx(
            rj.extra["units_admitted"])
        se = max(np.hypot(rn.t_comp_std, rj.t_comp_std)
                 / np.sqrt(CELL_TRIALS), 1e-9)
        assert abs(rn.t_comp - rj.t_comp) <= 6 * se + 1e-12
        for q in ("p50", "p95", "p99"):
            assert abs(rn.extra[q] - rj.extra[q]) <= 6 * se + 1e-12, q
        g = rn.extra["goodput_units"]
        assert abs(g - rj.extra["goodput_units"]) <= max(
            6 * 0.03 * g, 6 * se * CELL_N) + 1e-12
        m = rn.extra["slo_miss_rate"]
        ntot = max(rn.extra["completed_jobs"] * CELL_TRIALS, 1.0)
        se_m = np.sqrt(max(m * (1 - m), 0.25 / ntot) / ntot)
        assert abs(m - rj.extra["slo_miss_rate"]) <= 6 * se_m + 1e-12

    def test_scan_seed_determinism(self):
        cfg = ServingConfig(**CELL_CFG)
        args = ("work_exchange", {}, [small_het()], cfg, CELL_N,
                CELL_TRIALS)
        a = run_serving_grid(*args, CELL_SEED, backend="jax")[0]
        b = run_serving_grid(*args, CELL_SEED, backend="jax")[0]
        assert a.to_dict() == b.to_dict()
        c = run_serving_grid(*args, CELL_SEED + 1, backend="jax")[0]
        assert c.t_comp != a.t_comp

    def test_env_resolution_reaches_engine(self, monkeypatch):
        monkeypatch.setenv(SERVING_ENV, "jax")
        rep = run_serving_grid("work_exchange", {}, [small_het()],
                               ServingConfig(**CELL_CFG), CELL_N,
                               CELL_TRIALS, CELL_SEED)[0]
        assert rep.extra["serving_backend"] == "jax"

    def test_bucketed_matches_exact_shapes(self, cell, monkeypatch):
        # REPRO_SHAPE_BUCKETS=0 compiles at the exact (S, Q, B) instead
        # of the pow2 bucket: different draw shapes, same distribution
        monkeypatch.setenv("REPRO_SHAPE_BUCKETS", "0")
        exact = run_serving_grid("work_exchange", {}, [small_het()],
                                 ServingConfig(**CELL_CFG), CELL_N,
                                 CELL_TRIALS, CELL_SEED,
                                 backend="jax")[0]
        bucketed = cell["jax"]["work_exchange"]
        se = max(np.hypot(exact.t_comp_std, bucketed.t_comp_std)
                 / np.sqrt(CELL_TRIALS), 1e-9)
        assert abs(exact.t_comp - bucketed.t_comp) <= 6 * se + 1e-12
        assert abs(exact.extra["p99"] - bucketed.extra["p99"]) \
            <= 6 * se + 1e-12

    def test_queue_tier_splice_bitwise(self, monkeypatch):
        # fixed-units scans first run every row at the narrow _TIER_Q
        # physical queue width, then rerun exactly the rows whose true
        # admission cap was ever threatened at the full width; the
        # splice must be invisible -- bitwise equal to one full-width
        # dispatch (same bucketed shapes, so identical cap streams)
        import repro.serving.scan as scan
        cfg = ServingConfig(loads=(0.95, 1.15), slots=200,
                            max_queue_jobs=48, deadline_slo=None)
        args = ("work_exchange", {}, [small_het(K=5, mu=25.0, seed=11)],
                cfg, 20, 6, 77)
        tiered = run_serving_grid(*args, backend="jax")
        monkeypatch.setattr(scan, "_TIER_Q", sys.maxsize)
        full = run_serving_grid(*args, backend="jax")
        assert [r.to_dict() for r in tiered] == [r.to_dict() for r in full]

    def test_censored_parity(self):
        # jobs too large to ever finish: both engines must flag the
        # horizon bound instead of posing as a measurement
        cfg = quick_cfg(slots=100, slot_dt=0.01)
        args = ("fixed", {}, [small_het()], cfg, 1_000_000, 4, 5)
        rn = run_serving_grid(*args)[0]
        rj = run_serving_grid(*args, backend="jax")[0]
        for rep in (rn, rj):
            assert rep.extra["latency_censored"] == 1.0
            assert rep.extra["censored_frac"] == 1.0
            assert rep.extra["p50"] == rep.extra["p99"] \
                == pytest.approx(1.0)
            assert rep.t_comp == pytest.approx(1.0)

    def test_unadaptable_policy_falls_back_to_numpy(self, monkeypatch):
        # adapter classes the scan has no pure-function translation for
        # run through the oracle loop, stamped so reports never lie
        import repro.serving.scan as scan
        monkeypatch.setattr(scan, "_policy_static", lambda pol: None)
        cfg = quick_cfg()
        args = ("work_exchange", {}, [small_het()], cfg, 30, 4, 9)
        via_jax = run_serving_grid(*args, backend="jax")[0]
        pure = run_serving_grid(*args, backend="numpy")[0]
        assert via_jax.extra["serving_backend"] == "numpy"
        assert via_jax.t_comp == pure.t_comp

    def test_closed_loop_rejected_on_scan(self):
        cfg = quick_cfg(arrival="closed_loop",
                        arrival_params={"think_slots": 2})
        with pytest.raises(ValueError, match="[Cc]losed-loop"):
            run_serving_grid("work_exchange", {}, [small_het()], cfg,
                             30, 2, 0, backend="jax")


# ---------------------------------------------------------------------------
# q_hi window compaction (burst-then-idle regression)
# ---------------------------------------------------------------------------

class TestQHiCompaction:
    def test_burst_arrivals_shape(self):
        arr = get_arrival("burst", burst_frac=0.05)
        c = arr.job_counts(2000, 600, 0.3, RNG(0))
        assert c.shape == (2000, 600)
        assert c.mean() == pytest.approx(0.3, rel=0.1)   # mean preserved
        assert c[:, 30:].sum() == 0                      # silent tail
        with pytest.raises(ValueError):
            get_arrival("burst", burst_frac=0.0)
        with pytest.raises(ValueError):
            get_arrival("burst", burst_frac=1.5)

    def test_burst_drain_compacts_high_water_mark(self):
        # the whole demand lands in the first 5% of the horizon and
        # drains; a frozen high-water mark would keep q_hi_mean pinned
        # near q_hi_peak for the idle tail, so the shrink is visible as
        # mean << peak
        cfg = quick_cfg(loads=(0.3,), slots=800, arrival="burst",
                        arrival_params={"burst_frac": 0.05})
        rep = simulate_serving(small_het(), "work_exchange", {}, cfg,
                               N=5, load=0.3, trials=4, rng=RNG(12))
        e = rep.extra
        assert e["q_hi_peak"] >= 4
        assert e["q_hi_mean"] < 0.65 * e["q_hi_peak"]
        assert e["units_admitted"] == pytest.approx(
            e["units_served"] + e["units_cancelled"] + e["units_backlog"])

    def test_steady_state_mark_stays_tight(self):
        # at steady load the mark tracks occupancy: mean close to peak
        rep = simulate_serving(small_het(), "work_exchange", {},
                               quick_cfg(), N=30, load=0.6, trials=4,
                               rng=RNG(12))
        e = rep.extra
        assert e["q_hi_peak"] > 0
        assert e["q_hi_mean"] > 0.2 * e["q_hi_peak"]


# ---------------------------------------------------------------------------
# ServingConfig.backend spec-hash discipline
# ---------------------------------------------------------------------------

class TestServingBackendSpecHash:
    def test_backend_key_omitted_at_default(self):
        cfg = ServingConfig()
        assert "backend" not in cfg.to_dict()
        assert ServingConfig.from_dict(cfg.to_dict()) == cfg

    def test_backend_key_present_when_set(self):
        cfg = ServingConfig(backend="jax")
        d = cfg.to_dict()
        assert d["backend"] == "jax"
        assert ServingConfig.from_dict(d) == cfg

    def test_unknown_backend_fails_at_construction(self):
        with pytest.raises(KeyError, match="unknown serving backend"):
            ServingConfig(backend="cuda")

    def test_pre_backend_spec_hash_pinned(self):
        """Literal regression pin: a serving spec at the default backend
        hashes exactly as it did before the backend field existed, so
        every stored serving result keeps its address."""
        from repro.experiments import ExperimentSpec, ScenarioGrid, \
            scheme_spec
        spec = ExperimentSpec(
            name="pin-serving",
            grid=ScenarioGrid(K=6, points=[(20.0, 20.0 ** 2 / 6, 3)]),
            schemes=(scheme_spec("work_exchange"), scheme_spec("fixed")),
            N=100, trials=4, seed=11,
            serving=ServingConfig(loads=(0.6, 0.9), slots=400,
                                  deadline_slo=4.0))
        pinned = ("770dfde613e0d7df6303627d1ccbe12b"
                  "867d3665e5485910235bc0fcb6deb96b")
        assert spec.spec_hash() == pinned
        # a non-default engine is a different address on purpose
        import dataclasses
        jax_spec = spec.replace(serving=dataclasses.replace(
            spec.serving, backend="jax"))
        assert jax_spec.spec_hash() != pinned
        assert ExperimentSpec.from_json(jax_spec.to_json()) == jax_spec


# ---------------------------------------------------------------------------
# sharded scan: stacked (load x trial) rows over simulated devices
# ---------------------------------------------------------------------------

SHARDED_SERVING_PROBE = textwrap.dedent("""
    import json, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.experiments import (ExperimentSpec, ScenarioGrid,
                                   compile_plan, run_experiment,
                                   scheme_spec)
    from repro.serving import ServingConfig

    def make(devices):
        return ExperimentSpec(
            name="shard-serving",
            grid=ScenarioGrid(K=6, points=[(20.0, 20.0**2/6, 3)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("het_mds")),
            N=100, trials=8, seed=7, devices=devices,
            serving=ServingConfig(loads=(0.6, 0.9), slots=400,
                                  deadline_slo=4.0, backend="jax"))

    plan = compile_plan(make(4))
    assert plan.devices == 4, plan.devices
    r1, r4 = run_experiment(make(1)), run_experiment(make(4))
    rows = []
    for k in r1.keys():
        for a, b in zip(r1.report(k), r4.report(k)):
            rows.append({"key": k, "load": a.extra["offered_load"],
                         "single": a.t_comp, "shard": b.t_comp,
                         "std": a.t_comp_std})
    print("PROBE" + json.dumps(rows))
""")


@needs_jax
class TestShardedServingScan:
    @pytest.fixture(scope="class")
    def probe(self):
        env = {k: v for k, v in os.environ.items()
               if k not in ("XLA_FLAGS", "REPRO_SERVING_BACKEND")}
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c",
                              SHARDED_SERVING_PROBE],
                             capture_output=True, text=True, timeout=900,
                             cwd=REPO, env=env)
        assert out.returncode == 0, out.stderr[-3000:]
        line = next(ln for ln in out.stdout.splitlines()
                    if ln.startswith("PROBE"))
        return json.loads(line[len("PROBE"):])

    def test_four_device_scan_matches_single(self, probe):
        assert len(probe) == 4                  # 2 schemes x 2 loads
        for row in probe:
            se = max(row["std"] / np.sqrt(8), 1e-9)
            drift = abs(row["single"] - row["shard"])
            assert drift <= 6.0 * se + 1e-12, row


# ---------------------------------------------------------------------------
# CLI rendering (ls / compare / demo) -- subprocess, store under tmp
# ---------------------------------------------------------------------------


class TestCLIServingRows:
    @pytest.fixture(scope="class")
    def demo_store(self, tmp_path_factory):
        root = str(tmp_path_factory.mktemp("store"))
        out = _cli(["--demo", "serving", "--trials", "4", "--store", root,
                    "--check-cache"])
        return root, out

    def test_demo_renders_latency_surface(self, demo_store):
        _, out = demo_store
        assert "sojourn=" in out and "p99=" in out and "slo_miss=" in out
        assert "check-cache: OK" in out

    def test_ls_shows_p99_at_top_load(self, demo_store):
        root, _ = demo_store
        out = _cli(["ls", "--store", root])
        assert "serving p99@load=0.9:" in out
        assert "work_exchange=" in out

    def test_compare_renders_percentile_deltas(self, demo_store):
        root, out = demo_store
        line = next(ln for ln in out.splitlines() if "spec hash" in ln)
        h = line.split()[-1][:16]
        cmp_out = _cli(["compare", h, h, "--store", root])
        assert "p99" in cmp_out and "slo_miss_rate" in cmp_out
        assert "within the 6-SE MC band" in cmp_out
