"""Per-kernel allclose vs the pure-jnp oracle, interpret mode, with
shape/dtype sweeps (and a backward check through the custom VJPs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.linear_scan.kernel import linear_scan as ls_kernel
from repro.kernels.linear_scan.ops import linear_scan as ls_op
from repro.kernels.linear_scan.ref import linear_scan_ref
from repro.kernels.moe_gmm.kernel import expert_matmul
from repro.kernels.moe_gmm.ref import expert_matmul_ref
from repro.kernels.we_rounds import (gamma_rows_grid, lowering_available,
                                     resolve_mode, we_rounds_grid)

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Sq,Sk,Hq,Hkv,d,causal,window",
        [
            (1, 64, 64, 2, 2, 32, True, 0),
            (2, 128, 128, 4, 2, 16, True, 0),      # GQA
            (1, 64, 64, 4, 1, 32, True, 0),        # MQA
            (1, 128, 128, 2, 2, 32, True, 32),     # sliding window
            (2, 64, 64, 2, 2, 64, False, 0),       # non-causal (encoder)
            (1, 32, 128, 2, 1, 32, True, 0),       # Sq < Sk (right-aligned)
        ])
    def test_fwd_matches_ref(self, dtype, B, Sq, Sk, Hq, Hkv, d, causal,
                             window):
        q = _rand((B, Sq, Hq, d), dtype)
        k = _rand((B, Sk, Hkv, d), dtype)
        v = _rand((B, Sk, Hkv, d), dtype)
        out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                  block_q=32, block_k=32, interpret=True)
        want = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_block_shape_sweep(self):
        q = _rand((1, 128, 2, 32), jnp.float32)
        k = _rand((1, 128, 2, 32), jnp.float32)
        v = _rand((1, 128, 2, 32), jnp.float32)
        want = fa_ref.attention_ref(q, k, v)
        for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]:
            out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk,
                                      interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"block {bq}x{bk}")

    def test_vjp_matches_ref_grad(self):
        q = _rand((1, 64, 2, 16), jnp.float32)
        k = _rand((1, 64, 1, 16), jnp.float32)
        v = _rand((1, 64, 1, 16), jnp.float32)

        def f_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v, True, 0, None, True) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(fa_ref.attention_ref(q, k, v) ** 2)

        g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


class TestExpertMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("E,C,D,F", [
        (2, 32, 32, 32), (4, 64, 32, 64), (1, 128, 64, 32), (8, 32, 64, 64),
    ])
    def test_matches_ref(self, dtype, E, C, D, F):
        buf = _rand((E, C, D), dtype)
        w = _rand((E, D, F), dtype)
        out = expert_matmul(buf, w, block_c=32, block_f=32, block_d=32,
                            interpret=True)
        want = expert_matmul_ref(buf, w)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


class TestLinearScan:
    @pytest.mark.parametrize("B,S,D,chunk", [
        (1, 64, 16, 16), (2, 128, 32, 32), (3, 96, 8, 32), (1, 256, 64, 64),
    ])
    def test_matches_ref(self, B, S, D, chunk):
        a = jnp.asarray(RNG.uniform(0.5, 1.0, (B, S, D)), jnp.float32)
        b = _rand((B, S, D), jnp.float32)
        out = ls_kernel(a, b, chunk=chunk, interpret=True)
        want = linear_scan_ref(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_vjp_matches_ref_grad(self):
        a = jnp.asarray(RNG.uniform(0.5, 0.99, (1, 64, 8)), jnp.float32)
        b = _rand((1, 64, 8), jnp.float32)

        def f_kernel(a, b):
            return jnp.sum(ls_op(a, b, True) ** 2)

        def f_ref(a, b):
            return jnp.sum(linear_scan_ref(a, b) ** 2)

        g1 = jax.grad(f_kernel, argnums=(0, 1))(a, b)
        g2 = jax.grad(f_ref, argnums=(0, 1))(a, b)
        for x, y in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-4)

    def test_matches_model_recurrence(self):
        """The kernel is the oracle-equivalent of models.recurrent."""
        from repro.models.recurrent import linear_recurrence
        a = jnp.asarray(RNG.uniform(0.2, 1.0, (2, 64, 16)), jnp.float32)
        b = _rand((2, 64, 16), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ls_kernel(a, b, chunk=32, interpret=True)),
            np.asarray(linear_recurrence(a, b)), rtol=1e-5, atol=1e-5)


class TestWeRounds:
    """The fused work-exchange round-pipeline kernel (pallas backend)."""

    K, N = 12, 30_000
    THRESHOLD = 0.01 * N / K

    def _lam_rows(self, B, seed=3):
        rng = np.random.default_rng(seed)
        return np.repeat(rng.uniform(10.0, 30.0, size=(1, self.K)), B,
                         axis=0)

    def _run(self, B, mode, known=True, seed=(11, 22)):
        cap = np.inf if known else float(np.ceil(self.N / self.K))
        return we_rounds_grid(self._lam_rows(B), seed, n0=self.N,
                              threshold=self.THRESHOLD, cap=cap,
                              known=known, max_iter=10_000, mode=mode)

    @pytest.mark.parametrize("known", [True, False])
    def test_interpret_kernel_bitwise_matches_reference(self, known):
        """Counter-based draws make kernel tiling invisible: the
        interpreted kernel and the jnp oracle are BIT-identical."""
        for a, b in zip(self._run(256, "interpret", known),
                        self._run(256, "reference", known)):
            np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("known", [True, False])
    def test_drift_schedule_bitwise_across_modes(self, known):
        """The per-round rate schedule (drifting scenarios) keeps the
        kernel/reference bit-identity: counters are untouched, the
        schedule only re-scales the Gamma draws -- including on odd
        batches where the schedule rows are padded alongside."""
        for B in (256, 100):
            lam = self._lam_rows(B)
            rng = np.random.default_rng(17)
            sched = (lam[:, None, :]
                     * np.exp(0.15 * rng.standard_normal((B, 6, self.K))))
            cap = np.inf if known else float(np.ceil(self.N / self.K))
            out = [we_rounds_grid(lam, (11, 22), n0=self.N,
                                  threshold=self.THRESHOLD, cap=cap,
                                  known=known, max_iter=10_000, mode=mode,
                                  rate_schedule=sched)
                   for mode in ("interpret", "reference")]
            for a, b in zip(*out):
                np.testing.assert_array_equal(a, b)
            # and the schedule actually changed the outcome
            plain = we_rounds_grid(lam, (11, 22), n0=self.N,
                                   threshold=self.THRESHOLD, cap=cap,
                                   known=known, max_iter=10_000,
                                   mode="reference")
            assert not np.array_equal(out[1][0], plain[0])

    @pytest.mark.parametrize("B", [1, 77, 130, 200])
    def test_padding_path_odd_batches(self, B):
        """Odd / non-power-of-two trial counts pad to the tile multiple;
        padding rows must never perturb real rows (vs the unpadded
        reference) and outputs keep the requested length."""
        t, it, cm = self._run(B, "interpret")
        t_ref, it_ref, cm_ref = self._run(B, "reference")
        assert t.shape == it.shape == cm.shape == (B,)
        np.testing.assert_array_equal(t, t_ref)
        np.testing.assert_array_equal(it, it_ref)
        np.testing.assert_array_equal(cm, cm_ref)
        assert np.isfinite(t).all() and (it >= 1).all() and (cm >= 0).all()

    @pytest.mark.parametrize("known", [True, False])
    def test_statistically_equivalent_to_jax_backend(self, known):
        """Interpret-mode kernel vs the fused jax backend at 6 combined
        standard errors on a shared scenario (both sample the same fluid
        relaxation from independent bit streams)."""
        from repro.core.samplers import work_exchange_grid_jax
        from repro.core.types import ExchangeConfig, HetSpec

        trials = 512
        lam = self._lam_rows(1)[0]
        t_k, _, cm_k = self._run(trials, "interpret", known)
        cfg = ExchangeConfig(known_heterogeneity=known)
        t_j, _, cm_j = work_exchange_grid_jax(
            lam[None, :], self.N, cfg, trials, np.random.default_rng(5))
        se = np.hypot(t_k.std(), t_j.std()) / np.sqrt(trials)
        assert abs(t_k.mean() - t_j.mean()) < max(6.0 * se,
                                                  1e-3 * t_j.mean())
        assert abs(cm_k.mean() - cm_j.mean()) / self.N < 0.01
        oracle = self.N / HetSpec(lam).lambda_sum
        assert oracle <= t_k.mean() < 1.05 * oracle

    def test_gamma_rows_moments(self):
        """Counter-based MT gamma rows: mean exact, variance alpha + 1/9
        (large-shape transform) at 6 SE."""
        R, K, alpha, scale = 4096, 8, 7.5, 0.5
        g = gamma_rows_grid(np.full((R, K), alpha), np.full((R, K), scale),
                            (1, 2))
        n = R * K
        se_mean = np.sqrt(alpha + 1 / 9) * scale / np.sqrt(n)
        assert abs(g.mean() - alpha * scale) < 6 * se_mean
        var_want = (alpha + 1 / 9) * scale ** 2
        assert abs(g.var() - var_want) < 0.05 * var_want

    def test_mode_resolution_and_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_WE_ROUNDS_MODE", raising=False)
        assert resolve_mode() in ("kernel", "reference")
        assert resolve_mode("interpret") == "interpret"
        monkeypatch.setenv("REPRO_WE_ROUNDS_MODE", "reference")
        assert resolve_mode() == "reference"
        with pytest.raises(KeyError, match="bogus"):
            resolve_mode("bogus")

    @pytest.mark.skipif(not lowering_available(),
                        reason="Pallas lowering needs a TPU backend; "
                               "interpret/reference modes cover CPU CI")
    def test_compiled_kernel_bitwise_matches_reference(self):
        """On hosts with a real Pallas backend the compiled kernel must
        reproduce the oracle bit-for-bit too (counter-based draws)."""
        for a, b in zip(self._run(256, "kernel"),
                        self._run(256, "reference")):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


class TestChunkedAttentionSkip:
    def test_unrolled_causal_skip_matches_map_and_direct(self):
        """The static causal-block-skip path (UNROLL_CHUNKS) is exact."""
        from repro.models import attention as attn
        from repro.models.common import causal_mask
        rng = np.random.default_rng(3)
        B, S, Hq, Hkv, d = 2, 256, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(B, S, Hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), jnp.float32)
        for window in (0, 64):
            ref = attn.grouped_attention(q, k, v,
                                         causal_mask(S, S, 0, window),
                                         d ** -0.5)
            old = attn.UNROLL_CHUNKS
            try:
                attn.UNROLL_CHUNKS = True
                out = attn.chunked_attention(q, k, v, d ** -0.5,
                                             window=window, chunk=64)
            finally:
                attn.UNROLL_CHUNKS = old
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"window={window}")
