"""Hypothesis property tests on the system's invariants.

Skipped cleanly when ``hypothesis`` is not installed (it is a dev-only
dependency, declared in pyproject's ``dev`` extra); the deterministic
invariant checks live in test_schemes.py and always run.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import oracle, simulator
from repro.core import schemes as schemes_mod
from repro.core.assignment import (capped_proportional_assignment,
                                   largest_remainder_round,
                                   proportional_assignment)
from repro.core.coded import GradientCoding, MDSCodedMatmul
from repro.core.exchange import MasterScheduler
from repro.core.runtime import VirtualWorkerPool
from repro.core.types import ExchangeConfig, HetSpec

SETTINGS = dict(deadline=None, max_examples=40,
                suppress_health_check=[HealthCheck.too_slow])

rates_strategy = st.lists(st.floats(0.05, 50.0), min_size=2, max_size=12)


class TestAssignmentProperties:
    @given(shares=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=20),
           total=st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_largest_remainder_exact_total(self, shares, total):
        out = largest_remainder_round(np.array(shares), total)
        assert out.sum() == total
        assert (out >= 0).all()

    @given(rates=rates_strategy, n=st.integers(1, 5000),
           cap_frac=st.floats(0.3, 3.0))
    @settings(**SETTINGS)
    def test_capped_never_exceeds_cap_or_total(self, rates, n, cap_frac):
        K = len(rates)
        cap = max(1, int(cap_frac * n / K))
        out = capped_proportional_assignment(np.array(rates), n, cap)
        assert (out <= cap).all()
        assert out.sum() <= n

    @given(rates=rates_strategy, n=st.integers(1, 100_000))
    @settings(**SETTINGS)
    def test_proportional_monotone_in_rate(self, rates, n):
        out = proportional_assignment(np.array(rates), n)
        order = np.argsort(rates)
        assigned = out[order]
        # monotone up to rounding by 1 unit
        assert all(assigned[i] <= assigned[i + 1] + 1
                   for i in range(len(rates) - 1))


class TestSchedulerProperties:
    @given(rates=rates_strategy, n=st.integers(1, 400),
           seed=st.integers(0, 2**31 - 1),
           known=st.booleans())
    @settings(**SETTINGS)
    def test_work_conservation_every_unit_once(self, rates, n, seed, known):
        K = len(rates)
        sched = MasterScheduler(range(n), K,
                                rates=np.array(rates) if known else None)
        pool = VirtualWorkerPool(rates, seed=seed)
        guard = 0
        while not sched.finished and guard < 500:
            a = sched.next_assignment()
            if a is None:
                break
            elapsed, done = pool.run_epoch(a)
            sched.report(done, elapsed)
            guard += 1
        assert sorted(sched.done_ids) == list(range(n))

    @given(rates=rates_strategy, n=st.integers(10, 400),
           seed=st.integers(0, 2**31 - 1),
           fail_worker=st.integers(0, 11))
    @settings(**SETTINGS)
    def test_work_conservation_under_failure(self, rates, n, seed,
                                             fail_worker):
        K = len(rates)
        if K < 2:
            return
        fail_worker %= K
        sched = MasterScheduler(range(n), K, rates=np.array(rates))
        pool = VirtualWorkerPool(rates, seed=seed)
        dead = np.zeros(K, bool)
        epoch = 0
        while not sched.finished and epoch < 500:
            a = sched.next_assignment()
            if a is None:
                break
            if epoch == 1:
                dead[fail_worker] = True
            elapsed, done = pool.run_epoch(a, dead)
            sched.report(done, elapsed)
            if epoch == 1:
                sched.mark_failed(fail_worker)
            epoch += 1
        assert sorted(sched.done_ids) == list(range(n))


class TestStochasticModelProperties:
    @given(rates=rates_strategy, n=st.integers(1, 2000),
           seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_no_policy_beats_oracle_in_expectation(self, rates, n, seed):
        het = HetSpec(np.array(rates))
        rng = np.random.default_rng(seed)
        cfg = ExchangeConfig(known_heterogeneity=True)
        mc = simulator.work_exchange_mc(het, n, cfg, trials=8, rng=rng)
        # allow MC noise: 8 trials of a >= bound quantity
        assert mc.t_comp > 0.5 * n / het.lambda_sum

    @given(rates=rates_strategy, n=st.integers(1, 500),
           seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_simulated_run_conserves_work(self, rates, n, seed):
        het = HetSpec(np.array(rates))
        rng = np.random.default_rng(seed)
        stats = simulator.simulate_work_exchange(
            het, n, ExchangeConfig(known_heterogeneity=False), rng)
        stats.check_work_conserved(n)    # raises on violation
        assert stats.t_comp >= 0
        assert stats.n_comm >= 0


class TestBatchedMDSSweepProperties:
    """The grid MDS L-sweep: all candidate L values as extra rows of one
    batched draw must reproduce the PR-2 per-L loop exactly (numpy)."""

    @given(K=st.integers(2, 12), mu=st.floats(5.0, 80.0),
           sigma2_frac=st.floats(0.0, 1.0 / 3.0),
           n=st.integers(50, 20_000), trials=st.integers(4, 40),
           seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_batched_sweep_picks_same_L_as_loop(self, K, mu, sigma2_frac,
                                                n, trials, seed):
        het = HetSpec.uniform_random(K, mu, sigma2_frac * mu * mu,
                                     np.random.default_rng(seed))
        L_loop, mean_loop, ts_loop = schemes_mod.mds_sweep(
            het, n, trials, np.random.default_rng(seed + 1))
        L_bat, mean_bat, ts_bat = schemes_mod.mds_sweep_batched(
            het, n, trials, np.random.default_rng(seed + 1),
            backend="numpy")
        assert L_bat == L_loop
        assert mean_bat == mean_loop
        np.testing.assert_array_equal(ts_bat, ts_loop)

    @given(K=st.integers(2, 10), mu=st.floats(5.0, 60.0),
           n=st.integers(100, 10_000), seed=st.integers(0, 2**31 - 1),
           n_specs=st.integers(1, 3))
    @settings(**SETTINGS)
    def test_grid_mc_picks_same_L_as_per_spec_mc(self, K, mu, n, seed,
                                                 n_specs):
        """mc_grid's batched specs x L x trials cube chooses, per spec,
        exactly the L the per-spec mc sweep chooses (fresh rng each --
        the sweep draws are bit-identical per spec block)."""
        specs = [HetSpec.uniform_random(K, mu, mu * mu / 6,
                                        np.random.default_rng(seed + s))
                 for s in range(n_specs)]
        trials = 16
        scheme = schemes_mod.get_scheme("mds", opt_trials=trials)
        grid = scheme.mc_grid(specs, n, trials,
                              np.random.default_rng(seed),
                              backend="numpy")
        for g, het in zip(grid, specs):
            L_solo, _, _ = schemes_mod.mds_sweep_batched(
                het, n, trials, _rng_at_spec(specs, het, seed, trials, n),
                backend="numpy")
            assert g.extra["L"] == L_solo

    @given(K=st.integers(2, 12), mu=st.floats(5.0, 80.0),
           n=st.integers(50, 20_000), trials=st.integers(4, 64),
           L=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_fixed_L_time_samples_backend_path_is_exact(self, K, mu, n,
                                                        trials, L, seed):
        """The backend-routed mds_time_samples (numpy) is bit-identical
        to the direct rng.gamma draw it replaced."""
        L = min(L, K)
        het = HetSpec.uniform_random(K, mu, mu * mu / 6,
                                     np.random.default_rng(seed))
        rng = np.random.default_rng(seed + 2)
        m = int(np.ceil(n / L))
        t = rng.gamma(shape=m, scale=1.0 / het.lambdas, size=(trials, K))
        t.sort(axis=1)
        want = t[:, L - 1]
        got = schemes_mod.mds_time_samples(
            het, n, L, trials, np.random.default_rng(seed + 2),
            backend="numpy")
        np.testing.assert_array_equal(got, want)


def _rng_at_spec(specs, het, seed, trials, n):
    """Replay the grid draw stream up to ``het``'s spec block: the cube is
    spec-major, so spec g's sweep sees the rng after g earlier sweeps."""
    rng = np.random.default_rng(seed)
    for h in specs:
        if h is het:
            return rng
        schemes_mod.mds_sweep_batched(h, n, trials, rng, backend="numpy")
    raise AssertionError("spec not in grid")


class TestCodedProperties:
    @given(rows=st.integers(2, 40), d=st.integers(1, 8),
           K=st.integers(2, 7), seed=st.integers(0, 1000))
    @settings(**SETTINGS)
    def test_mds_decode_from_any_L_subset(self, rows, d, K, seed):
        rng = np.random.default_rng(seed)
        L = rng.integers(1, K + 1)
        A = rng.normal(size=(rows, d))
        x = rng.normal(size=(d,))
        code = MDSCodedMatmul(K=K, L=int(L))
        chunks = code.encode(A)
        workers = rng.choice(K, size=int(L), replace=False)
        replies = {int(w): chunks[int(w)] @ x for w in workers}
        np.testing.assert_allclose(code.decode(replies), A @ x,
                                   rtol=1e-6, atol=1e-6)

    @given(n_units=st.integers(1, 30), seed=st.integers(0, 1000))
    @settings(**SETTINGS)
    def test_gradient_coding_covers_with_any_group_survivor(self, n_units,
                                                            seed):
        rng = np.random.default_rng(seed)
        K, s = 6, 2
        gc = GradientCoding(K=K, s=s)
        owners = gc.assignment(n_units)
        grads = [rng.normal(size=3) for _ in range(n_units)]
        # drop one whole replica group except one worker per... the FR code
        # guarantees recovery when, per replica group, the survivors still
        # cover the partition: drop any s workers
        drop = set(rng.choice(K, size=s, replace=False).tolist())
        replies = {w: {u: grads[u] for u in owners[w]}
                   for w in range(K) if w not in drop}
        try:
            out = gc.decode(n_units, replies)
            np.testing.assert_allclose(out, np.sum(grads, axis=0), rtol=1e-9)
        except ValueError:
            # dropping s workers in the same group CAN uncover units only if
            # they constitute a full cover of some unit -- with s+1=3 groups
            # and s=2 drops, every unit still has >= 1 replica: must decode
            raise
