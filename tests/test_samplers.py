"""Sampler backends: registry/selection semantics, numpy bit-identity,
numpy-vs-jax statistical equivalence, and ``mc_grid`` agreement.

The jax tests deliberately share one padded batch-shape bucket (B=512) so
the whole file pays a single jit compilation.
"""
import numpy as np
import pytest

from repro.core.samplers import (ENV_VAR, SAMPLER_BACKENDS, SamplerBackend,
                                 get_backend, list_backends,
                                 register_backend, resolve_backend,
                                 work_exchange_grid_numpy)
from repro.core.schemes import get_scheme, work_exchange_mc_batched
from repro.core.types import ExchangeConfig, HetSpec

RNG = lambda s=0: np.random.default_rng(s)  # noqa: E731

K, N, TRIALS = 15, 50_000, 512      # B = 512: one jit bucket for the file


def make_het(K=K, mu=20.0, sigma2=20.0 ** 2 / 6, seed=3):
    return HetSpec.uniform_random(K, mu, sigma2, RNG(seed))


# ---------------------------------------------------------------------------
# registry + selection
# ---------------------------------------------------------------------------

class TestBackendRegistry:
    def test_builtin_backends_registered(self):
        assert {"numpy", "jax", "pallas"} <= set(list_backends())
        for name in ("numpy", "jax", "pallas"):
            assert get_backend(name).name == name
            assert get_backend(name).description

    def test_default_is_numpy(self, monkeypatch):
        monkeypatch.delenv(ENV_VAR, raising=False)
        assert resolve_backend() == "numpy"

    def test_env_var_selects_backend(self, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "jax")
        assert resolve_backend() == "jax"
        monkeypatch.setenv(ENV_VAR, "numpy")
        assert resolve_backend() == "numpy"

    def test_kwarg_overrides_env(self, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "jax")
        assert resolve_backend("numpy") == "numpy"

    def test_unknown_backend_raises(self, monkeypatch):
        with pytest.raises(KeyError, match="no_such"):
            resolve_backend("no_such")
        monkeypatch.setenv(ENV_VAR, "bogus")
        with pytest.raises(KeyError, match="bogus"):
            resolve_backend()

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_backend(SamplerBackend(
                name="numpy", work_exchange_grid=work_exchange_grid_numpy))

    def test_unavailable_backend_rejected_with_hint(self):
        register_backend(SamplerBackend(name="tmp_unavailable",
                                        work_exchange_grid=None),
                         available=lambda: False)
        try:
            with pytest.raises(RuntimeError, match="unavailable"):
                resolve_backend("tmp_unavailable")
        finally:
            del SAMPLER_BACKENDS["tmp_unavailable"]

    def test_env_var_reaches_scheme_mc(self, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "jax")
        rep = get_scheme("work_exchange").mc(make_het(), N, TRIALS, RNG(0))
        assert rep.extra["backend"] == "jax"
        rep = get_scheme("work_exchange").mc(make_het(), N, TRIALS, RNG(0),
                                             backend="numpy")
        assert rep.extra["backend"] == "numpy"


class TestBackendValidationFix:
    """Regression: an unknown backend -- kwarg OR env var -- must raise a
    KeyError naming the registered backends from EVERY scheme's mc/mc_grid
    entry point, including schemes that never draw through a backend
    (previously the name was silently ignored there, and the env-var path
    could only fail far downstream)."""

    # one loop-based, one static-batched, one redundant-batched, one
    # engine-backed, plus the sweep scheme: the full mc override surface
    SCHEMES = ("oracle", "fixed", "mds", "het_mds", "work_exchange",
               "trace_replay")

    @pytest.mark.parametrize("name", SCHEMES)
    def test_kwarg_nosuch_raises_keyerror(self, name):
        with pytest.raises(KeyError, match="nosuch.*numpy"):
            get_scheme(name).mc(make_het(), 1_000, 2, RNG(0),
                                backend="nosuch")

    @pytest.mark.parametrize("name", SCHEMES)
    def test_env_nosuch_raises_keyerror(self, name, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "nosuch")
        with pytest.raises(KeyError, match="nosuch.*numpy"):
            get_scheme(name).mc(make_het(), 1_000, 2, RNG(0))

    @pytest.mark.parametrize("name", ("fixed", "mds", "het_mds",
                                      "work_exchange"))
    def test_mc_grid_nosuch_raises_keyerror(self, name, monkeypatch):
        monkeypatch.setenv(ENV_VAR, "nosuch")
        with pytest.raises(KeyError, match="nosuch.*numpy"):
            get_scheme(name).mc_grid([make_het()], 1_000, 2, RNG(0))

    def test_error_lists_registered_backends(self):
        with pytest.raises(KeyError) as ei:
            get_scheme("oracle").mc(make_het(), 1_000, 2, RNG(0),
                                    backend="nosuch")
        for registered in list_backends():
            assert registered in str(ei.value)

    def test_loop_engine_still_validates_backend(self):
        # regression: engine="loop" used to drop the kwarg entirely
        with pytest.raises(KeyError, match="nosuch"):
            get_scheme("work_exchange", engine="loop").mc(
                make_het(), 1_000, 2, RNG(0), backend="nosuch")


class TestGammaRows:
    """The per-backend batched Gamma primitive the MDS sweep draws on."""

    @pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
    def test_broadcast_shapes_including_R_equals_K(self, backend):
        # regression: a 1-D (K,) scale with R == K used to be padded as
        # if it carried the batch rows, crashing the jitted kernel
        from repro.core.samplers import get_gamma_rows
        draw = get_gamma_rows(backend)
        K = 8
        for shape_rows, scale in (
                (np.full((K, 1), 50.0), np.full(K, 0.1)),      # R == K
                (np.full((3, 1), 20.0), np.full((1, K), 0.2)),
                (np.full((5, K), 10.0), np.full((5, K), 0.5))):
            out = draw(shape_rows, scale, RNG(1))
            R = np.broadcast_shapes(shape_rows.shape,
                                    np.asarray(scale).shape)[0]
            assert out.shape == (R, K)
            assert np.isfinite(out).all() and (out > 0).all()

    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_mean_matches_exact_numpy(self, backend):
        from repro.core.samplers import get_gamma_rows
        shape_rows = np.full((4096, 4), 12.0)
        scale = np.full(4, 0.25)
        g = get_gamma_rows(backend)(shape_rows, scale, RNG(2))
        n = g.size
        se = np.sqrt(12.0 + 1 / 9) * 0.25 / np.sqrt(n)
        assert abs(g.mean() - 3.0) < 6 * se


# ---------------------------------------------------------------------------
# numpy backend: exact semantics
# ---------------------------------------------------------------------------

class TestNumpyBackend:
    def test_mc_backend_numpy_is_the_batched_engine(self):
        het = make_het()
        cfg = ExchangeConfig(known_heterogeneity=True)
        a = get_scheme("work_exchange").mc(het, 5_000, 32, RNG(1),
                                           keep_trials=True,
                                           backend="numpy")
        b = work_exchange_mc_batched(het, 5_000, cfg, 32, RNG(1),
                                     keep_trials=True)
        np.testing.assert_array_equal(a.t_comp_trials, b.t_comp_trials)
        np.testing.assert_array_equal(a.n_comm_trials, b.n_comm_trials)

    def test_single_spec_grid_is_bitwise_mc(self):
        het = make_het(seed=9)
        for known in (True, False):
            scheme = get_scheme("work_exchange" if known
                                else "work_exchange_unknown")
            rep = scheme.mc(het, 4_000, 24, RNG(2), keep_trials=True,
                            backend="numpy")
            [grid] = scheme.mc_grid([het], 4_000, 24, RNG(2),
                                    keep_trials=True, backend="numpy")
            np.testing.assert_array_equal(rep.t_comp_trials,
                                          grid.t_comp_trials)
            np.testing.assert_array_equal(rep.iterations_trials,
                                          grid.iterations_trials)
            np.testing.assert_array_equal(rep.n_comm_trials,
                                          grid.n_comm_trials)

    def test_grid_engine_conserves_work_per_row(self):
        lam = np.stack([make_het(seed=s).lambdas for s in (1, 2, 3)])
        cfg = ExchangeConfig(known_heterogeneity=False)
        t, it, cm = work_exchange_grid_numpy(lam, 3_000, cfg, 8, RNG(3))
        assert t.shape == it.shape == cm.shape == (24,)
        assert (t > 0).all() and (it >= 1).all() and (cm >= 0).all()

    def test_bad_lam_shape_raises(self):
        with pytest.raises(ValueError, match="G, K"):
            work_exchange_grid_numpy(np.ones(5), 100,
                                     ExchangeConfig(), 2, RNG(0))


# ---------------------------------------------------------------------------
# jax backend: statistical equivalence with the exact engine
# ---------------------------------------------------------------------------

def _stat_close(rep_np, rep_jax, trials):
    """Mean agreement within MC tolerance: 6 combined standard errors with
    a small relative floor for the fluid relaxation's float32 pipeline."""
    se = np.hypot(rep_np.t_comp_std, rep_jax.t_comp_std) / np.sqrt(trials)
    tol = max(6.0 * se, 1e-3 * rep_np.t_comp)
    assert abs(rep_np.t_comp - rep_jax.t_comp) < tol, \
        (rep_np.t_comp, rep_jax.t_comp, tol)


class TestJaxEquivalence:
    @pytest.mark.parametrize("name", ["work_exchange",
                                      "work_exchange_unknown"])
    def test_mean_time_matches(self, name):
        het = make_het(seed=11)
        scheme = get_scheme(name)
        rn = scheme.mc(het, N, TRIALS, RNG(5), backend="numpy")
        rj = scheme.mc(het, N, TRIALS, RNG(5), backend="jax")
        assert rj.extra["backend"] == "jax"
        _stat_close(rn, rj, TRIALS)
        # both sit just above the work-conservation lower bound
        oracle = N / het.lambda_sum
        assert oracle <= rj.t_comp < 1.05 * oracle

    @pytest.mark.parametrize("name", ["work_exchange",
                                      "work_exchange_unknown"])
    def test_iterations_and_comm_match(self, name):
        het = make_het(seed=12)
        scheme = get_scheme(name)
        rn = scheme.mc(het, N, TRIALS, RNG(6), backend="numpy")
        rj = scheme.mc(het, N, TRIALS, RNG(6), backend="jax")
        # the fluid relaxation may end the exchange loop a couple of
        # rounds away from the integer engine (sub-half-unit shares are
        # carried, not rounded up)
        assert abs(rn.iterations - rj.iterations) <= max(
            4.0, 0.2 * rn.iterations)
        # communication: identical at the fraction-of-N scale
        assert abs(rn.n_comm - rj.n_comm) / N < 0.01

    def test_keep_trials_shapes(self):
        rep = get_scheme("work_exchange").mc(make_het(), N, TRIALS, RNG(7),
                                             keep_trials=True, backend="jax")
        for arr in (rep.t_comp_trials, rep.iterations_trials,
                    rep.n_comm_trials):
            assert arr is not None and arr.shape == (TRIALS,)
        assert rep.t_comp == pytest.approx(rep.t_comp_trials.mean())

    def test_waterfill_mode_not_supported(self):
        scheme = get_scheme("work_exchange_unknown", capped_mode="waterfill")
        with pytest.raises(ValueError, match="waterfill"):
            scheme.mc(make_het(), 2_000, 4, RNG(8), backend="jax")

    def test_loop_engine_ignores_backend(self):
        # engine="loop" is the scalar validation reference: it stays numpy
        rep = get_scheme("work_exchange", engine="loop").mc(
            make_het(), 2_000, 3, RNG(9), backend="jax")
        assert rep.trials == 3 and rep.t_comp > 0


# ---------------------------------------------------------------------------
# mc_grid semantics
# ---------------------------------------------------------------------------

class TestMcGrid:
    def test_default_loop_equals_manual_loop(self):
        # base-class mc_grid draws from the shared rng in spec order
        specs = [make_het(seed=s) for s in (1, 2)]
        scheme = get_scheme("oracle")
        grid = scheme.mc_grid(specs, 10_000, 16, RNG(10))
        rng = RNG(10)
        manual = [scheme.mc(h, 10_000, 16, rng) for h in specs]
        for g, m in zip(grid, manual):
            assert g.t_comp == m.t_comp and g.t_comp_std == m.t_comp_std

    @pytest.mark.parametrize("backend", ["numpy", "jax"])
    def test_grid_matches_looped_mc_statistically(self, backend):
        specs = [make_het(seed=s, mu=10.0 * (s + 1),
                          sigma2=(10.0 * (s + 1)) ** 2 / 6) for s in (0, 1)]
        trials = TRIALS // len(specs)       # same B bucket as the rest
        scheme = get_scheme("work_exchange_unknown")
        grid = scheme.mc_grid(specs, N, trials, RNG(11), backend=backend)
        for het, g in zip(specs, grid):
            m = scheme.mc(het, N, trials, RNG(12), backend="numpy")
            se = np.hypot(g.t_comp_std, m.t_comp_std) / np.sqrt(trials)
            assert abs(g.t_comp - m.t_comp) < max(6 * se, 2e-3 * m.t_comp)
        # reports align with the spec axis: faster cluster finishes sooner
        assert grid[1].t_comp < grid[0].t_comp

    def test_mixed_k_grid_falls_back_to_loop(self):
        specs = [make_het(K=5, seed=1), make_het(K=8, seed=2)]
        scheme = get_scheme("work_exchange")
        grid = scheme.mc_grid(specs, 3_000, 6, RNG(13), backend="numpy")
        rng = RNG(13)
        manual = [scheme.mc(h, 3_000, 6, rng, backend="numpy")
                  for h in specs]
        for g, m in zip(grid, manual):
            assert g.t_comp == m.t_comp

    def test_grid_report_metadata(self):
        specs = [make_het(seed=s) for s in (4, 5)]
        grid = get_scheme("work_exchange").mc_grid(
            specs, 5_000, 8, RNG(14), keep_trials=True, backend="numpy")
        assert len(grid) == 2
        for rep in grid:
            assert rep.scheme == "work_exchange"
            assert rep.trials == 8
            assert rep.extra["backend"] == "numpy"
            assert rep.t_comp_trials.shape == (8,)

    @pytest.mark.parametrize("name", ["fixed", "uniform", "het_mds"])
    def test_static_scheme_grid_matches_looped_mc(self, name):
        # the one-draw batched grid is the same distribution as looped mc
        specs = [make_het(seed=s) for s in (6, 7)]
        trials = 400
        scheme = get_scheme(name)
        grid = scheme.mc_grid(specs, 20_000, trials, RNG(16))
        for het, g in zip(specs, grid):
            m = scheme.mc(het, 20_000, trials, RNG(17))
            se = np.hypot(g.t_comp_std, m.t_comp_std) / np.sqrt(trials)
            assert abs(g.t_comp - m.t_comp) < 6 * se
            assert g.n_comm == m.n_comm and g.iterations == 1.0

    def test_empty_grid(self):
        assert get_scheme("work_exchange").mc_grid([], 1_000, 4,
                                                   RNG(15)) == []


# ---------------------------------------------------------------------------
# K / R shape bucketing
# ---------------------------------------------------------------------------

class TestShapeBucketing:
    """Panel shape bucketing: non-pow2 ``(K, R)`` pad into pow2 buckets
    with fully-masked columns / repeated last schedule rows, so one
    compilation (and one persistent-cache entry) serves the shape
    family.  On the counter-keyed pallas pipeline the padding must be
    bitwise invisible; on the stream-keyed jax engine, statistically."""

    def test_bucket_targets(self):
        from repro.core.samplers import bucket_cols, bucket_rounds
        assert [bucket_cols(k) for k in (3, 12, 13, 16, 17, 50)] == \
            [4, 16, 16, 16, 24, 56]
        assert [bucket_rounds(r) for r in (6, 7, 16, 19, 48)] == \
            [8, 8, 16, 32, 48]

    def test_disable_env(self, monkeypatch):
        from repro.core.samplers import bucket_cols, bucket_rounds
        monkeypatch.setenv("REPRO_SHAPE_BUCKETS", "0")
        assert bucket_cols(13) == 13 and bucket_rounds(19) == 19

    def test_grid_bucket_shape_families(self):
        # two different raw panel shapes landing in ONE bucket is the
        # whole point: one compile, one shared cache entry
        from repro.core.samplers import grid_bucket_shape
        a = grid_bucket_shape(2, 16, 12, None, backend="jax")
        b = grid_bucket_shape(3, 8, 14, None, backend="jax")
        assert a == b == {"rows": 64, "K": 16}

    @pytest.mark.parametrize("known", [True, False])
    def test_non_pow2_K_mode_identity_under_bucketing(self, known,
                                                      monkeypatch):
        """K=13 pads to the 16 bucket with masked zero-rate columns; at
        the padded shape the interpreted kernel and the jnp reference
        stay BIT-identical (the pin the bucketing must not break).
        Bucketed vs exact shapes are NOT bit-equal -- float32 reduction
        order over the K axis changes with the padded width -- so the
        cross-setting check is statistical, below."""
        from repro.core.samplers import work_exchange_grid_pallas
        lam = RNG(2).uniform(5.0, 15.0, size=(2, 13))
        cfg = ExchangeConfig(known_heterogeneity=known)
        for buckets in ("1", "0"):
            monkeypatch.setenv("REPRO_SHAPE_BUCKETS", buckets)
            outs = []
            for mode in ("interpret", "reference"):
                monkeypatch.setenv("REPRO_WE_ROUNDS_MODE", mode)
                outs.append(work_exchange_grid_pallas(lam, 6_000, cfg, 32,
                                                      RNG(9)))
            for a, b in zip(*outs):
                np.testing.assert_array_equal(a, b, err_msg=buckets)

    @pytest.mark.parametrize("known", [True, False])
    def test_non_pow2_R_drift_mode_identity_under_bucketing(self, known,
                                                            monkeypatch):
        """A 19-round drift schedule pads to the 32 bucket by repeating
        the last row -- exactly the engines' ``round >= R`` clamp -- and
        the padded shape keeps the interpret/reference bit-identity."""
        from repro.core.samplers import work_exchange_grid_pallas
        rng = RNG(4)
        lam = rng.uniform(5.0, 15.0, size=(2, 13))
        sched = lam[:, None, :] * np.exp(
            0.2 * rng.standard_normal((2, 19, 13)))
        cfg = ExchangeConfig(known_heterogeneity=known)
        for buckets in ("1", "0"):
            monkeypatch.setenv("REPRO_SHAPE_BUCKETS", buckets)
            outs = []
            for mode in ("interpret", "reference"):
                monkeypatch.setenv("REPRO_WE_ROUNDS_MODE", mode)
                outs.append(work_exchange_grid_pallas(
                    lam, 6_000, cfg, 32, RNG(9), rate_schedule=sched))
            for a, b in zip(*outs):
                np.testing.assert_array_equal(a, b, err_msg=buckets)

    def test_bucketed_vs_exact_statistical_on_pallas(self, monkeypatch):
        """Bucketing on vs off at non-pow2 K: means agree at 6 SE (the
        padding is statistically, not bitwise, invisible)."""
        from repro.core.samplers import work_exchange_grid_pallas
        lam = RNG(5).uniform(15.0, 25.0, size=(1, 13))
        cfg = ExchangeConfig(known_heterogeneity=False)
        trials = 512
        res = {}
        for buckets in ("1", "0"):
            monkeypatch.setenv("REPRO_SHAPE_BUCKETS", buckets)
            res[buckets] = work_exchange_grid_pallas(lam, N, cfg, trials,
                                                     RNG(9))
        t1, t0 = res["1"][0], res["0"][0]
        se = np.hypot(t1.std(), t0.std()) / np.sqrt(trials)
        assert abs(t1.mean() - t0.mean()) < max(6 * se, 2e-3 * t0.mean())

    def test_non_pow2_K_statistical_on_jax(self, monkeypatch):
        """The jax engine keys draws by stream, not counters, so K
        padding moves individual samples; means must still agree with
        the exact numpy engine at 6 SE at a non-pow2 K."""
        from repro.core.samplers import work_exchange_grid_jax
        lam = RNG(6).uniform(15.0, 25.0, size=(1, 13))
        cfg = ExchangeConfig(known_heterogeneity=False)
        trials = 512
        t_j, _, _ = work_exchange_grid_jax(lam, N, cfg, trials, RNG(7))
        t_n, _, _ = work_exchange_grid_numpy(lam, N, cfg, trials, RNG(8))
        se = np.hypot(t_j.std(), t_n.std()) / np.sqrt(trials)
        assert abs(t_j.mean() - t_n.mean()) < max(6 * se,
                                                  2e-3 * t_n.mean())


# ---------------------------------------------------------------------------
# fused whole-panel dispatch
# ---------------------------------------------------------------------------

class TestFusedPanelDispatch:
    """``mc_grid_panel``: the WE known/unknown pair as ONE engine call."""

    def _schemes(self):
        return {"we": get_scheme("work_exchange"),
                "weu": get_scheme("work_exchange_unknown"),
                "fixed": get_scheme("fixed")}

    def test_pair_detection(self):
        from repro.core.schemes import _panel_pair
        assert _panel_pair(self._schemes()) == ("we", "weu")
        # mismatched thresholds cannot share one round loop
        s = self._schemes()
        s["weu"] = get_scheme("work_exchange_unknown", threshold_frac=0.05)
        assert _panel_pair(s) is None
        # loop-engine references never fuse
        s = self._schemes()
        s["we"] = get_scheme("work_exchange", engine="loop")
        assert _panel_pair(s) is None

    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_panel_matches_numpy_at_6se(self, backend):
        from repro.core.schemes import mc_grid_panel
        specs = [make_het(seed=s) for s in (1, 2)]
        trials = 256
        out = mc_grid_panel(self._schemes(), specs, N, trials, RNG(21),
                            backend=backend)
        for key in ("we", "weu"):
            assert all(r.extra.get("fused_panel") == 1 for r in out[key])
            name = ("work_exchange" if key == "we"
                    else "work_exchange_unknown")
            ref = get_scheme(name).mc_grid(specs, N, trials, RNG(22),
                                           backend="numpy")
            for g, (a, b) in enumerate(zip(out[key], ref)):
                se = np.hypot(a.t_comp_std, b.t_comp_std) / np.sqrt(trials)
                assert abs(a.t_comp - b.t_comp) < max(6 * se,
                                                      2e-3 * b.t_comp), \
                    (backend, key, g)
                assert abs(a.n_comm - b.n_comm) / N < 0.01

    def test_rng_mapping_keeps_non_pair_bitwise(self):
        """With the executor's per-task rng mapping, non-fused schemes
        draw from exactly the per-scheme stream: panel mode only moves
        the fused pair's numbers."""
        from repro.core.schemes import mc_grid_panel
        specs = [make_het(seed=4)]
        rngs = {"we": RNG(31), "weu": RNG(32), "fixed": RNG(33)}
        out = mc_grid_panel(self._schemes(), specs, 20_000, 64, rngs,
                            backend="jax")
        ref = get_scheme("fixed").mc_grid(specs, 20_000, 64, RNG(33),
                                          backend="jax")
        assert out["fixed"][0].t_comp == ref[0].t_comp
        assert out["fixed"][0].extra.get("fused_panel") is None

    def test_numpy_falls_back_per_scheme_bitwise(self):
        """No panel executor on the exact backend: every scheme runs its
        own mc_grid from its own stream -- bit-identical to per-scheme
        dispatch, no fused_panel flag."""
        from repro.core.schemes import mc_grid_panel
        specs = [make_het(seed=5)]
        rngs = {"we": RNG(41), "weu": RNG(42), "fixed": RNG(43)}
        out = mc_grid_panel(self._schemes(), specs, 20_000, 16, rngs,
                            backend="numpy")
        for key, name, seed in (("we", "work_exchange", 41),
                                ("weu", "work_exchange_unknown", 42),
                                ("fixed", "fixed", 43)):
            ref = get_scheme(name).mc_grid(specs, 20_000, 16, RNG(seed),
                                           backend="numpy")
            assert out[key][0].t_comp == ref[0].t_comp
            assert out[key][0].extra.get("fused_panel") is None

    def test_pallas_panel_mode_identity(self, monkeypatch):
        """The stacked pallas panel launch is bitwise mode-identical:
        interpret-mode kernel == jitted reference, known and unknown
        halves both."""
        from repro.core.samplers import work_exchange_panel_pallas
        lam = RNG(51).uniform(10.0, 30.0, size=(2, 12))
        cfg_k = ExchangeConfig(known_heterogeneity=True)
        cfg_u = ExchangeConfig(known_heterogeneity=False)
        outs = []
        for mode in ("interpret", "reference"):
            monkeypatch.setenv("REPRO_WE_ROUNDS_MODE", mode)
            outs.append(work_exchange_panel_pallas(lam, 10_000, cfg_k,
                                                   cfg_u, 32, RNG(52)))
        for slot in ("known", "unknown"):
            for a, b in zip(outs[0][slot], outs[1][slot]):
                np.testing.assert_array_equal(a, b, err_msg=slot)

    def test_drift_panel_matches_numpy_at_6se(self):
        from repro.core.schemes import mc_grid_panel
        rng = RNG(61)
        specs = [make_het(seed=6)]
        lam = specs[0].lambdas
        sched = (lam[None, None, :]
                 * np.exp(0.15 * rng.standard_normal((1, 9, K))))
        trials = 256
        out = mc_grid_panel(self._schemes(), specs, N, trials, RNG(62),
                            backend="jax", rate_schedule=sched)
        for key, name in (("we", "work_exchange"),
                          ("weu", "work_exchange_unknown")):
            ref = get_scheme(name).mc_grid(specs, N, trials, RNG(63),
                                           backend="numpy",
                                           rate_schedule=sched)
            a, b = out[key][0], ref[0]
            se = np.hypot(a.t_comp_std, b.t_comp_std) / np.sqrt(trials)
            assert abs(a.t_comp - b.t_comp) < max(6 * se, 2e-3 * b.t_comp)
