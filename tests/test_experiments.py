"""Declarative experiment API: spec round-trip + hash stability, the
content-addressed store's hit/miss contract, engine semantics (per-task
seeding, numpy bit-reproducibility, figure-driver bit-identity), and
sharded-vs-single-device statistical equivalence over every registered
sampler backend (subprocess with simulated devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.schemes import MCReport, get_scheme
from repro.core.samplers import active_grid_mesh, grid_sharding
from repro.core.types import HetSpec
from repro.experiments import (ExperimentResult, ExperimentSpec, Plan,
                               ResultsStore, ScenarioGrid, compile_plan,
                               run_experiment, scheme_spec)

RNG = lambda s=0: np.random.default_rng(s)  # noqa: E731


def quick_spec(**overrides) -> ExperimentSpec:
    base = dict(
        name="test-quick",
        grid=ScenarioGrid(K=8, points=[(10.0, 10.0 ** 2 / 6, 1),
                                       (20.0, 0.0, 2)]),
        schemes=(scheme_spec("work_exchange"),
                 scheme_spec("hedged"),
                 scheme_spec("work_exchange_unknown", key="we-th",
                             threshold_frac=0.05, seed=99)),
        N=5_000, trials=8, seed=42)
    base.update(overrides)
    return ExperimentSpec(**base)


class TestHetSpecValue:
    """Satellite: HetSpec is hashable + serializable."""

    def test_round_trip_exact(self):
        het = HetSpec.uniform_random(17, 33.3, 33.3 ** 2 / 6, RNG(5))
        back = HetSpec.from_dict(json.loads(json.dumps(het.to_dict())))
        assert back == het
        np.testing.assert_array_equal(back.lambdas, het.lambdas)

    def test_hash_and_eq(self):
        a = HetSpec(np.array([1.0, 2.0, 3.0]))
        b = HetSpec(np.array([1.0, 2.0, 3.0]))
        c = HetSpec(np.array([1.0, 2.0, 3.5]))
        assert a == b and hash(a) == hash(b)
        assert a != c and a != "not a spec"
        assert len({a, b, c}) == 2
        assert a.canonical_hash() == b.canonical_hash()
        assert a.canonical_hash() != c.canonical_hash()

    def test_canonical_hash_pinned(self):
        # platform-stable (big-endian float64 bytes): a changed preimage
        # would silently orphan every stored result
        assert HetSpec(np.array([1.0, 2.0])).canonical_hash() == (
            "f814737da80b11b6d6e54c254b9d7e71"
            "1669462c0e53585f776afea6ea073afc")

    def test_rates_frozen(self):
        het = HetSpec(np.array([1.0, 2.0]))
        with pytest.raises(ValueError):
            het.lambdas[0] = 9.0

    def test_no_aliasing_of_caller_buffer(self):
        buf = np.array([1.0, 2.0])
        HetSpec(buf)
        buf[0] = 5.0                    # caller's array stays writable


class TestSpecRoundTrip:
    def test_json_round_trip_and_hash_stability(self):
        spec = quick_spec()
        back = ExperimentSpec.from_json(spec.to_json())
        assert back == spec
        assert back.to_dict() == spec.to_dict()
        assert back.spec_hash() == spec.spec_hash()

    def test_hash_covers_every_knob(self):
        base = quick_spec()
        seen = {base.spec_hash()}
        for changed in (base.replace(N=6_000),
                        base.replace(trials=9),
                        base.replace(seed=43),
                        base.replace(backend="numpy"),
                        base.replace(devices=4),
                        base.replace(schemes=base.schemes[:2]),
                        base.replace(grid=ScenarioGrid(
                            K=8, points=[(10.0, 10.0 ** 2 / 6, 1)]))):
            h = changed.spec_hash()
            assert h not in seen, changed
            seen.add(h)

    def test_scheme_params_reach_the_hash(self):
        a = quick_spec()
        b = quick_spec(schemes=(scheme_spec("work_exchange"),
                                scheme_spec("hedged"),
                                scheme_spec("work_exchange_unknown",
                                            key="we-th",
                                            threshold_frac=0.2, seed=99)))
        assert a.spec_hash() != b.spec_hash()

    def test_panel_key_omitted_at_default(self):
        """``panel="per_scheme"`` must not appear in the serialized spec:
        every pre-panel hash and store address survives the new field."""
        base = quick_spec()
        assert "panel" not in base.to_dict()
        fused = quick_spec(panel="fused")
        assert fused.to_dict()["panel"] == "fused"
        assert fused.spec_hash() != base.spec_hash()
        back = ExperimentSpec.from_json(fused.to_json())
        assert back.panel == "fused" and back.spec_hash() == fused.spec_hash()
        with pytest.raises(ValueError, match="panel"):
            quick_spec(panel="bogus")

    def test_panel_fused_excludes_serving_and_live(self):
        from repro.experiments import ServingConfig
        with pytest.raises(ValueError, match="batch MC only"):
            quick_spec(panel="fused",
                       serving=ServingConfig(loads=(0.5,), slots=100))

    def test_explicit_grid_round_trip(self):
        hets = (HetSpec(np.array([1.0, 2.0, 3.0])),
                HetSpec(np.array([2.0, 2.0, 2.0])))
        grid = ScenarioGrid(explicit=hets)
        assert grid.K == 3 and len(grid) == 2
        back = ScenarioGrid.from_dict(json.loads(json.dumps(grid.to_dict())))
        assert back == grid
        assert back.specs() == list(hets)

    def test_points_grid_materializes_deterministically(self):
        grid = ScenarioGrid(K=8, points=[(10.0, 5.0, 3)])
        np.testing.assert_array_equal(grid.specs()[0].lambdas,
                                      grid.specs()[0].lambdas)
        want = HetSpec.uniform_random(8, 10.0, 5.0, RNG(3))
        assert grid.specs()[0] == want

    def test_invalid_specs_rejected(self):
        with pytest.raises(ValueError, match="exactly one"):
            ScenarioGrid(K=4)
        with pytest.raises(ValueError, match="exactly one"):
            ScenarioGrid(K=4, points=[(1.0, 0.0, 1)],
                         explicit=(HetSpec(np.array([1.0])),))
        with pytest.raises(ValueError, match="share K"):
            ScenarioGrid(explicit=(HetSpec(np.array([1.0])),
                                   HetSpec(np.array([1.0, 2.0]))))
        with pytest.raises(ValueError, match="at least one scheme"):
            quick_spec(schemes=())
        with pytest.raises(ValueError, match="duplicate"):
            quick_spec(schemes=(scheme_spec("work_exchange"),
                                scheme_spec("work_exchange")))
        with pytest.raises(ValueError, match="devices"):
            quick_spec(devices="many")

    def test_compile_validates_scheme_names_and_params(self):
        with pytest.raises(KeyError, match="no_such"):
            compile_plan(quick_spec(schemes=(scheme_spec("no_such"),)))
        with pytest.raises(TypeError):
            compile_plan(quick_spec(
                schemes=(scheme_spec("work_exchange", bogus_param=1),)))

    def test_compile_resolves_backend_and_devices(self):
        plan = compile_plan(quick_spec())
        assert isinstance(plan, Plan)
        assert plan.backend == "numpy"
        assert plan.devices == 1            # numpy pins to 1 device
        assert plan.spec.backend == "numpy"
        # unknown env/kwarg backends fail at compile
        with pytest.raises(KeyError, match="nope"):
            compile_plan(quick_spec(backend="nope"))
        # per-task seeds: explicit override beats the spec seed
        assert [t.seed for t in plan.tasks] == [42, 42, 99]

    def test_devices_clamp_to_host(self):
        # jax backend with an over-ask clamps to the attached device count
        plan = compile_plan(quick_spec(backend="jax", devices=512))
        import jax
        assert plan.devices == len(jax.devices())


class TestStore:
    def test_miss_then_hit(self, tmp_path):
        store = ResultsStore(tmp_path / "store")
        spec = quick_spec()
        assert store.get(spec) is None
        first = run_experiment(spec, store=store)
        assert not first.cache_hit
        path = store.path_for(first.spec_hash)
        assert path.is_file()
        second = run_experiment(spec, store=store)
        assert second.cache_hit
        assert second.to_dict()["reports"] == first.to_dict()["reports"]
        assert store.entries() == [first.spec_hash]
        assert not list((tmp_path / "store").glob("*.tmp"))

    def test_changed_spec_is_a_miss(self, tmp_path):
        store = ResultsStore(tmp_path)
        run_experiment(quick_spec(), store=store)
        assert store.get(quick_spec(trials=9)) is None
        assert not run_experiment(quick_spec(trials=9),
                                  store=store).cache_hit

    def test_corrupt_entry_reads_as_miss(self, tmp_path):
        store = ResultsStore(tmp_path)
        result = run_experiment(quick_spec(), store=store)
        store.path_for(result.spec_hash).write_text("{not json")
        assert store.get(quick_spec()) is None
        # the engine recomputes and heals the entry
        healed = run_experiment(quick_spec(), store=store)
        assert not healed.cache_hit
        assert store.get(quick_spec()) is not None

    def test_structurally_wrong_entry_reads_as_miss(self, tmp_path):
        store = ResultsStore(tmp_path)
        result = run_experiment(quick_spec(), store=store)
        for junk in ('{"spec": null}', "[1, 2, 3]", '{"spec": {"grid": 7}}'):
            store.path_for(result.spec_hash).write_text(junk)
            assert store.get(quick_spec()) is None, junk

    def test_clamped_device_overask_still_hits(self, tmp_path):
        # devices=8 on a 1-device host stores under the clamped hash;
        # spec-keyed lookups must resolve the same way
        store = ResultsStore(tmp_path)
        spec = quick_spec(backend="jax", devices=8)
        result = run_experiment(spec, store=store)
        assert result.spec.devices >= 1        # concrete after compile
        assert spec in store
        assert store.get(spec) is not None
        assert run_experiment(spec, store=store).cache_hit

    def test_mismatched_address_reads_as_miss(self, tmp_path):
        store = ResultsStore(tmp_path)
        result = run_experiment(quick_spec(), store=store)
        # copy the valid record to a wrong address: content hash disagrees
        (tmp_path / ("0" * 64 + ".json")).write_text(
            store.path_for(result.spec_hash).read_text())
        assert store.get("0" * 64) is None

    def test_force_recomputes_and_rewrites(self, tmp_path):
        store = ResultsStore(tmp_path)
        first = run_experiment(quick_spec(), store=store)
        forced = run_experiment(quick_spec(), store=store, force=True)
        assert not forced.cache_hit
        # numpy backend is bit-reproducible: identical stored numbers
        assert forced.to_dict()["reports"] == first.to_dict()["reports"]


class TestEngine:
    def test_result_round_trip(self, tmp_path):
        result = run_experiment(quick_spec())
        back = ExperimentResult.from_dict(
            json.loads(json.dumps(result.to_dict())))
        assert back.spec_hash == result.spec_hash
        assert back.spec == result.spec
        for key in result.keys():
            for a, b in zip(result.report(key), back.report(key)):
                assert isinstance(b, MCReport)
                assert (a.t_comp, a.t_comp_std, a.extra) == \
                    (b.t_comp, b.t_comp_std, b.extra)

    def test_per_task_seeding_is_order_independent(self):
        full = run_experiment(quick_spec())
        solo = run_experiment(quick_spec(
            schemes=(scheme_spec("hedged"),)))
        a = full.report("hedged")
        b = solo.report("hedged")
        assert [r.t_comp for r in a] == [r.t_comp for r in b]

    def test_matches_direct_mc_grid(self):
        spec = quick_spec()
        result = run_experiment(spec)
        hets = spec.grid.specs()
        direct = get_scheme("work_exchange_unknown",
                            threshold_frac=0.05).mc_grid(
            hets, spec.N, trials=spec.trials, rng=RNG(99))
        assert [r.t_comp for r in result.report("we-th")] == \
            [r.t_comp for r in direct]

    def test_fused_panel_execution(self):
        """panel='fused' on jax: the WE pair's reports carry the
        fused_panel flag, every other task is bit-identical to
        per-scheme execution (per-task rng mapping), and the fused
        means sit within SE of the per-scheme run."""
        spec = quick_spec(backend="jax",
                          schemes=(scheme_spec("work_exchange"),
                                   scheme_spec("work_exchange_unknown"),
                                   scheme_spec("hedged")),
                          trials=64)
        per = run_experiment(spec)
        fus = run_experiment(spec.replace(panel="fused"))
        assert [r.t_comp for r in fus.report("hedged")] == \
            [r.t_comp for r in per.report("hedged")]
        for key in ("work_exchange", "work_exchange_unknown"):
            for a, b in zip(fus.report(key), per.report(key)):
                assert a.extra.get("fused_panel") == 1
                assert b.extra.get("fused_panel") is None
                se = np.hypot(a.t_comp_std, b.t_comp_std) / np.sqrt(64)
                assert abs(a.t_comp - b.t_comp) < max(6 * se,
                                                      2e-3 * b.t_comp)

    def test_fused_panel_pins_devices(self):
        plan = compile_plan(quick_spec(panel="fused", backend="jax",
                                       devices="auto"))
        assert plan.devices == 1


class TestFigureDriversBitIdentical:
    """Acceptance: fig5/6/7 via ExperimentSpec == the pre-spec drivers,
    seed-for-seed on the numpy backend (small budgets, same seeds)."""

    N = 20_000

    def test_fig5(self):
        from benchmarks import fig5
        from benchmarks.common import FIG_SCHEMES
        rows = fig5.run(trials=3, n=self.N, quick=True)
        specs = fig5.grid_specs(quick=True)
        for name in FIG_SCHEMES:
            reports = get_scheme(name).mc_grid(specs, self.N, trials=3,
                                               rng=RNG(1234))
            for row, rep in zip(rows, reports):
                assert row[name] == rep.t_comp, name
        assert rows[0]["mds_opt"] == rows[0]["mds"]      # legacy columns

    def test_fig6(self):
        from benchmarks import fig6
        from benchmarks.common import THRESHOLD_FRAC, make_het
        rows = fig6.run(n=self.N, trials=2, quick=True)
        sigma2s = fig6.SIGMA2S[::2]
        n_draws = max(4, 20 // 4)
        specs = [make_het(fig6.MU, s2, seed=1000 + d)
                 for s2 in sigma2s for d in range(n_draws)]
        reps = get_scheme("work_exchange_unknown",
                          threshold_frac=THRESHOLD_FRAC).mc_grid(
            specs, self.N, trials=2, rng=RNG(2024))
        for i, s2 in enumerate(sigma2s):
            cell = reps[i * n_draws:(i + 1) * n_draws]
            want = float(np.mean([r.n_comm / self.N for r in cell]))
            assert rows[i]["comm_unknown"] == want

    def test_fig7(self):
        from benchmarks import fig7
        from benchmarks.common import make_het
        rows = fig7.run(n=self.N, trials=2, quick=True)
        fracs = fig7.THRESH_FRACS[::2]
        sigma2s = fig7.SIGMA2S[::2]
        specs = [make_het(fig7.MU, s2, seed=int(s2) + 7) for s2 in sigma2s]
        i = 0
        for frac in fracs:
            reps = get_scheme("work_exchange_unknown",
                              threshold_frac=frac).mc_grid(
                specs, self.N, trials=2, rng=RNG(int(frac * 1e6)))
            for rep in reps:
                assert rows[i]["iters"] == rep.iterations
                i += 1

    def test_store_round_trip_preserves_rows(self, tmp_path):
        from benchmarks import fig5
        store = ResultsStore(tmp_path)
        fresh = fig5.run(trials=2, n=self.N, quick=True, store=store)
        cached = fig5.run(trials=2, n=self.N, quick=True, store=store)
        assert fresh == cached


class TestGridShardingContext:
    def test_single_device_context_is_noop(self):
        # the main test process has 1 CPU device: the context must not
        # install a mesh, and results must be unchanged
        spec = HetSpec.uniform_random(8, 10.0, 10.0 ** 2 / 6, RNG(0))
        ref = get_scheme("work_exchange").mc(spec, 5_000, 16, RNG(1),
                                             keep_trials=True)
        with grid_sharding(4):
            assert active_grid_mesh() is None
            rep = get_scheme("work_exchange").mc(spec, 5_000, 16, RNG(1),
                                                 keep_trials=True)
        np.testing.assert_array_equal(rep.t_comp_trials, ref.t_comp_trials)
        assert active_grid_mesh() is None


SHARDED_PROBE = textwrap.dedent("""
    import os, json, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.core.samplers import (SAMPLER_BACKENDS, get_backend,
                                     grid_sharding, active_grid_mesh)
    from repro.core.schemes import get_scheme
    from repro.core.types import HetSpec

    K, N, T = 15, 50_000, 256
    specs = [HetSpec.uniform_random(K, mu, mu * mu / 6,
                                    np.random.default_rng(s))
             for s, mu in enumerate((10.0, 20.0))]
    out = {}
    for name in sorted(SAMPLER_BACKENDS):
        if not get_backend(name).available():
            continue
        single = get_scheme("work_exchange").mc_grid(
            specs, N, T, np.random.default_rng(5), backend=name,
            keep_trials=True)
        with grid_sharding(4):
            assert active_grid_mesh() is not None
            shard = get_scheme("work_exchange").mc_grid(
                specs, N, T, np.random.default_rng(5), backend=name,
                keep_trials=True)
        rows = []
        for a, b in zip(single, shard):
            se = float(np.hypot(a.t_comp_std, b.t_comp_std) / np.sqrt(T))
            rows.append({
                "single": a.t_comp, "sharded": b.t_comp, "se": se,
                "bitwise": bool(np.array_equal(a.t_comp_trials,
                                               b.t_comp_trials)),
            })
        out[name] = rows
    json.dump(out, sys.stdout)
""")


class TestShardedEquivalence:
    """Acceptance: 4-device sharded execution agrees with single-device
    at 6 combined standard errors, under list(SAMPLER_BACKENDS).

    Runs in a subprocess because simulated host devices require XLA_FLAGS
    before the first jax import, and the main pytest process has already
    imported jax on one device.
    """

    @pytest.fixture(scope="class")
    def verdicts(self):
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env.pop("REPRO_SAMPLER_BACKEND", None)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", SHARDED_PROBE],
                              capture_output=True, text=True, env=env,
                              timeout=900)
        assert proc.returncode == 0, proc.stderr[-4000:]
        return json.loads(proc.stdout)

    def test_numpy_oracle_is_untouched_by_sharding(self, verdicts):
        for row in verdicts["numpy"]:
            assert row["bitwise"], row

    def test_backends_agree_at_six_se(self, verdicts):
        assert set(verdicts) >= {"numpy"}
        for name, rows in verdicts.items():
            for row in rows:
                drift = abs(row["single"] - row["sharded"])
                assert drift < 6.0 * row["se"] + 1e-12, (name, row)

    def test_sharded_backends_actually_resharded(self, verdicts):
        # jax/pallas shard with fresh per-device key streams: identical
        # trial vectors would mean the mesh was silently ignored
        for name in ("jax", "pallas"):
            if name in verdicts:
                assert not all(r["bitwise"] for r in verdicts[name]), name


SHARDED_PANEL_PROBE = textwrap.dedent("""
    import os, json, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.experiments import (ExperimentSpec, ScenarioGrid,
                                   compile_plan, run_experiment,
                                   scheme_spec)

    def make(devices):
        return ExperimentSpec(
            name="shard-fused",
            grid=ScenarioGrid(K=8, points=[(24.0, 24.0**2/8, 3),
                                           (24.0, 24.0**2/8, 6)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     scheme_spec("fixed")),
            N=200, trials=64, seed=5, backend="pallas",
            devices=devices, panel="fused")

    plan = compile_plan(make(4))
    assert plan.devices == 4, plan.devices      # fused pin lifted
    r1, r4 = run_experiment(make(1)), run_experiment(make(4))
    rows = []
    for k in r1.keys():
        for a, b in zip(r1.report(k), r4.report(k)):
            rows.append({"key": k, "single": a.t_comp,
                         "sharded": b.t_comp, "std": a.t_comp_std})
    json.dump(rows, sys.stdout)
""")


class TestShardedFusedPanel:
    """The fused known/unknown WE panel over a 4-device mesh: the
    stacked mixed-mode rows (per-row known flags) shard like any other
    batch, and must agree with the single-device launch at 6 SE."""

    @pytest.fixture(scope="class")
    def rows(self):
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env.pop("REPRO_SAMPLER_BACKEND", None)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", SHARDED_PANEL_PROBE],
                              capture_output=True, text=True, env=env,
                              timeout=900)
        assert proc.returncode == 0, proc.stderr[-4000:]
        return json.loads(proc.stdout)

    def test_sharded_fused_panel_agrees_at_six_se(self, rows):
        assert len(rows) == 6                   # 3 schemes x 2 points
        for row in rows:
            se = max(row["std"] / np.sqrt(64), 1e-9)
            drift = abs(row["single"] - row["sharded"])
            assert drift < 6.0 * se + 1e-12, row
