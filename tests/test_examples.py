"""Examples and launchers stay runnable (subprocess smoke tests)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=420):
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, timeout=timeout, cwd=REPO, env=ENV)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "oracle lower bound" in out
    assert "work exchange" in out


def test_train_launcher_and_resume(tmp_path):
    ck = str(tmp_path / "ck")
    out = _run(["-m", "repro.launch.train", "--steps", "3",
                "--units", "8", "--ckpt", ck, "--save-every", "2"])
    assert "step 2" in out
    out2 = _run(["-m", "repro.launch.train", "--steps", "4",
                 "--units", "8", "--ckpt", ck, "--save-every", "2"])
    assert "resumed" in out2 and "step 3" in out2


def test_serve_launcher():
    out = _run(["-m", "repro.launch.serve", "--arch", "xlstm-350m",
                "--steps", "4", "--batch", "2"])
    assert "tok/s" in out


def test_serve_batch():
    out = _run(["examples/serve_batch.py"])
    assert "greedy, KV-cached" in out
    assert "streaming prefill batches" in out
    for policy in ("work_exchange", "work_exchange_unknown", "fixed",
                   "uniform"):
        assert f"  {policy} " in out
    assert "SLO-miss" in out


def test_paper_figures_quick(tmp_path):
    out = _run(["examples/paper_figures.py", "--quick",
                "--out", str(tmp_path)])
    assert "fig5_completion_time.csv" in out
    assert (tmp_path / "fig7_threshold.csv").exists()
