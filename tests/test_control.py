"""The live async control plane (``repro.control``).

Transport conformance over every registered transport (echo, ordering,
timeout, close), fault-injection behaviour (drops -> retries, worker
loss -> leftover reassignment, degraded completion), live-vs-MC T_comp
agreement for the exchange and coded paths, telemetry conservation, the
``LiveConfig``/``ExperimentSpec`` value discipline (spec-hash
back-compat pinned), and the generic ``Registry`` helper's regression
surface across all five plugin registries.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.control import (Comm, CommClosedError, LiveConfig, Telemetry,
                           TRANSPORT_REGISTRY, get_transport,
                           list_transports, run_live)
from repro.control.coordinator import live_supported
from repro.core.registry import Registry
from repro.core.schemes import get_scheme
from repro.core.types import HetSpec

RNG = np.random.default_rng

# constructor params that make each registered transport behave as a
# reliable channel -- what the conformance battery runs against
RELIABLE_PARAMS = {"inproc": {}, "flaky": {"drop": 0.0, "seed": 0},
                   "tcp": {}}


def small_het(K=4, seed=2):
    return HetSpec.uniform_random(K, 4.0, 4.0 ** 2 / 6.0, RNG(seed))


def quick_cfg(**kw):
    kw.setdefault("target_wall_s", 0.15)
    return LiveConfig(**kw)


def echo_handler():
    async def handle(comm: Comm):
        while True:
            try:
                msg = await comm.recv()
            except (CommClosedError, asyncio.CancelledError):
                return
            await comm.send({"echo": msg})
    return handle


# ---------------------------------------------------------------------------
# transport conformance (parametrized over the registry)
# ---------------------------------------------------------------------------

class TestTransportConformance:
    @pytest.mark.parametrize("name", list_transports())
    def test_registered_and_instantiable(self, name):
        tr = get_transport(name, **RELIABLE_PARAMS[name])
        assert tr.name == name

    @pytest.mark.parametrize("name", list_transports())
    def test_echo_round_trip(self, name):
        async def main():
            tr = get_transport(name, **RELIABLE_PARAMS[name])
            listener = tr.listen(echo_handler())
            await listener.start()
            comm = await tr.connect(listener.address)
            await comm.send({"x": 1, "payload": [1, 2, 3]})
            reply = await comm.recv(timeout=2.0)
            assert reply == {"echo": {"x": 1, "payload": [1, 2, 3]}}
            await comm.close()
            await listener.stop()
        asyncio.run(main())

    @pytest.mark.parametrize("name", list_transports())
    def test_fifo_ordering(self, name):
        async def main():
            tr = get_transport(name, **RELIABLE_PARAMS[name])
            listener = tr.listen(echo_handler())
            await listener.start()
            comm = await tr.connect(listener.address)
            for i in range(20):
                await comm.send({"i": i})
            got = [(await comm.recv(timeout=2.0))["echo"]["i"]
                   for _ in range(20)]
            assert got == list(range(20))
            await listener.stop()
        asyncio.run(main())

    @pytest.mark.parametrize("name", list_transports())
    def test_recv_timeout(self, name):
        async def main():
            tr = get_transport(name, **RELIABLE_PARAMS[name])
            listener = tr.listen(echo_handler())
            await listener.start()
            comm = await tr.connect(listener.address)
            with pytest.raises(asyncio.TimeoutError):
                await comm.recv(timeout=0.05)
            await listener.stop()
        asyncio.run(main())

    @pytest.mark.parametrize("name", list_transports())
    def test_peer_close_raises(self, name):
        async def main():
            server_comms = []

            async def handle(comm):
                server_comms.append(comm)
                await comm.close()

            tr = get_transport(name, **RELIABLE_PARAMS[name])
            listener = tr.listen(handle)
            await listener.start()
            comm = await tr.connect(listener.address)
            with pytest.raises(CommClosedError):
                await comm.recv(timeout=2.0)
            await listener.stop()
        asyncio.run(main())

    def test_connect_unknown_address_fails(self):
        async def main():
            tr = get_transport("inproc")
            with pytest.raises(CommClosedError):
                await tr.connect("inproc://no-such-listener")
        asyncio.run(main())

    def test_flaky_latency_preserves_order(self):
        async def main():
            tr = get_transport("flaky", delay=0.001, jitter=0.002, seed=4)
            listener = tr.listen(echo_handler())
            await listener.start()
            comm = await tr.connect(listener.address)
            for i in range(10):
                await comm.send({"i": i})
            got = [(await comm.recv(timeout=5.0))["echo"]["i"]
                   for _ in range(10)]
            assert got == list(range(10))
            await listener.stop()
        asyncio.run(main())

    def test_flaky_drops_messages_after_handshake(self):
        async def main():
            tr = get_transport("flaky", drop=0.5, seed=11)
            listener = tr.listen(echo_handler())
            await listener.start()
            comm = await tr.connect(listener.address)
            for i in range(30):
                await comm.send({"i": i})
            # the client-side wrapper counts its own silent drops
            assert comm.dropped > 0
            await listener.stop()
        asyncio.run(main())

    def test_flaky_validates_params(self):
        with pytest.raises(ValueError):
            get_transport("flaky", drop=1.5)
        with pytest.raises(ValueError):
            get_transport("flaky", delay=-1.0)

    def test_get_transport_bad_param_lists_allowed(self):
        with pytest.raises(KeyError, match="bad params.*nope.*allowed"):
            get_transport("inproc", nope=1)

    def test_tcp_address_concrete_after_start(self):
        async def main():
            tr = get_transport("tcp")
            listener = tr.listen(echo_handler())
            await listener.start()
            host, _, port = listener.address[len("tcp://"):].rpartition(":")
            assert host == "127.0.0.1" and int(port) > 0
            await listener.stop()
        asyncio.run(main())

    def test_tcp_connect_dead_port_fails(self):
        async def main():
            tr = get_transport("tcp")
            listener = tr.listen(echo_handler())
            await listener.start()
            addr = listener.address
            await listener.stop()
            with pytest.raises(CommClosedError):
                await tr.connect(addr)
        asyncio.run(main())

    def test_tcp_serializes_numpy_scalars(self):
        async def main():
            tr = get_transport("tcp")
            listener = tr.listen(echo_handler())
            await listener.start()
            comm = await tr.connect(listener.address)
            await comm.send({"n": np.int64(3), "t": np.float64(0.5),
                             "v": np.arange(3)})
            reply = await comm.recv(timeout=2.0)
            assert reply == {"echo": {"n": 3, "t": 0.5, "v": [0, 1, 2]}}
            await comm.close()
            await listener.stop()
        asyncio.run(main())

    def test_flaky_composes_over_tcp(self):
        async def main():
            tr = get_transport("flaky", inner="tcp", delay=0.001, seed=4)
            listener = tr.listen(echo_handler())
            await listener.start()
            assert listener.address.startswith("tcp://127.0.0.1:")
            comm = await tr.connect(listener.address)
            for i in range(5):
                await comm.send({"i": i})
            got = [(await comm.recv(timeout=5.0))["echo"]["i"]
                   for _ in range(5)]
            assert got == list(range(5))
            await listener.stop()
        asyncio.run(main())


# ---------------------------------------------------------------------------
# live execution: agreement with MC, faults, conservation
# ---------------------------------------------------------------------------

def assert_ledger_conserves(rep):
    led = rep.extra["control_plane"]["ledger"]
    assert led["units_dispatched"] == (led["units_completed"]
                                       + led["units_reassigned"])
    return led


class TestLiveExecution:
    def test_work_exchange_live_matches_mc(self):
        het, N = small_het(), 800
        rep = run_live("work_exchange", {}, het, N, quick_cfg(), trials=3,
                       seed=7)
        mc = get_scheme("work_exchange").mc(het, N, 400, RNG(0))
        se = np.hypot(rep.t_comp_std / np.sqrt(3), mc.t_comp_std / 20.0)
        # generous band: 3 live episodes against 400 MC trials
        assert abs(rep.t_comp - mc.t_comp) < max(8.0 * se, 0.25 * mc.t_comp)
        led = assert_ledger_conserves(rep)
        assert led["units_completed"] == 3 * N
        assert rep.iterations >= 2          # it actually exchanged

    def test_fixed_live_matches_mc(self):
        het, N = small_het(), 800
        rep = run_live("fixed", {}, het, N, quick_cfg(), trials=3, seed=7)
        mc = get_scheme("fixed").mc(het, N, 400, RNG(0))
        se = np.hypot(rep.t_comp_std / np.sqrt(3), mc.t_comp_std / 20.0)
        assert abs(rep.t_comp - mc.t_comp) < max(8.0 * se, 0.25 * mc.t_comp)
        led = assert_ledger_conserves(rep)
        assert led["units_reassigned"] == 0     # single wait-all round
        assert rep.iterations == 1

    def test_coded_path_runs_mds_and_hedged(self):
        het, N = small_het(), 600
        for name, params in (("mds", {"L": 3}), ("hedged", {})):
            rep = run_live(name, params, het, N, quick_cfg(), trials=2,
                           seed=5)
            assert rep.t_comp > 0 and rep.iterations == 1
            led = assert_ledger_conserves(rep)
            # redundant schemes ship more than N units
            assert led["units_dispatched"] > 2 * N
            assert rep.n_comm == float(
                get_scheme(name, **params).initial_sizes(het, N).sum() - N)

    def test_live_unsupported_scheme_fails_fast(self):
        for name in ("oracle", "gradient_coded"):
            with pytest.raises(ValueError, match="cannot run live"):
                live_supported(get_scheme(name))
        assert live_supported(get_scheme("work_exchange")) == "exchange"
        assert live_supported(get_scheme("mds")) == "coded"

    def test_injected_drops_trigger_retries_and_still_complete(self):
        het, N = small_het(), 500
        cfg = quick_cfg(transport="flaky",
                        transport_params={"drop": 0.2, "seed": 3},
                        timeout_s=0.1, retries=4)
        rep = run_live("work_exchange", {}, het, N, cfg, trials=1, seed=2)
        cp = rep.extra["control_plane"]
        assert cp["timeline"]["counters"].get("rpc_retries", 0) > 0
        led = assert_ledger_conserves(rep)
        assert led["units_completed"] == N      # complete despite loss
        assert rep.t_comp > 0

    def test_worker_loss_reassigns_leftovers(self):
        het, N = small_het(), 800
        cfg = quick_cfg(target_wall_s=0.3, timeout_s=0.05, retries=1,
                        kill_worker=0, kill_after_frac=0.2)
        rep = run_live("work_exchange", {}, het, N, cfg, trials=1, seed=4)
        cp = rep.extra["control_plane"]
        assert cp["workers_lost"] == [0]
        led = assert_ledger_conserves(rep)
        assert led["units_completed"] == N      # degraded, not hung
        assert led["units_reassigned"] > 0      # the dead worker's units
        # degraded: measured T_comp above the no-fault run's
        base = run_live("work_exchange", {}, het, N,
                        quick_cfg(target_wall_s=0.3), trials=1, seed=4)
        assert rep.t_comp > base.t_comp

    def test_occupancy_tracks_rates(self):
        het = HetSpec(np.array([1.0, 4.0]))
        rep = run_live("fixed", {}, het, 400, quick_cfg(), trials=1,
                       seed=9)
        occ = rep.extra["control_plane"]["timeline"]["occupancy"]
        # the 4x-faster worker pushes ~4x the units through its shard
        thr0 = occ["0"]["throughput_units_per_s"]
        thr1 = occ["1"]["throughput_units_per_s"]
        assert thr1 > 2.0 * thr0
        assert occ["0"]["units_done"] + occ["1"]["units_done"] == 400

    def test_timeline_is_json_safe(self):
        rep = run_live("work_exchange", {}, small_het(), 400, quick_cfg(),
                       trials=1, seed=1)
        json.dumps(rep.extra["control_plane"])   # must not raise


class TestTelemetry:
    def test_spans_counters_events(self):
        tel = Telemetry(max_events=3)
        tel.start()
        tel.count("units_dispatched", 10)
        tel.count("units_dispatched", 5)
        for i in range(5):
            tel.event("e", i=i)
        tel.span_open(0, "busy")
        tel.span_close(0, units=7)
        d = tel.to_dict()
        assert d["counters"]["units_dispatched"] == 15
        assert len(d["events"]) == 3 and d["n_events"] == 5  # capped
        assert d["occupancy"]["0"]["units_done"] == 7
        assert d["occupancy"]["0"]["busy_s"] >= 0.0

    def test_span_open_closes_previous(self):
        tel = Telemetry()
        tel.start()
        tel.span_open(1, "busy")
        tel.span_open(1, "idle")     # implicitly closes the busy span
        tel.close_all()
        states = [s["state"] for s in tel.spans[1]]
        assert states == ["busy", "idle"]


# ---------------------------------------------------------------------------
# LiveConfig value discipline
# ---------------------------------------------------------------------------

class TestLiveConfig:
    def test_round_trip(self):
        cfg = LiveConfig(transport="flaky",
                         transport_params={"drop": 0.1, "seed": 5},
                         target_wall_s=0.25, kill_worker=1)
        again = LiveConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
        assert again == cfg

    def test_unknown_transport_fails_at_construction(self):
        with pytest.raises(KeyError, match="unknown transport"):
            LiveConfig(transport="carrier_pigeon")

    def test_bad_transport_params_fail_at_construction(self):
        with pytest.raises(KeyError, match="bad params"):
            LiveConfig(transport="inproc", transport_params={"nope": 1})

    def test_validation(self):
        with pytest.raises(ValueError):
            LiveConfig(target_wall_s=0.0)
        with pytest.raises(ValueError):
            LiveConfig(time_scale=-1.0)
        with pytest.raises(ValueError):
            LiveConfig(retries=-1)
        with pytest.raises(ValueError):
            LiveConfig(backoff=0.5)
        with pytest.raises(ValueError):
            LiveConfig(kill_after_frac=0.0)

    def test_from_dict_rejects_unknown_keys(self):
        with pytest.raises(KeyError, match="unknown live key"):
            LiveConfig.from_dict({"transport": "inproc", "wat": 1})

    def test_resolve_time_scale(self):
        assert LiveConfig(time_scale=2.0).resolve_time_scale(100.0) == 2.0
        auto = LiveConfig(target_wall_s=0.5).resolve_time_scale(100.0)
        assert auto == pytest.approx(0.005)


# ---------------------------------------------------------------------------
# Experiment API integration (satellite 1)
# ---------------------------------------------------------------------------

def live_exp_spec(**kw):
    from repro.experiments import ExperimentSpec, ScenarioGrid, scheme_spec
    kw.setdefault("execution", "live")
    kw.setdefault("live", LiveConfig(target_wall_s=0.12))
    return ExperimentSpec(
        name="live-int",
        grid=ScenarioGrid(K=3, points=[(4.0, 4.0 ** 2 / 6, 3)]),
        schemes=(scheme_spec("work_exchange"), scheme_spec("fixed")),
        N=400, trials=2, seed=21, **kw)


class TestExperimentIntegration:
    def test_spec_round_trip_and_hash(self):
        from repro.experiments import ExperimentSpec
        spec = live_exp_spec()
        again = ExperimentSpec.from_json(spec.to_json())
        assert again == spec
        assert again.spec_hash() == spec.spec_hash()
        # the live axis is part of the address
        mc = spec.replace(execution="mc", live=None)
        assert mc.spec_hash() != spec.spec_hash()

    def test_no_live_keys_preserves_pre_live_hashes(self):
        spec = live_exp_spec().replace(execution="mc", live=None)
        d = spec.to_dict()
        assert "execution" not in d and "live" not in d
        # the serialized shape is EXACTLY the pre-live one: rebuilding
        # the dict by hand reproduces the spec hash byte-for-byte
        import hashlib
        pre_live = json.dumps(d, sort_keys=True, separators=(",", ":"))
        assert spec.spec_hash() == hashlib.sha256(
            pre_live.encode()).hexdigest()

    def test_execution_live_defaults_live_config(self):
        spec = live_exp_spec(live=None)
        assert spec.live == LiveConfig()

    def test_live_and_serving_are_exclusive(self):
        from repro.serving import ServingConfig
        with pytest.raises(ValueError, match="mutually exclusive"):
            live_exp_spec(serving=ServingConfig(loads=(0.5,)))

    def test_live_requires_live_execution(self):
        with pytest.raises(ValueError, match="requires execution='live'"):
            live_exp_spec(execution="mc")

    def test_bad_execution_rejected(self):
        with pytest.raises(ValueError, match="execution must be"):
            live_exp_spec(execution="warp", live=None)

    def test_compile_plan_pins_single_device_and_validates(self):
        from repro.experiments.plan import compile_plan
        plan = compile_plan(live_exp_spec())
        assert plan.devices == 1
        from repro.experiments import scheme_spec
        bad = live_exp_spec().replace(
            schemes=(scheme_spec("gradient_coded"),))
        with pytest.raises(ValueError, match="cannot run live"):
            compile_plan(bad)

    def test_run_experiment_store_round_trip(self, tmp_path):
        from repro.experiments import run_experiment
        from repro.experiments.store import ResultsStore
        store = ResultsStore(tmp_path / "store")
        spec = live_exp_spec()
        first = run_experiment(spec, store=store)
        assert not first.cache_hit
        for key in ("work_exchange", "fixed"):
            rows = first.report(key)
            assert len(rows) == 1
            assert rows[0].extra["control_plane"]["transport"] == "inproc"
            assert_ledger_conserves(rows[0])
        second = run_experiment(spec, store=store)
        assert second.cache_hit
        assert second.to_dict()["reports"] == first.to_dict()["reports"]


# ---------------------------------------------------------------------------
# the generic Registry helper + the five migrated plugin surfaces
# ---------------------------------------------------------------------------

class TestRegistryHelper:
    def test_basic_contract(self):
        reg: Registry[int] = Registry("widget")
        reg.register("a", 1, aliases=("alpha",))
        reg.register("b", 2)
        assert reg.get("a") == reg.get("alpha") == 1
        assert reg.canonical("alpha") == "a"
        assert reg.names() == ["a", "b"]
        assert reg.names(include_aliases=True) == ["a", "b", "alpha"]
        assert "a" in reg and len(reg) == 2
        with pytest.raises(ValueError, match="already registered"):
            reg.register("alpha", 3)
        with pytest.raises(KeyError, match="unknown widget 'z'"):
            reg.get("z")
        del reg["a"]
        assert "a" not in reg and reg.canonical("alpha") == "alpha"

    def test_scheme_registry_error_text_unchanged(self):
        with pytest.raises(KeyError) as exc:
            get_scheme("definitely_missing")
        msg = str(exc.value)
        assert "unknown scheme 'definitely_missing'" in msg
        assert "work_exchange" in msg and "aliases" in msg

    def test_sampler_registry_error_text_unchanged(self):
        from repro.core.samplers import get_backend
        with pytest.raises(KeyError) as exc:
            get_backend("definitely_missing")
        assert "unknown sampler backend 'definitely_missing'" in str(
            exc.value)

    def test_scenario_registry_error_text_unchanged(self):
        from repro.scenarios import get_family
        with pytest.raises(KeyError) as exc:
            get_family("definitely_missing")
        assert "unknown scenario family 'definitely_missing'" in str(
            exc.value)

    def test_arrival_registry_error_text_unchanged(self):
        from repro.serving import get_arrival
        with pytest.raises(KeyError) as exc:
            get_arrival("definitely_missing")
        assert "unknown arrival process 'definitely_missing'" in str(
            exc.value)

    def test_transport_registry_surface(self):
        assert "inproc" in list_transports()
        assert "flaky" in list_transports()
        assert "faulty" in list_transports(include_aliases=True)
        assert (TRANSPORT_REGISTRY.get("faulty")
                is TRANSPORT_REGISTRY.get("flaky"))
        with pytest.raises(KeyError) as exc:
            get_transport("definitely_missing")
        assert "unknown transport 'definitely_missing'" in str(exc.value)

    def test_all_five_registries_round_trip(self):
        from repro.core.samplers import SAMPLER_BACKENDS
        from repro.core.schemes import SCHEME_REGISTRY
        from repro.scenarios.base import SCENARIO_REGISTRY
        from repro.serving.arrivals import ARRIVAL_REGISTRY
        for reg, key in ((SCHEME_REGISTRY, "work_exchange"),
                         (SAMPLER_BACKENDS, "numpy"),
                         (SCENARIO_REGISTRY, "uniform_random"),
                         (ARRIVAL_REGISTRY, "poisson"),
                         (TRANSPORT_REGISTRY, "inproc")):
            assert isinstance(reg, Registry)
            assert key in reg.names()
            assert reg.get(key) is reg[key]


class TestTimelineFigure:
    """The occupancy-timeline figure over telemetry spans."""

    SPANS = {"0": [{"t0": 0.0, "t1": 0.6, "state": "busy", "units": 3},
                   {"t0": 0.6, "t1": 1.0, "state": "idle"}],
             "1": [{"t0": 0.0, "t1": 1.0, "state": "busy", "units": 5}]}

    def test_renders_span_rows(self):
        from benchmarks.fig_timeline import render_timeline
        out = render_timeline({"spans": self.SPANS}, width=10)
        lines = out.splitlines()
        assert "spans" in lines[0]
        w0 = next(ln for ln in lines if ln.strip().startswith("w0"))
        w1 = next(ln for ln in lines if ln.strip().startswith("w1"))
        # worker 0: 60% busy then idle; worker 1: solid busy
        assert "######...." in w0 and "busy  60.0%" in w0
        assert "units 3" in w0
        assert "##########" in w1 and "busy 100.0%" in w1

    def test_occupancy_fallback_for_pre_span_records(self):
        from benchmarks.fig_timeline import render_timeline
        out = render_timeline(
            {"occupancy": {"0": {"busy_s": 0.25, "idle_s": 0.75,
                                 "units_done": 2}}}, width=8)
        assert "occupancy summary" in out
        assert "##......" in out and "busy  25.0%" in out

    def test_accepts_control_plane_wrapper_and_empty(self):
        from benchmarks.fig_timeline import render_timeline
        wrapped = render_timeline({"timeline": {"spans": self.SPANS}},
                                  width=10)
        assert "w1" in wrapped
        assert "no worker telemetry" in render_timeline({})

    def test_live_episode_renders(self):
        from benchmarks.fig_timeline import render_report
        het = HetSpec.uniform_random(3, 4.0, 4.0 ** 2 / 6,
                                     np.random.default_rng(2))
        rep = run_live("work_exchange", {}, het, N=32,
                       cfg=LiveConfig(target_wall_s=0.1), trials=1, seed=3)
        out = render_report(rep)
        assert "scheme=work_exchange" in out
        assert "#" in out          # somebody was busy
