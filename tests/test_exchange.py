"""Work-exchange protocol tests: simulator, scheduler, estimators, coding."""
import numpy as np
import pytest

from repro.core import simulator
from repro.core.assignment import (capped_proportional_assignment,
                                   largest_remainder_round,
                                   proportional_assignment)
from repro.core.coded import GradientCoding, MDSCodedMatmul
from repro.core.estimator import (CumulativeRateEstimator, EMARateEstimator,
                                  GammaPosteriorEstimator)
from repro.core.exchange import MasterScheduler
from repro.core.types import ExchangeConfig, HetSpec


RNG = lambda s=0: np.random.default_rng(s)


class TestAssignment:
    def test_largest_remainder_sums(self):
        for total in (0, 1, 7, 100, 999):
            out = largest_remainder_round(np.array([0.2, 3.0, 1.7]), total)
            assert out.sum() == total and (out >= 0).all()

    def test_proportional_matches_corollary2(self):
        lam = np.array([1.0, 3.0, 6.0])
        np.testing.assert_array_equal(proportional_assignment(lam, 200),
                                      [20, 60, 120])

    def test_cap_respected_and_waterfilled(self):
        lam = np.array([1.0, 1.0, 10.0])
        out = capped_proportional_assignment(lam, 100, cap=30)
        assert out.sum() <= 100 and (out <= 30).all()
        assert out[2] == 30                 # fast worker capped
        assert out.sum() == 90              # 30+30+30: all capped, 10 carried


class TestSimulator:
    def test_work_exchange_close_to_oracle_known(self):
        het = HetSpec.uniform_random(20, mu=10.0, sigma2=10.0**2 / 6, rng=RNG(5))
        N = 20_000
        cfg = ExchangeConfig(known_heterogeneity=True)
        mc = simulator.work_exchange_mc(het, N, cfg, trials=40, rng=RNG(6))
        oracle_t = N / het.lambda_sum
        assert mc.t_comp == pytest.approx(oracle_t, rel=0.03)

    def test_work_exchange_close_to_oracle_unknown(self):
        het = HetSpec.uniform_random(20, mu=10.0, sigma2=10.0**2 / 6, rng=RNG(7))
        N = 20_000
        cfg = ExchangeConfig(known_heterogeneity=False)
        mc = simulator.work_exchange_mc(het, N, cfg, trials=40, rng=RNG(8))
        oracle_t = N / het.lambda_sum
        assert mc.t_comp == pytest.approx(oracle_t, rel=0.06)

    def test_no_scheme_beats_oracle(self):
        het = HetSpec.uniform_random(10, mu=5.0, sigma2=5.0**2 / 6, rng=RNG(9))
        N = 5_000
        oracle_t = N / het.lambda_sum
        cfg = ExchangeConfig(known_heterogeneity=True)
        mc = simulator.work_exchange_mc(het, N, cfg, trials=60, rng=RNG(10))
        assert mc.t_comp >= oracle_t * 0.999
        fixed = simulator.fixed_mean_time(het, N, 200, RNG(11))
        assert fixed >= oracle_t
        _, mds_t = simulator.mds_optimize(het, N, 200, RNG(12))
        assert mds_t >= oracle_t * 0.999

    def test_known_het_near_zero_comm(self):
        """Paper Fig 6a: with heterogeneity knowledge, N_comm ~ 0."""
        het = HetSpec.uniform_random(20, mu=10.0, sigma2=10.0, rng=RNG(13))
        N = 50_000
        cfg = ExchangeConfig(known_heterogeneity=True)
        mc = simulator.work_exchange_mc(het, N, cfg, trials=20, rng=RNG(14))
        assert mc.n_comm / N < 0.02

    def test_unknown_het_comm_grows_with_variance(self):
        """Paper Fig 6a: without knowledge, N_comm grows with sigma^2."""
        N, K = 30_000, 20
        cfg = ExchangeConfig(known_heterogeneity=False)
        comms = []
        for sig2 in (0.0, 16.0, 33.0):
            het = HetSpec.uniform_random(K, mu=10.0, sigma2=sig2, rng=RNG(15))
            mc = simulator.work_exchange_mc(het, N, cfg, trials=20, rng=RNG(16))
            comms.append(mc.n_comm / N)
        # eq. (19) predicts 0 at sigma^2=0 from TRUE rates; the realized
        # protocol keeps a small residual from lambda-hat sampling noise.
        assert comms[0] < 0.03
        assert comms[2] > 2 * comms[0]

    def test_homogeneous_mds_optimal_L_is_K(self):
        """Paper: sigma^2=0 => optimized MDS == oracle (L=K, no redundancy)."""
        K = 10
        het = HetSpec(np.full(K, 4.0))
        N = 10_000
        L, t = simulator.mds_optimize(het, N, 400, RNG(17))
        assert L == K
        # equality with the oracle is asymptotic: the L=K completion time is a
        # max of K Erlangs, oracle + O(1/sqrt(N/K)) fluctuation (~5% here)
        assert t == pytest.approx(N / het.lambda_sum, rel=0.08)

    def test_mds_suboptimal_at_high_variance(self):
        """Paper Fig 5: MDS degrades vs oracle at high sigma^2; WE does not."""
        het = HetSpec.uniform_random(20, mu=10.0, sigma2=10.0**2 / 6,
                                     rng=RNG(18))
        N = 20_000
        _, t_mds = simulator.mds_optimize(het, N, 200, RNG(19))
        cfg = ExchangeConfig(known_heterogeneity=True)
        t_we = simulator.work_exchange_mc(het, N, cfg, 40, RNG(20)).t_comp
        oracle_t = N / het.lambda_sum
        assert t_mds > 1.05 * oracle_t      # visible MDS gap
        assert t_we < 1.03 * oracle_t       # WE hugs the bound

    def test_threshold_tradeoff(self):
        """Paper Fig 7: larger cutting threshold => fewer iterations."""
        het = HetSpec.uniform_random(20, mu=10.0, sigma2=12.0, rng=RNG(21))
        N = 20_000
        iters = []
        for frac in (0.001, 0.01, 0.3):
            cfg = ExchangeConfig(known_heterogeneity=False, threshold_frac=frac)
            iters.append(simulator.work_exchange_mc(het, N, cfg, 20,
                                                    RNG(22)).iterations)
        assert iters[0] >= iters[1] >= iters[2]


class TestMasterScheduler:
    def _drive(self, sched, rates, seed=0):
        """Run scheduler against a virtual pool until done; return stats."""
        from repro.core.runtime import VirtualWorkerPool
        pool = VirtualWorkerPool(rates, seed=seed)
        while not sched.finished:
            a = sched.next_assignment()
            if a is None:
                break
            elapsed, done = pool.run_epoch(a)
            sched.report(done, elapsed)
        return sched

    def test_every_unit_done_exactly_once(self):
        rates = np.array([1.0, 5.0, 2.0, 9.0])
        sched = MasterScheduler(range(1000), K=4, rates=rates)
        self._drive(sched, rates)
        assert sorted(sched.done_ids) == list(range(1000))

    def test_unknown_het_learns(self):
        rates = np.array([1.0, 10.0])
        sched = MasterScheduler(range(4000), K=2, rates=None,
                                threshold_frac=0.005)
        self._drive(sched, rates, seed=3)
        est = sched.estimated_rates()
        assert est[1] / est[0] == pytest.approx(10.0, rel=0.35)

    def test_failure_reassigns(self):
        from repro.core.runtime import VirtualWorkerPool
        rates = np.array([2.0, 2.0, 2.0])
        sched = MasterScheduler(range(300), K=3, rates=rates)
        pool = VirtualWorkerPool(rates, seed=1)
        first = True
        while not sched.finished:
            a = sched.next_assignment()
            if a is None:
                break
            dead = np.array([False, False, first])   # worker 2 dies at epoch 0
            elapsed, done = pool.run_epoch(a, dead=dead)
            sched.report(done, elapsed)
            if first:
                sched.mark_failed(2)
                first = False
        assert sorted(sched.done_ids) == list(range(300))
        assert all(l.done_counts[2] == 0 for l in sched.logs[1:])


class TestEstimators:
    def test_cumulative_matches_paper_eq23(self):
        est = CumulativeRateEstimator(2)
        est.update(np.array([10, 40]), 5.0)
        est.update(np.array([20, 60]), 10.0)
        np.testing.assert_allclose(est.rates(), [2.0, 100 / 15.0])

    def test_ema_tracks_drift(self):
        est = EMARateEstimator(1, alpha=0.5)
        for _ in range(20):
            est.update(np.array([10.0]), 1.0)
        assert est.rates()[0] == pytest.approx(10.0, rel=1e-6)
        for _ in range(20):
            est.update(np.array([2.0]), 1.0)
        assert est.rates()[0] == pytest.approx(2.0, rel=1e-3)

    def test_bayes_shrinks_to_truth(self):
        est = GammaPosteriorEstimator(1, prior_rate=1.0)
        est.update(np.array([500.0]), 100.0)
        assert est.rates()[0] == pytest.approx(5.0, rel=0.02)

    def test_ema_silent_worker_holds_prior(self):
        # regression: a worker that has produced nothing yet must keep
        # its prior rate, not have it EMA-decayed toward zero by its own
        # silence (which starved slow-starting workers of assignments)
        est = EMARateEstimator(2, prior_rate=3.0, alpha=0.4)
        for _ in range(25):
            est.update(np.array([8.0, 0.0]), 1.0)
        assert est.rates()[0] == pytest.approx(8.0, rel=1e-3)
        assert est.rates()[1] == pytest.approx(3.0)
        # first real observation replaces the prior outright...
        est.update(np.array([0.0, 2.0]), 1.0)
        assert est.rates()[1] == pytest.approx(2.0)
        # ...and zeros AFTER first contact do decay (a stall is signal)
        est.update(np.array([0.0, 0.0]), 1.0)
        assert est.rates()[1] == pytest.approx(0.6 * 2.0)

    def test_make_estimator_unknown_kind_lists_registry(self):
        from repro.core.estimator import make_estimator
        with pytest.raises(KeyError) as ei:
            make_estimator("kalman", 4)
        msg = str(ei.value)
        assert "unknown estimator 'kalman'" in msg
        assert "'bayes', 'cumulative', 'ema'" in msg


class TestCoded:
    def test_mds_matmul_decodes_from_any_L(self):
        rng = RNG(30)
        A = rng.normal(size=(20, 7))
        x = rng.normal(size=(7,))
        code = MDSCodedMatmul(K=5, L=3)
        chunks = code.encode(A)
        replies = {k: chunks[k] @ x for k in (0, 2, 4)}   # arbitrary 3 of 5
        np.testing.assert_allclose(code.decode(replies), A @ x, rtol=1e-8)

    def test_gradient_coding_tolerates_stragglers(self):
        rng = RNG(31)
        n_units, K, s = 12, 6, 1
        unit_grads = [rng.normal(size=4) for _ in range(n_units)]
        gc = GradientCoding(K=K, s=s)
        owners = gc.assignment(n_units)
        # workers 1 and 4 straggle (s=1 per group is tolerated here since the
        # two replica groups each lose one worker but jointly cover all units)
        replies = {w: {u: unit_grads[u] for u in owners[w]}
                   for w in range(K) if w not in (1,)}
        out = gc.decode(n_units, replies)
        np.testing.assert_allclose(out, np.sum(unit_grads, axis=0), rtol=1e-9)
