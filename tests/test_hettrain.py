"""The spec-driven training subsystem (``repro.hettrain``): engine
bit-identity, the policy battery over the whole scheme registry,
spec-hash preservation, and store-addressed training studies."""
import numpy as np
import pytest

from repro.core.estimator import make_estimator
from repro.core.runtime import VirtualWorkerPool
from repro.core.schemes import get_scheme, list_schemes
from repro.core.types import HetSpec
from repro.experiments import (ExperimentSpec, ResultsStore, ScenarioGrid,
                               run_experiment, scheme_spec)
from repro.hettrain import (MIN_BUCKET, ScanGradEngine, TrainConfig,
                            bucket_units, policy_mode, run_training_grid,
                            run_virtual_step, build_scheduler)

RATES = np.array([1.0, 4.0, 2.0, 8.0])
HET = HetSpec(RATES)

SMALL = TrainConfig(steps=2)
N_STEP = 8


@pytest.fixture(scope="module")
def engine_setup():
    model, params = SMALL.build_model()
    store = SMALL.build_store()
    return model, params, store


class TestTrainConfig:
    def test_round_trip(self):
        cfg = TrainConfig(steps=5, model="small", lr=3e-3,
                          estimator="ema", target_loss=2.5)
        back = TrainConfig.from_dict(cfg.to_dict())
        assert back == cfg
        assert back.to_dict() == cfg.to_dict()

    def test_unknown_key_rejected(self):
        d = TrainConfig().to_dict()
        d["typo_knob"] = 1
        with pytest.raises(KeyError):
            TrainConfig.from_dict(d)

    def test_bad_model_and_estimator_fail_fast(self):
        with pytest.raises(ValueError):
            TrainConfig(model="gpt-7t")
        with pytest.raises(KeyError, match="psychic"):
            TrainConfig(estimator="psychic")
        with pytest.raises(ValueError):
            TrainConfig(steps=0)

    def test_training_excludes_other_execution_axes(self):
        from repro.experiments import ServingConfig
        kw = dict(name="x", grid=ScenarioGrid(K=4, points=[(4.0, 1.0, 1)]),
                  schemes=(scheme_spec("work_exchange"),), N=8, trials=2,
                  seed=1)
        with pytest.raises(ValueError, match="mutually exclusive"):
            ExperimentSpec(training=TrainConfig(), serving=ServingConfig(),
                           **kw)
        with pytest.raises(ValueError, match="fused"):
            ExperimentSpec(training=TrainConfig(), panel="fused", **kw)


class TestBucketing:
    def test_pow2_with_floor(self):
        assert [bucket_units(n) for n in (1, 3, 4, 5, 8, 9, 16, 17)] == \
            [4, 4, 4, 8, 8, 16, 16, 32]
        assert bucket_units(3, min_bucket=1) == 4
        assert bucket_units(2, min_bucket=1) == 2
        with pytest.raises(ValueError):
            bucket_units(0)

    def test_epochs_share_compiles(self, engine_setup):
        model, params, store = engine_setup
        eng = ScanGradEngine(model, store)
        for ids in ([0, 1, 2], [3, 4, 5, 6], [7], [8, 9]):
            eng.grad_sum(params, ids)
        # 1..4 units all pad to the one MIN_BUCKET shape
        assert eng.stats()["bucket_sizes"] == [MIN_BUCKET]
        assert eng.stats()["dispatches"] == 4
        assert eng.stats()["units"] == 10


class TestEngineBitIdentity:
    def test_order_invariance_bitwise(self, engine_setup):
        model, params, store = engine_setup
        eng = ScanGradEngine(model, store)
        import jax
        a, la = eng.grad_sum(params, [5, 1, 3, 7, 0, 2, 6, 4])
        b, lb = eng.grad_sum(params, list(range(8)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(x), np.asarray(y))
        assert np.array_equal(la, lb)

    def test_masked_padding_adds_exact_zero(self, engine_setup):
        model, params, store = engine_setup
        import jax
        padded = ScanGradEngine(model, store, min_bucket=4)
        exact = ScanGradEngine(model, store, min_bucket=1)
        a, _ = padded.grad_sum(params, [0, 1])    # bucket 4: 2 pad slots
        b, _ = exact.grad_sum(params, [0, 1])     # bucket 2: no padding
        assert padded.stats()["bucket_sizes"] == [4]
        assert exact.stats()["bucket_sizes"] == [2]
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(x), np.asarray(y))


class TestPolicyBattery:
    """Every registered scheme as an epoch-assignment policy."""

    @pytest.mark.parametrize("name", list_schemes())
    def test_conservation_and_seed_determinism(self, name):
        scheme = get_scheme(name)
        mode = policy_mode(scheme)
        unit_ids = list(range(16))
        if mode == "simulate":
            a = scheme.simulate(HET, 16, np.random.default_rng(3))
            b = scheme.simulate(HET, 16, np.random.default_rng(3))
            assert a.t_comp == b.t_comp and a.t_comp > 0
            return
        stats = []
        for rep in range(2):
            # fresh estimator per rep: online estimates are state, and
            # a carried-over one would (correctly) change the schedule
            estimator = (make_estimator("cumulative", HET.K)
                         if getattr(scheme, "known", True) is False
                         else None)
            pool = VirtualWorkerPool(RATES, seed=11)
            sched = build_scheduler(scheme, unit_ids, RATES,
                                    estimator=estimator,
                                    threshold_frac=0.05)
            stats.append(run_virtual_step(sched, pool, unit_ids))
        a, b = stats
        # same seed -> identical virtual time; fresh pools both times
        assert a.t_comp == b.t_comp and a.t_comp > 0
        assert a.iterations == b.iterations
        # conservation: the realized (worker, units) groups partition the
        # step's unit set -- each unit dispatched exactly once
        dispatched = sorted(u for _, us in a.groups for u in us)
        assert dispatched == unit_ids

    def test_loss_curves_bit_identical_across_schemes(self):
        curves = {}
        for name in ("work_exchange", "uniform", "gradient_coded"):
            reps = run_training_grid(name, {}, [HET], SMALL, N_STEP,
                                     trials=2, seed=5)
            curves[name] = tuple(reps[0].extra["training"]["loss_curve"])
            assert reps[0].t_comp > 0
        assert len(set(curves.values())) == 1

    def test_grid_seed_determinism(self):
        a = run_training_grid("work_exchange", {}, [HET], SMALL, N_STEP,
                              trials=2, seed=9)[0]
        b = run_training_grid("work_exchange", {}, [HET], SMALL, N_STEP,
                              trials=2, seed=9)[0]
        assert a.t_comp == b.t_comp
        assert a.extra["training"] == b.extra["training"]


class TestSpecHashPreservation:
    def test_training_key_omitted_when_absent(self):
        spec = ExperimentSpec(
            name="pre-training",
            grid=ScenarioGrid(K=4, points=[(4.0, 1.0, 1)]),
            schemes=(scheme_spec("work_exchange"),), N=8, trials=2, seed=1)
        assert "training" not in spec.to_dict()

    def test_pre_training_spec_hash_pinned(self):
        # the PR-4 literal: every stored result written before the
        # training axis existed must stay addressable
        spec = ExperimentSpec(
            name="pin-uniform",
            grid=ScenarioGrid(K=8, points=[(10.0, 10.0 ** 2 / 6, 1),
                                           (20.0, 0.0, 2)]),
            schemes=(scheme_spec("work_exchange"),),
            N=5000, trials=8, seed=42, backend="numpy", devices=1)
        assert spec.spec_hash() == (
            "5a1f47511f756d8832ec4d975a58a840"
            "d31fdba8c55412fde64066b0a98e06e0")

    def test_training_spec_round_trips(self):
        spec = ExperimentSpec(
            name="train-rt",
            grid=ScenarioGrid(K=4, points=[(4.0, 1.0, 1)]),
            schemes=(scheme_spec("work_exchange"),), N=8, trials=2, seed=1,
            training=TrainConfig(steps=3, target_loss=3.0))
        back = ExperimentSpec.from_dict(spec.to_dict())
        assert back.training == spec.training
        assert back.spec_hash() == spec.spec_hash()


class TestStoreAddressedTraining:
    def _spec(self):
        return ExperimentSpec(
            name="train-store",
            grid=ScenarioGrid(K=4, points=[(4.0, 4.0 ** 2 / 6, 11)]),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("uniform")), N=N_STEP, trials=2,
            seed=77, training=SMALL)

    def test_miss_then_hit_with_loss_rows(self, tmp_path):
        store = ResultsStore(tmp_path / "store")
        first = run_experiment(self._spec(), store=store)
        assert not first.cache_hit
        again = run_experiment(self._spec(), store=store)
        assert again.cache_hit
        for name in again.keys():
            (rep,) = again.report(name)
            tr = rep.extra["training"]
            assert len(tr["loss_curve"]) == SMALL.steps
            assert all(isinstance(x, float) for x in tr["loss_curve"])
            assert tr["final_loss"] == tr["loss_curve"][-1]
            assert len(tr["t_comp_per_step"]) == SMALL.steps
            assert 0.0 <= tr["straggler_wait_frac"] <= 1.0
        we = again.report("work_exchange")[0]
        un = again.report("uniform")[0]
        assert we.extra["training"]["loss_curve"] == \
            un.extra["training"]["loss_curve"]
