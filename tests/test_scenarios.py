"""Scenario-family subsystem: registry contract (round-trip + the hash
covers every knob, per registered family), PR-4 spec-hash back-compat
pins, strict unknown-key/unknown-family errors, the measured-trace
corpus loader, drifting schedules (incl. scalar-reference bit-identity
through the numpy engine), HCMM load sweeps, and the experiment-engine
integration that threads per-round schedules to the schemes."""
import json

import numpy as np
import pytest

from repro.core.schemes import get_scheme, simulate_work_exchange_scalar
from repro.core.types import ExchangeConfig, HetSpec
from repro.experiments import (ExperimentSpec, ScenarioGrid, compile_plan,
                               run_experiment, scheme_spec)
from repro.scenarios import (SCENARIO_REGISTRY, DriftingScenario,
                             ExplicitScenario, HCMMSweepScenario,
                             ScenarioFamily, TraceCorpusScenario,
                             UniformRandomScenario, get_family,
                             list_families, load_corpus, register_family,
                             scenario_from_dict)

RNG = lambda s=0: np.random.default_rng(s)  # noqa: E731

# one representative instance per registered family (a new family must
# add itself here or the registry-coverage test fails)
SAMPLES = {
    "uniform_random": UniformRandomScenario(
        K=8, points=((10.0, 10.0 ** 2 / 6, 1), (20.0, 0.0, 2))),
    "explicit": ExplicitScenario(
        explicit=(HetSpec(np.array([1.0, 2.0, 3.0])),
                  HetSpec(np.array([2.5, 2.5, 2.5])))),
    "drifting": DriftingScenario(
        K=8, points=((20.0, 20.0 ** 2 / 6, 3),), kind="ar1", rounds=12),
    "trace_corpus": TraceCorpusScenario(
        corpus="default_64x48", K=12, windows=((0, 0), (24, 16)),
        epochs=10),
    "hcmm_sweep": HCMMSweepScenario(
        K=10, mu=30.0, sigma2=30.0 ** 2 / 6, seed=3, loads=(4, 64),
        opt_trials=32),
}

# per-family knob tweaks that MUST move the serialized dict (and hence
# the spec hash): every materialization-relevant field appears here
KNOB_VARIANTS = {
    "uniform_random": [dict(K=9), dict(points=((10.0, 5.0, 1),))],
    "explicit": [dict(explicit=(HetSpec(np.array([1.0, 2.0, 3.5])),))],
    "drifting": [dict(K=9), dict(points=((21.0, 0.0, 3),)),
                 dict(kind="regime"), dict(rounds=13), dict(rho=0.5),
                 dict(drift_sigma=0.3), dict(regime_prob=0.2),
                 dict(regime_scale=0.9), dict(recover_prob=0.5)],
    "trace_corpus": [dict(corpus="other_corpus"), dict(K=13),
                     dict(windows=((1, 0),)), dict(epochs=11)],
    "hcmm_sweep": [dict(K=11), dict(mu=31.0), dict(sigma2=100.0),
                   dict(seed=4), dict(loads=(8, 64)),
                   dict(redundancies=(1.0, 1.5)), dict(opt_trials=33)],
}


def canon(fam: ScenarioFamily) -> str:
    return json.dumps(fam.to_dict(), sort_keys=True)


class TestRegistry:
    def test_all_families_registered(self):
        assert list_families() == sorted(
            ("uniform_random", "explicit", "drifting", "trace_corpus",
             "hcmm_sweep"))

    def test_samples_cover_the_registry(self):
        assert set(SAMPLES) == set(SCENARIO_REGISTRY)
        assert set(KNOB_VARIANTS) == set(SCENARIO_REGISTRY)

    def test_get_family_unknown_raises(self):
        with pytest.raises(KeyError, match="registered|have"):
            get_family("nope")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_family("drifting")(DriftingScenario)


@pytest.mark.parametrize("name", sorted(SAMPLES))
class TestFamilyContract:
    """The per-family value contract, over every registered family."""

    def test_round_trip_lossless(self, name):
        fam = SAMPLES[name]
        back = scenario_from_dict(json.loads(json.dumps(fam.to_dict())))
        assert back == fam
        assert back.to_dict() == fam.to_dict()
        assert type(back) is type(fam)

    def test_specs_deterministic_value(self, name):
        fam = SAMPLES[name]
        if name == "trace_corpus" and fam.corpus == "other_corpus":
            pytest.skip("needs the committed corpus")
        a, b = fam.specs(), fam.specs()
        assert a == b
        assert len(fam) == len(a) > 0
        assert all(h.K == fam.K for h in a)
        sched = fam.rate_schedules()
        if sched is not None:
            assert sched.shape[0] == len(fam)
            assert sched.shape[2] == fam.K
            assert (sched > 0).all()
            np.testing.assert_array_equal(sched, fam.rate_schedules())

    def test_hash_covers_every_knob(self, name):
        base = SAMPLES[name]
        seen = {canon(base)}
        for changes in KNOB_VARIANTS[name]:
            variant = type(base)(**{**_fields(base), **changes})
            c = canon(variant)
            assert c not in seen, (name, changes)
            seen.add(c)

    def test_unknown_key_raises_keyerror(self, name):
        d = dict(SAMPLES[name].to_dict())
        d["bogus_knob"] = 1
        with pytest.raises(KeyError, match="bogus_knob"):
            scenario_from_dict(d)


def _fields(fam):
    import dataclasses
    return {f.name: getattr(fam, f.name)
            for f in dataclasses.fields(fam)}


class TestBackCompat:
    """PR-4 specs keep their hashes and store addresses (acceptance)."""

    def test_uniform_random_spec_hash_pinned(self):
        spec = ExperimentSpec(
            name="pin-uniform",
            grid=ScenarioGrid(K=8, points=[(10.0, 10.0 ** 2 / 6, 1),
                                           (20.0, 0.0, 2)]),
            schemes=(scheme_spec("work_exchange"),),
            N=5000, trials=8, seed=42, backend="numpy", devices=1)
        # literal PR-4 hash: a change here orphans every stored result
        assert spec.spec_hash() == (
            "5a1f47511f756d8832ec4d975a58a840"
            "d31fdba8c55412fde64066b0a98e06e0")

    def test_explicit_spec_hash_pinned(self):
        spec = ExperimentSpec(
            name="pin-explicit",
            grid=ScenarioGrid(explicit=(HetSpec(np.array([1.0, 2.0, 3.0])),
                                        HetSpec(np.array([2.5, 2.5,
                                                          2.5])))),
            schemes=(scheme_spec("hedged"),),
            N=2000, trials=4, seed=7, backend="numpy", devices=1)
        assert spec.spec_hash() == (
            "237e6cf1ca324c4e1ce41938893e79b9"
            "8f59e2c928ac5c21b45eb0c338bbd2f8")

    def test_committed_store_entries_still_addressable(self):
        from repro.experiments import default_store
        store = default_store()
        entries = store.entries()
        assert entries, "committed results/store entries missing"
        for h in entries:
            result = store.get(h)
            assert result is not None, h
            assert result.spec.spec_hash() == h

    def test_facade_builds_registered_families(self):
        g = ScenarioGrid(K=4, points=[(10.0, 0.0, 1)])
        assert isinstance(g, UniformRandomScenario)
        e = ScenarioGrid(explicit=(HetSpec(np.array([1.0])),))
        assert isinstance(e, ExplicitScenario)
        with pytest.raises(ValueError, match="exactly one"):
            ScenarioGrid(K=4)
        with pytest.raises(ValueError, match="exactly one"):
            ScenarioGrid(K=4, points=[(1.0, 0.0, 1)],
                         explicit=(HetSpec(np.array([1.0])),))

    def test_legacy_dict_shapes_still_deserialize(self):
        u = ScenarioGrid.from_dict({"K": 4, "points": [[10.0, 0.0, 1]]})
        assert isinstance(u, UniformRandomScenario)
        e = ScenarioGrid.from_dict({"explicit": [{"lambdas": [1.0, 2.0]}]})
        assert isinstance(e, ExplicitScenario)


class TestStrictKeys:
    """Satellite: unknown scenario/family keys raise KeyError listing
    the registered families (the validate_backend behaviour)."""

    def test_legacy_shape_with_extra_key_raises(self):
        # PR-4 ScenarioGrid silently swallowed extra keys; now: KeyError
        with pytest.raises(KeyError) as ei:
            ScenarioGrid.from_dict({"K": 4, "points": [[10.0, 0.0, 1]],
                                    "bogus": 1})
        msg = str(ei.value)
        assert "bogus" in msg and "uniform_random" in msg

    def test_unknown_family_lists_registered(self):
        with pytest.raises(KeyError) as ei:
            scenario_from_dict({"family": "no_such_family"})
        assert "drifting" in str(ei.value)

    def test_shapeless_dict_lists_registered(self):
        with pytest.raises(KeyError) as ei:
            scenario_from_dict({"Ks": 4})
        assert "trace_corpus" in str(ei.value)

    def test_spec_from_dict_propagates(self):
        spec = ExperimentSpec(
            name="x", grid=ScenarioGrid(K=4, points=[(10.0, 0.0, 1)]),
            schemes=(scheme_spec("fixed"),), N=100, trials=2)
        d = spec.to_dict()
        d["grid"]["mystery"] = True
        with pytest.raises(KeyError, match="mystery"):
            ExperimentSpec.from_dict(d)


class TestTraceCorpus:
    def test_loader_and_window_wrapping(self):
        c = load_corpus("default_64x48")
        assert c.rates.shape == (64, 48)
        assert (c.rates > 0).all()
        w = c.window(K=8, worker_offset=60, epoch_start=44, epochs=10)
        assert w.shape == (8, 10)
        # wrapped rows/cols come from the same matrix
        np.testing.assert_array_equal(w[0, :4], c.rates[60, 44:48])
        np.testing.assert_array_equal(w[4:], c.window(8, 60, 44, 10)[4:])
        np.testing.assert_array_equal(c.window(8, 64, 0, 48),
                                      c.rates[:8])   # offsets wrap too

    def test_missing_corpus_raises(self):
        with pytest.raises(FileNotFoundError, match="no_such_corpus"):
            load_corpus("no_such_corpus")

    def test_nominal_is_window_mean_and_schedule_is_window(self):
        fam = SAMPLES["trace_corpus"]
        c = load_corpus(fam.corpus)
        for g, (w, e) in enumerate(fam.windows):
            win = c.window(fam.K, w, e, fam.epochs)
            np.testing.assert_allclose(fam.specs()[g].lambdas,
                                       win.mean(axis=1))
            np.testing.assert_array_equal(fam.rate_schedules()[g], win.T)

    def test_trace_replay_scheme_replays_the_same_window(self):
        fam = SAMPLES["trace_corpus"]
        params = fam.trace_replay_params(0)
        scheme = get_scheme("trace_replay", **params)
        het = fam.specs()[0]
        np.testing.assert_array_equal(
            scheme._traces_for(het),
            load_corpus(fam.corpus).window(fam.K, *fam.windows[0],
                                           fam.epochs))
        stats = scheme.simulate(het, 2_000, RNG(1))
        stats.check_work_conserved(2_000)

    def test_trace_replay_synthetic_fallback_unchanged(self):
        # no corpus, no traces: the PR-1 synthetic drift profile
        het = HetSpec.uniform_random(6, 20.0, 10.0, RNG(2))
        scheme = get_scheme("trace_replay")
        prof = scheme._traces_for(het)
        assert prof.shape == (6, scheme.period)
        stats = scheme.simulate(het, 1_000, RNG(3))
        stats.check_work_conserved(1_000)


class TestDrifting:
    def test_round0_is_nominal(self):
        for kind in ("ar1", "regime"):
            fam = DriftingScenario(K=8, points=((20.0, 20.0 ** 2 / 6, 3),),
                                   kind=kind, rounds=6)
            np.testing.assert_allclose(
                fam.rate_schedules()[:, 0, :],
                np.stack([h.lambdas for h in fam.specs()]))

    def test_regime_switching_hits_the_throttled_state(self):
        fam = DriftingScenario(K=16, points=((20.0, 0.0, 5),),
                               kind="regime", rounds=40, regime_prob=0.3,
                               regime_scale=0.5)
        sched = fam.rate_schedules()[0]
        base = fam.specs()[0].lambdas
        ratio = sched / base[None, :]
        assert set(np.round(np.unique(ratio), 6)) <= {0.5, 1.0}
        assert (ratio == 0.5).any() and (ratio == 1.0).any()

    def test_invalid_knobs_rejected(self):
        good = dict(K=4, points=((10.0, 0.0, 1),))
        with pytest.raises(ValueError, match="kind"):
            DriftingScenario(kind="brownian", **good)
        with pytest.raises(ValueError, match="rounds"):
            DriftingScenario(rounds=0, **good)
        with pytest.raises(ValueError, match="rho"):
            DriftingScenario(rho=1.0, **good)

    def test_scalar_reference_bit_identical_to_batched_numpy(self):
        """The exact scalar drift path == the batched numpy engine at
        trials=1 (same stream), for both WE variants."""
        fam = SAMPLES["drifting"]
        het = fam.specs()[0]
        sched = fam.rate_schedules()[0]
        for name, known in (("work_exchange", True),
                            ("work_exchange_unknown", False)):
            cfg = ExchangeConfig(known_heterogeneity=known)
            ref = simulate_work_exchange_scalar(het, 10_000, cfg, RNG(7),
                                                rate_schedule=sched)
            rep = get_scheme(name).mc(het, 10_000, 1, RNG(7),
                                      keep_trials=True,
                                      rate_schedule=sched)
            assert rep.t_comp_trials[0] == ref.t_comp, name
            assert rep.iterations_trials[0] == ref.iterations
            assert rep.n_comm_trials[0] == ref.n_comm

    def test_drift_changes_the_numbers(self):
        het = HetSpec.uniform_random(8, 20.0, 20.0 ** 2 / 6, RNG(3))
        # nominal round 0, then the whole cluster throttled to 40%
        sched = np.concatenate([het.lambdas[None, :],
                                np.repeat(het.lambdas[None, :] * 0.4, 23,
                                          axis=0)])
        still = get_scheme("work_exchange").mc(het, 20_000, 64, RNG(9))
        drift = get_scheme("work_exchange").mc(het, 20_000, 64, RNG(9),
                                               rate_schedule=sched)
        # heavy throttling must slow completion beyond MC noise
        assert drift.t_comp > still.t_comp + 4 * still.t_comp_std

    def test_loop_engine_accepts_schedules(self):
        fam = SAMPLES["drifting"]
        het = fam.specs()[0]
        sched = fam.rate_schedules()
        rep = get_scheme("work_exchange", engine="loop").mc_grid(
            [het], 5_000, 2, RNG(1), rate_schedule=sched)
        assert rep[0].trials == 2


class TestHCMMSweep:
    def test_operating_points_move_with_load(self):
        fam = HCMMSweepScenario(K=20, mu=30.0, sigma2=30.0 ** 2 / 6,
                                seed=3, loads=(4, 256), opt_trials=96)
        (het_a, n_a, r_a), (het_b, n_b, r_b) = fam.operating_points()
        assert n_a == 4 * 20 and n_b == 256 * 20
        # light per-worker loads want redundancy; heavy loads don't
        assert r_a > 1.0
        assert r_b <= r_a
        assert fam.het_mds_params(0) == {"redundancy": r_a}

    def test_points_are_independent_draws(self):
        fam = SAMPLES["hcmm_sweep"]
        specs = fam.specs()
        assert specs[0] != specs[1]
        # derived seeds: adding a load point never perturbs the others
        wider = HCMMSweepScenario(**{**_fields(fam),
                                     "loads": fam.loads + (1024,)})
        assert wider.specs()[:2] == specs

    def test_validation(self):
        good = dict(K=4, mu=10.0, sigma2=0.0, seed=1)
        with pytest.raises(ValueError, match="redundancy"):
            HCMMSweepScenario(redundancies=(0.9,), **good)
        with pytest.raises(ValueError, match="loads"):
            HCMMSweepScenario(loads=(), **good)


class TestEngineIntegration:
    """Schedules thread spec -> plan -> engine -> schemes."""

    def drift_spec(self, **overrides):
        base = dict(
            name="drift-int",
            grid=DriftingScenario(K=8, points=((20.0, 20.0 ** 2 / 6, 3),
                                               (40.0, 0.0, 4)),
                                  rounds=12),
            schemes=(scheme_spec("work_exchange"),
                     scheme_spec("work_exchange_unknown"),
                     scheme_spec("hedged")),
            N=5_000, trials=8, seed=42)
        base.update(overrides)
        return ExperimentSpec(**base)

    def test_plan_carries_schedules(self):
        plan = compile_plan(self.drift_spec())
        assert plan.rate_schedules is not None
        assert plan.rate_schedules.shape == (2, 12, 8)
        # stationary grids carry none
        plain = compile_plan(ExperimentSpec(
            name="s", grid=ScenarioGrid(K=4, points=[(10.0, 0.0, 1)]),
            schemes=(scheme_spec("fixed"),), N=100, trials=2))
        assert plain.rate_schedules is None

    def test_engine_matches_direct_mc_grid_with_schedule(self):
        spec = self.drift_spec()
        result = run_experiment(spec)
        fam = spec.grid
        direct = get_scheme("work_exchange").mc_grid(
            fam.specs(), spec.N, trials=spec.trials, rng=RNG(42),
            rate_schedule=fam.rate_schedules())
        assert [r.t_comp for r in result.report("work_exchange")] == \
            [r.t_comp for r in direct]

    def test_schedule_reaches_only_schedule_aware_schemes(self):
        # hedged (single-shot) must run exactly as without a schedule
        spec = self.drift_spec()
        result = run_experiment(spec)
        fam = spec.grid
        direct = get_scheme("hedged").mc_grid(
            fam.specs(), spec.N, trials=spec.trials, rng=RNG(42))
        assert [r.t_comp for r in result.report("hedged")] == \
            [r.t_comp for r in direct]

    def test_store_round_trip(self, tmp_path):
        from repro.experiments import ResultsStore
        store = ResultsStore(tmp_path)
        spec = self.drift_spec()
        first = run_experiment(spec, store=store)
        assert not first.cache_hit
        second = run_experiment(spec, store=store)
        assert second.cache_hit
        assert second.to_dict()["reports"] == first.to_dict()["reports"]

    def test_trace_corpus_spec_end_to_end(self, tmp_path):
        from repro.experiments import ResultsStore
        grid = SAMPLES["trace_corpus"]
        spec = ExperimentSpec(
            name="trace-int", grid=grid,
            schemes=(scheme_spec("work_exchange_unknown"),
                     scheme_spec("trace_replay", key="replay",
                                 **grid.trace_replay_params(0))),
            N=2_000, trials=4, seed=7)
        result = run_experiment(spec, store=ResultsStore(tmp_path))
        assert len(result.report("work_exchange_unknown")) == len(grid)
        assert run_experiment(spec,
                              store=ResultsStore(tmp_path)).cache_hit
