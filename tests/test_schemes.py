"""Unified Scheme API: registry round-trip, shim equivalence, seed-for-seed
validation of the vectorized MC engine, and uniform invariants over every
registered scheme."""
import time
import warnings

import numpy as np
import pytest

from repro.core import simulator
from repro.core.assignment import (capped_proportional_assignment,
                                   capped_proportional_assignment_batch,
                                   largest_remainder_round,
                                   largest_remainder_round_batch)
from repro.core.schemes import (MCReport, SCHEME_REGISTRY, Scheme,
                                get_scheme, list_schemes, register_scheme,
                                simulate_work_exchange_scalar,
                                work_exchange_mc_batched)
from repro.core.types import ExchangeConfig, HetSpec

RNG = lambda s=0: np.random.default_rng(s)

PAPER_SCHEMES = ("fixed", "uniform", "oracle", "mds", "work_exchange",
                 "work_exchange_unknown")
NEW_SCHEMES = ("het_mds", "trace_replay", "gradient_coded", "hedged")


def make_het(K=10, mu=10.0, sigma2=10.0 ** 2 / 6, seed=3):
    return HetSpec.uniform_random(K, mu, sigma2, RNG(seed))


class TestRegistry:
    def test_all_expected_schemes_registered(self):
        names = list_schemes()
        for n in PAPER_SCHEMES + NEW_SCHEMES:
            assert n in names, n

    def test_roundtrip(self):
        for name in list_schemes():
            s = get_scheme(name)
            assert isinstance(s, Scheme)
            assert s.name == name
            assert SCHEME_REGISTRY[name] is type(s)

    def test_aliases_resolve_to_canonical(self):
        assert type(get_scheme("het_static")) is type(get_scheme("fixed"))
        assert type(get_scheme("equal_static")) is type(get_scheme("uniform"))
        assert type(get_scheme("mds_opt")) is type(get_scheme("mds"))
        assert type(get_scheme("work_exchange_online")) is \
            type(get_scheme("work_exchange_unknown"))
        assert get_scheme("we_known").known is True
        assert get_scheme("we_unknown").known is False

    def test_unknown_name_raises_with_listing(self):
        with pytest.raises(KeyError, match="no_such_scheme"):
            get_scheme("no_such_scheme")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_scheme("oracle")
            class Dup(Scheme):
                pass

    def test_new_registration_is_visible_everywhere(self):
        @register_scheme("tmp_test_scheme")
        class Tmp(Scheme):
            def initial_sizes(self, het, N):
                return np.full(het.K, N // het.K, dtype=np.int64)
        try:
            assert "tmp_test_scheme" in list_schemes()
            assert isinstance(get_scheme("tmp_test_scheme"), Tmp)
        finally:
            del SCHEME_REGISTRY["tmp_test_scheme"]

    def test_params_forwarded(self):
        s = get_scheme("work_exchange_unknown", threshold_frac=0.2,
                       capped_mode="waterfill")
        assert s.threshold_frac == 0.2 and s.capped_mode == "waterfill"
        assert get_scheme("mds", L=3).L == 3
        assert get_scheme("het_mds", redundancy=1.5).redundancy == 1.5


class TestUniformReport:
    """Every scheme returns the same MCReport shape -- the tentpole claim."""

    @pytest.mark.parametrize("name", PAPER_SCHEMES + NEW_SCHEMES)
    def test_mc_report_shape(self, name):
        het = make_het()
        N, trials = 2_000, 4
        rep = get_scheme(name).mc(het, N, trials=trials, rng=RNG(1),
                                  keep_trials=True)
        assert isinstance(rep, MCReport)
        assert rep.scheme == name and rep.trials == trials
        assert np.isfinite(rep.t_comp) and rep.t_comp > 0
        assert rep.iterations >= 1 and rep.n_comm >= 0
        assert rep.t_comp_std >= 0
        for arr in (rep.t_comp_trials, rep.iterations_trials,
                    rep.n_comm_trials):
            assert arr is not None and arr.shape == (trials,)
        assert rep.t_comp == pytest.approx(rep.t_comp_trials.mean())

    @pytest.mark.parametrize("name", PAPER_SCHEMES + NEW_SCHEMES)
    def test_trials_omitted_by_default(self, name):
        rep = get_scheme(name).mc(make_het(), 1_000, trials=2, rng=RNG(2))
        assert rep.t_comp_trials is None

    @pytest.mark.parametrize("name", PAPER_SCHEMES + NEW_SCHEMES)
    def test_plan_covers_n(self, name):
        het = make_het()
        N = 1_000
        plan = get_scheme(name).plan(het, N)
        sizes = plan.sizes
        assert len(plan.queues) == het.K
        assert sizes.sum() >= N            # redundant schemes plan > N
        ids = [u for q in plan.queues for u in q]
        assert len(ids) == len(set(ids))   # distinct unit ids


class TestWorkConservation:
    """Satellite: the conservation property, uniformly over the registry."""

    @pytest.mark.parametrize("name", PAPER_SCHEMES + NEW_SCHEMES)
    @pytest.mark.parametrize("seed", [0, 7])
    def test_simulate_conserves_work(self, name, seed):
        het = make_het(seed=seed + 20)
        N = 1_500
        scheme = get_scheme(name)
        stats = scheme.simulate(het, N, RNG(seed))
        assert stats.t_comp > 0 and stats.iterations >= 1
        if scheme.redundant:
            # coded schemes deliver at least N (redundancy, never less)
            assert int(stats.n_done.sum()) >= N
        else:
            stats.check_work_conserved(N)


class TestHedged:
    """Satellite: replication-on-slowest (hedged requests)."""

    def test_layout(self):
        het = make_het(K=8)
        scheme = get_scheme("hedged")
        loads, spare, strag = scheme._layout(het, 2_000)
        assert spare == int(np.argmax(het.lambdas))
        assert loads[spare] == 0                 # spare holds no primary
        assert loads.sum() == 2_000
        loaded = np.flatnonzero(loads)
        assert strag in loaded
        assert het.lambdas[strag] == het.lambdas[loaded].min()
        sizes = scheme.initial_sizes(het, 2_000)
        assert sizes[spare] == loads[strag]      # the duplicated shard
        assert sizes.sum() == 2_000 + loads[strag]

    def test_hedge_never_slower_than_unhedged_straggler(self):
        """With the same primary draws, min(T_strag, T_spare) can only
        shrink the straggler's column, so per-trial completion is <= the
        completion of the same assignment without the hedge."""
        het = make_het(K=8, seed=5)
        scheme = get_scheme("hedged")
        loads, spare, strag = scheme._layout(het, 2_000)
        rng = RNG(7)
        t_comp, _, _, _, _, t_strag, t_spare = scheme._finish_times(
            het, 2_000, 200, rng)
        # reproduce the unhedged max with the identical primary draws
        rng = RNG(7)
        busy = loads > 0
        t_k = np.full((200, het.K), -np.inf)
        t_k[:, busy] = rng.gamma(shape=loads[busy],
                                 scale=1.0 / het.lambdas[busy],
                                 size=(200, int(busy.sum())))
        unhedged = t_k.max(axis=1)
        assert (t_comp <= unhedged + 1e-12).all()
        assert (t_comp < unhedged).any()         # the hedge fires sometimes

    def test_credit_goes_to_earlier_replica(self):
        # homogeneous cluster: the two replicas run the same load at the
        # same rate, so each wins ~half the time -- both credit paths fire
        het = HetSpec(np.full(6, 10.0))
        scheme = get_scheme("hedged")
        loads, spare, strag = scheme._layout(het, 1_000)
        saw = set()
        for seed in range(40):
            stats = scheme.simulate(het, 1_000, RNG(seed))
            assert stats.n_done.sum() == 1_000
            assert stats.n_done[spare] in (0, loads[strag])
            saw.add("spare" if stats.n_done[spare] else "straggler")
        assert saw == {"spare", "straggler"}     # both outcomes occur

    def test_fast_spare_usually_beats_slow_straggler(self):
        # heterogeneous cluster: the spare runs the straggler's load at
        # the fastest rate, so it should win the duplicate race nearly
        # always
        het = make_het(K=6, seed=9)
        scheme = get_scheme("hedged")
        _, spare, _ = scheme._layout(het, 1_000)
        wins = sum(bool(scheme.simulate(het, 1_000, RNG(s)).n_done[spare])
                   for s in range(30))
        assert wins >= 25

    def test_k1_degenerates_to_fixed(self):
        het = HetSpec(np.array([3.0]))
        rep = get_scheme("hedged").mc(het, 1_000, 16, RNG(1))
        assert rep.n_comm == 0 and rep.extra == {}

    def test_mc_matches_simulate_distribution(self):
        het = make_het(K=8, seed=3)
        rep = get_scheme("hedged").mc(het, 2_000, 400, RNG(2))
        sim = [get_scheme("hedged").simulate(het, 2_000, RNG(100 + i)).t_comp
               for i in range(400)]
        se = np.hypot(rep.t_comp_std, np.std(sim)) / np.sqrt(400)
        assert abs(rep.t_comp - np.mean(sim)) < 6 * se


class TestShimEquivalence:
    """Old simulator entry points == new Scheme API at the same seed."""

    def setup_method(self):
        warnings.simplefilter("ignore", DeprecationWarning)

    def test_fixed_mean_time(self):
        het = make_het()
        old = simulator.fixed_mean_time(het, 5_000, 50, RNG(4))
        new = get_scheme("fixed").mc(het, 5_000, 50, RNG(4)).t_comp
        assert old == new

    def test_oracle_mean_time(self):
        het = make_het()
        old = simulator.oracle_mean_time_mc(het, 5_000, 50, RNG(5))
        new = get_scheme("oracle").mc(het, 5_000, 50, RNG(5)).t_comp
        assert old == new

    def test_mds_optimize(self):
        het = make_het(K=6)
        L_old, t_old = simulator.mds_optimize(het, 3_000, 40, RNG(6))
        rep = get_scheme("mds").mc(het, 3_000, 40, RNG(6))
        assert rep.extra["L"] == L_old
        assert rep.t_comp == t_old

    def test_simulate_work_exchange_is_scalar_reference(self):
        het = make_het()
        cfg = ExchangeConfig(known_heterogeneity=False)
        old = simulator.simulate_work_exchange(het, 4_000, cfg, RNG(8))
        ref = simulate_work_exchange_scalar(het, 4_000, cfg, RNG(8))
        assert old.t_comp == ref.t_comp and old.n_comm == ref.n_comm
        np.testing.assert_array_equal(old.n_done, ref.n_done)

    def test_work_exchange_mc_loop_engine_matches_manual_loop(self):
        het = make_het()
        cfg = ExchangeConfig(known_heterogeneity=True)
        mc = simulator.work_exchange_mc(het, 4_000, cfg, 10, RNG(9),
                                        engine="loop")
        rng = RNG(9)
        ts = [simulate_work_exchange_scalar(het, 4_000, cfg, rng).t_comp
              for _ in range(10)]
        assert mc.t_comp == np.mean(ts)

    def test_legacy_exchange_mc_field_names(self):
        het = make_het()
        cfg = ExchangeConfig(known_heterogeneity=True)
        mc = simulator.work_exchange_mc(het, 2_000, cfg, 5, RNG(10))
        assert mc.t_std == mc.t_comp_std
        assert mc.i_std == mc.iterations_std
        assert mc.c_std == mc.n_comm_std

    def test_deprecation_warning_emitted(self):
        het = make_het()
        with pytest.warns(DeprecationWarning):
            simulator.simulate_oracle(het, 10, RNG(0))


class TestVectorizedEngine:
    """Seed-for-seed validation of the batched MC against the scalar path."""

    @pytest.mark.parametrize("known", [True, False])
    @pytest.mark.parametrize("sigma2", [0.0, 10.0 ** 2 / 6])
    @pytest.mark.parametrize("mode", ["carry", "waterfill"])
    def test_single_trial_bitwise_equal(self, known, sigma2, mode):
        """With one trial the batched engine consumes randomness in exactly
        the scalar order: results must be bit-identical, seed for seed."""
        cfg = ExchangeConfig(known_heterogeneity=known)
        for seed in range(6):
            het = HetSpec.uniform_random(13, 50.0, sigma2, RNG(seed + 100))
            s = simulate_work_exchange_scalar(het, 5_000, cfg, RNG(seed),
                                              mode)
            b = work_exchange_mc_batched(het, 5_000, cfg, 1, RNG(seed), mode,
                                         keep_trials=True)
            assert s.t_comp == b.t_comp_trials[0]
            assert s.iterations == b.iterations_trials[0]
            assert s.n_comm == b.n_comm_trials[0]

    @pytest.mark.parametrize("known", [True, False])
    def test_many_trials_statistically_match_loop(self, known):
        het = make_het(K=20, mu=10.0, seed=11)
        N, trials = 20_000, 300
        cfg = ExchangeConfig(known_heterogeneity=known)
        vec = work_exchange_mc_batched(het, N, cfg, trials, RNG(12))
        rng = RNG(13)
        loop_t = np.array([
            simulate_work_exchange_scalar(het, N, cfg, rng).t_comp
            for _ in range(trials)])
        # independent samples of the same distribution: compare via z-test
        se = np.hypot(vec.t_comp_std, loop_t.std()) / np.sqrt(trials)
        assert abs(vec.t_comp - loop_t.mean()) < 5 * se
        assert vec.t_comp == pytest.approx(N / het.lambda_sum, rel=0.05)

    def test_batched_respects_max_iterations(self):
        het = make_het()
        cfg = ExchangeConfig(known_heterogeneity=False, max_iterations=2,
                             threshold_frac=0.0)
        rep = work_exchange_mc_batched(het, 2_000, cfg, 8, RNG(14),
                                       keep_trials=True)
        assert (rep.iterations_trials <= 3).all()   # 2 loop + final phase

    def test_speedup_over_per_trial_loop(self):
        """The acceptance measurement (full K=50/trials=1000/N=1e6 scale,
        where the measured speedup is ~7-10x and the engine is RNG-bound)
        lives in benchmarks/run.py -> BENCH_schemes.json; here a reduced
        configuration must still clear a conservative floor under CI noise.
        """
        het = HetSpec.uniform_random(50, 50.0, 50.0 ** 2 / 6, RNG(15))
        N, trials = 100_000, 200
        cfg = ExchangeConfig(known_heterogeneity=False)
        rng = RNG(16)
        t0 = time.perf_counter()
        for _ in range(trials):
            simulate_work_exchange_scalar(het, N, cfg, rng)
        loop_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        work_exchange_mc_batched(het, N, cfg, trials, RNG(16))
        vec_s = time.perf_counter() - t0
        assert loop_s / vec_s > 3.0, (loop_s, vec_s)


class TestBatchedAssignment:
    def test_largest_remainder_batch_matches_scalar(self):
        rng = RNG(20)
        for _ in range(30):
            K = int(rng.integers(2, 12))
            T = int(rng.integers(1, 6))
            shares = rng.random((T, K)) * 10
            if rng.random() < 0.3:
                shares[rng.integers(T)] = 0.0       # ones-fallback row
            totals = rng.integers(0, 5_000, size=T)
            out = largest_remainder_round_batch(shares, totals)
            for i in range(T):
                np.testing.assert_array_equal(
                    out[i], largest_remainder_round(shares[i],
                                                    int(totals[i])))

    def test_capped_batch_matches_scalar(self):
        rng = RNG(21)
        for _ in range(30):
            K = int(rng.integers(2, 10))
            T = int(rng.integers(1, 5))
            lam = rng.random((T, K)) * 5 + 0.1
            n_rem = rng.integers(1, 3_000, size=T)
            cap = int(rng.integers(1, 600))
            out = capped_proportional_assignment_batch(lam, n_rem, cap)
            for i in range(T):
                np.testing.assert_array_equal(
                    out[i], capped_proportional_assignment(
                        lam[i], int(n_rem[i]), cap))


class TestScenarioSchemes:
    def test_het_mds_between_oracle_and_plain_mds(self):
        het = make_het(K=20, seed=30)
        N = 20_000
        oracle_t = N / het.lambda_sum
        rep = get_scheme("het_mds", redundancy=1.3).mc(het, N, 60, RNG(31))
        assert rep.t_comp >= oracle_t * 0.999
        # proportional coded loads beat the heterogeneity-blind (K, L) code
        mds = get_scheme("mds").mc(het, N, 60, RNG(32))
        assert rep.t_comp <= mds.t_comp * 1.05

    def test_het_mds_redundancy_tradeoff(self):
        """Under light-tailed Erlang service, proportional coded loads scale
        every worker's time by ~r: redundancy costs completion time (it buys
        straggler tolerance, not speed) and shifts work to communication."""
        het = make_het(K=20, seed=33)
        N = 20_000
        lean = get_scheme("het_mds", redundancy=1.0).mc(het, N, 60, RNG(34))
        fat = get_scheme("het_mds", redundancy=1.6).mc(het, N, 60, RNG(34))
        assert lean.t_comp <= fat.t_comp <= 1.7 * lean.t_comp
        assert lean.n_comm == 0 and fat.n_comm > 0

    def test_trace_replay_uses_pool_traces(self):
        het = make_het(K=4, seed=35)
        traces = np.outer(het.lambdas, [1.0, 0.5, 2.0])   # drifting rates
        scheme = get_scheme("trace_replay", traces=traces)
        stats = scheme.simulate(het, 600, RNG(36))
        stats.check_work_conserved(600)
        assert stats.iterations >= 1

    def test_trace_replay_synthetic_drift_shape_checked(self):
        scheme = get_scheme("trace_replay")
        het = make_het(K=5, seed=37)
        tr = scheme._traces_for(het)
        assert tr.shape == (5, scheme.period) and (tr > 0).all()
        bad = get_scheme("trace_replay", traces=np.ones((3, 4)))
        with pytest.raises(ValueError, match="workers"):
            bad.simulate(het, 100, RNG(0))

    def test_gradient_coded_covers_everything_early(self):
        het = make_het(K=6, seed=38)
        stats = get_scheme("gradient_coded", s=1).simulate(het, 900, RNG(39))
        assert int(stats.n_done.sum()) == 900    # unique-coverage credit
        assert stats.n_comm == pytest.approx(900)  # one extra replica
