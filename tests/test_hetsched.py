"""End-to-end heterogeneous training: policy equivalence, fault tolerance,
checkpoint/elasticity, compression, and paper-claim assertions on the
integrated system (not just the simulator)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, reshard_rates,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config, smoke_config
from repro.data import UnitStore
from repro.distributed.compression import Int8Compressor, TopKCompressor
from repro.distributed.hetsched import HetTrainer, POLICIES
from repro.models import build_model
from repro.optim import AdamW


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(smoke_config(get_config("phi3-mini-3.8b")),
                              dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, head_dim=16, n_kv_heads=2, d_ff=64,
                              vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    store = UnitStore(unit_batch=2, seq_len=16, vocab=cfg.vocab_size, seed=3)
    return cfg, model, params, store


RATES = np.array([1.0, 4.0, 2.0, 8.0])


def _run(setup, policy, steps=3, **kw):
    cfg, model, params, store = setup
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    trainer = HetTrainer(model, opt, RATES, store, policy=policy,
                         units_per_step=16, seed=7, **kw)
    return trainer.train(params, steps)


class TestPolicyEquivalence:
    def test_all_policies_same_trajectory(self, setup):
        """Work conservation => identical parameters for every policy."""
        ref = None
        for policy in POLICIES:
            p, _, hist = _run(setup, policy)
            leaves = jax.tree.leaves(p)
            if ref is None:
                ref = leaves
            else:
                for a, b in zip(ref, leaves):
                    np.testing.assert_allclose(
                        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5,
                        err_msg=f"{policy} diverged from equal_static")

    def test_loss_decreases(self, setup):
        cfg, model, params, store = setup
        store = dataclasses.replace(store, structured=True)
        opt = AdamW(lr=5e-3, weight_decay=0.0)
        trainer = HetTrainer(model, opt, RATES, store,
                             policy="work_exchange_online", units_per_step=8)
        _, _, hist = trainer.train(params, 12)
        first = np.mean([h.loss for h in hist[:3]])
        last = np.mean([h.loss for h in hist[-3:]])
        assert last < first, (first, last)


class TestVirtualTimeOrdering:
    def test_work_exchange_beats_equal_static(self, setup):
        """Paper Fig 5 on the integrated system: WE < naive equal split."""
        t = {}
        for policy in ("equal_static", "work_exchange",
                       "work_exchange_online"):
            _, _, hist = _run(setup, policy, steps=6)
            t[policy] = np.mean([h.t_virtual for h in hist])
        assert t["work_exchange"] < t["equal_static"]
        assert t["work_exchange_online"] < t["equal_static"]

    def test_oracle_bound_holds(self, setup):
        _, _, hist = _run(setup, "work_exchange", steps=6)
        oracle = 16 / RATES.sum()   # units_per_step / lambda_sum
        for h in hist:
            assert h.t_virtual >= 0.6 * oracle   # stochastic, but bounded

    def test_het_static_beats_equal_static(self, setup):
        te = np.mean([h.t_virtual
                      for h in _run(setup, "equal_static", steps=6)[2]])
        th = np.mean([h.t_virtual
                      for h in _run(setup, "het_static", steps=6)[2]])
        assert th < te


class TestFaultTolerance:
    def test_worker_failure_mid_training(self, setup):
        """A dead worker's units get reassigned; learning is unaffected."""
        cfg, model, params, store = setup
        opt = AdamW(lr=1e-2, weight_decay=0.0)
        t_ok = HetTrainer(model, opt, RATES, store, policy="work_exchange",
                          units_per_step=16, seed=7)
        p_ok, _, _ = t_ok.train(params, 2)
        t_fail = HetTrainer(model, opt, RATES, store, policy="work_exchange",
                            units_per_step=16, seed=7)
        p_fail, _, hist = t_fail.train(params, 2, failures={1: [3]})
        for a, b in zip(jax.tree.leaves(p_ok), jax.tree.leaves(p_fail)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)

    def test_coded_tolerates_straggler_without_exchange(self, setup):
        _, _, hist = _run(setup, "gradient_coded", steps=2,
                          coded_stragglers=1)
        assert all(h.iterations == 1 for h in hist)   # no coordination


class TestCheckpoint:
    def test_roundtrip_and_retention(self, setup, tmp_path):
        cfg, model, params, store = setup
        opt = AdamW(lr=1e-2)
        state = opt.init(params)
        for s in (1, 2, 3, 4):
            save_checkpoint(tmp_path, s, (params, state), extra={"s": s},
                            keep=2)
        assert latest_checkpoint(tmp_path).name == "step_00000004"
        ckpts = sorted(p.name for p in tmp_path.iterdir())
        assert ckpts == ["step_00000003", "step_00000004"]
        (p2, s2), extra = restore_checkpoint(latest_checkpoint(tmp_path),
                                             (params, state))
        assert extra == {"s": 4}
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_elastic_rate_reshard(self):
        rates = np.array([2.0, 4.0, 6.0])
        grown = reshard_rates(rates, 5)
        assert grown.shape == (5,)
        np.testing.assert_allclose(grown[3:], 4.0)    # mean prior
        shrunk = reshard_rates(rates, 2)
        np.testing.assert_allclose(shrunk, [2.0, 4.0])


class TestCompression:
    def test_int8_saves_bytes_and_converges(self, setup):
        cfg, model, params, store = setup
        opt = AdamW(lr=1e-2, weight_decay=0.0)
        dense = HetTrainer(model, opt, RATES, store, policy="work_exchange",
                           units_per_step=8, seed=7)
        _, _, h_dense = dense.train(params, 2)
        comp = HetTrainer(model, opt, RATES, store, policy="work_exchange",
                          units_per_step=8, seed=7,
                          compressor=Int8Compressor())
        p_c, _, h_comp = comp.train(params, 2)
        assert h_comp[0].grad_bytes < 0.3 * h_dense[0].grad_bytes
        assert all(np.isfinite(h.loss) for h in h_comp)

    def test_topk_error_feedback_recovers_mass(self, setup):
        cfg, model, params, store = setup
        comp = TopKCompressor(frac=0.25)
        g = jax.tree.map(lambda x: jnp.ones_like(x, jnp.float32), params)
        out1, _ = comp.roundtrip(g, 0)
        out2, _ = comp.roundtrip(g, 0)
        # second round ships accumulated residual: more mass than round 1
        m1 = sum(float(jnp.sum(x)) for x in jax.tree.leaves(out1))
        m2 = sum(float(jnp.sum(x)) for x in jax.tree.leaves(out2))
        assert m2 >= m1
