"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs; plus decode-vs-forward
consistency for every family's cache path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import build_model

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = sorted(ARCHS)


def _batch_for(model, B=2, S=32):
    cfg = model.cfg
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        s = S // 2
        return {
            "frame_embeds": jnp.asarray(
                rng.normal(size=(B, s, cfg.d_model)), jnp.dtype(cfg.dtype)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s))),
        }
    if cfg.frontend == "vision":
        F = cfg.n_frontend_tokens
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - F))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - F))),
            "image_embeds": jnp.asarray(
                rng.normal(size=(B, F, cfg.d_model)), jnp.dtype(cfg.dtype)),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}


@pytest.fixture(scope="module")
def models():
    return {}


def _get(models, arch):
    if arch not in models:
        cfg = smoke_config(get_config(arch))
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        models[arch] = (m, params)
    return models[arch]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(models, arch):
    m, params = _get(models, arch)
    batch = _batch_for(m)
    loss, metrics = m.loss(params, batch, mode="unroll")
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_scan_matches_unroll(models, arch):
    m, params = _get(models, arch)
    batch = _batch_for(m)
    l1, _ = m.loss(params, batch, mode="unroll")
    l2, _ = m.loss(params, batch, mode="scan")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nans(models, arch):
    m, params = _get(models, arch)
    batch = _batch_for(m)

    def loss_fn(p):
        return m.loss(p, batch, mode="unroll")[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert jnp.all(jnp.isfinite(g)), f"{arch}: non-finite grad"
    # one SGD step changes the loss
    new = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_remat_matches(models, arch):
    m, params = _get(models, arch)
    batch = _batch_for(m)
    l1, _ = m.loss(params, batch, mode="scan", remat=False)
    l2, _ = m.loss(params, batch, mode="scan", remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_shapes(models, arch):
    """Prefill S tokens then decode 2 steps; shape + finiteness checks."""
    m, params = _get(models, arch)
    cfg = m.cfg
    B, S = 2, 32
    s_max = 64
    cache = m.init_cache(B, s_max)
    batch = _batch_for(m, B, S)
    batch.pop("labels", None)
    logits, cache = m.prefill(params, batch, cache, mode="unroll")
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
    for _ in range(2):
        logits, cache = m.decode_step(params, cache, tok, mode="unroll")
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
        tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "h2o-danube-3-4b",
                                  "minicpm3-4b", "recurrentgemma-2b",
                                  "xlstm-350m", "qwen3-moe-30b-a3b"])
def test_decode_consistent_with_forward(models, arch):
    """logits(prefill(x[:n]) + decode steps) == logits(forward(x)) stepwise."""
    m, params = _get(models, arch)
    cfg = m.cfg
    B, S, n = 1, 16, 12
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    full_batch = {"tokens": jnp.asarray(toks),
                  "labels": jnp.asarray(toks)}
    if cfg.frontend == "vision":
        F = cfg.n_frontend_tokens
        img = jnp.asarray(rng.normal(size=(B, F, cfg.d_model)),
                          jnp.dtype(cfg.dtype))
        full_batch["image_embeds"] = img
    # teacher-forced logits from the pure forward pass
    from repro.models import transformer as tf_mod
    from repro.models.common import rmsnorm
    h = tf_mod._embed_tokens(params, cfg, full_batch)
    h, _ = tf_mod.forward_hidden(params, cfg, h, mode="unroll")
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    ref_logits = np.asarray((h @ params["lm_head"]).astype(jnp.float32))
    off = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0

    cache = m.init_cache(B, S + off)
    pre_batch = {"tokens": jnp.asarray(toks[:, :n])}
    if cfg.frontend == "vision":
        pre_batch["image_embeds"] = full_batch["image_embeds"]
    logits, cache = m.prefill(params, pre_batch, cache, mode="unroll")
    # bf16 tolerance: cache paths reorder matmuls (e.g. MLA absorption);
    # exact agreement is separately asserted in f32 below.
    np.testing.assert_allclose(
        np.asarray(logits[:, -1].astype(jnp.float32)),
        ref_logits[:, off + n - 1], rtol=6e-2, atol=6e-2)
    for t in range(n, S - 1):
        tok = jnp.asarray(toks[:, t:t + 1])
        logits, cache = m.decode_step(params, cache, tok, mode="unroll")
        np.testing.assert_allclose(
            np.asarray(logits[:, 0].astype(jnp.float32)),
            ref_logits[:, off + t], rtol=6e-2, atol=6e-2,
            err_msg=f"{arch}: decode step {t} diverges from forward")


@pytest.mark.parametrize("arch", ["minicpm3-4b", "recurrentgemma-2b",
                                  "xlstm-350m"])
def test_decode_exact_in_f32(arch):
    """Float32: cache/absorbed decode must match the forward pass tightly."""
    cfg = dataclasses.replace(smoke_config(get_config(arch)), dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    B, S, n = 1, 16, 12
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    from repro.models import transformer as tf_mod
    from repro.models.common import rmsnorm
    h = tf_mod._embed_tokens(params, cfg, {"tokens": jnp.asarray(toks)})
    h, _ = tf_mod.forward_hidden(params, cfg, h, mode="unroll")
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    ref = np.asarray(h @ params["lm_head"])
    cache = m.init_cache(B, S)
    logits, cache = m.prefill(params, {"tokens": jnp.asarray(toks[:, :n])},
                              cache, mode="unroll")
    np.testing.assert_allclose(np.asarray(logits[:, -1]), ref[:, n - 1],
                               rtol=1e-4, atol=1e-4)
    for t in range(n, S - 1):
        logits, cache = m.decode_step(params, cache,
                                      jnp.asarray(toks[:, t:t + 1]),
                                      mode="unroll")
        np.testing.assert_allclose(np.asarray(logits[:, 0]), ref[:, t],
                                   rtol=1e-4, atol=1e-4)
