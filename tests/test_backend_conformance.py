"""Cross-backend conformance suite: the contract every sampler backend
must satisfy, parametrized over ``list(SAMPLER_BACKENDS)`` so a future
backend gets the full battery for free just by registering.

Contract, per backend:
  * conservation -- every non-redundant scheme completes exactly N units
    (exact engines assert it internally; fluid engines sit at/above the
    work-conservation bound and never lose work at MC tolerance);
  * statistical equivalence -- mean AND variance of T_comp within
    tolerance of the exact numpy engine on a shared scenario grid;
  * determinism -- same seed, same report, twice;
  * mc/mc_grid agreement -- the grid dispatch is the same distribution as
    looped ``mc``.

The work-exchange runs share one ``B = G * trials = 512`` batch bucket so
jitted backends pay a single compilation for the whole file.
"""
import numpy as np
import pytest

from repro.core.samplers import ENV_VAR, SAMPLER_BACKENDS, get_backend
from repro.core.schemes import get_scheme, list_schemes
from repro.core.types import HetSpec

RNG = lambda s=0: np.random.default_rng(s)  # noqa: E731

K, N, TRIALS = 15, 50_000, 512

BACKENDS = [name for name in sorted(SAMPLER_BACKENDS)
            if get_backend(name).available()]
WE_SCHEMES = ("work_exchange", "work_exchange_unknown")


def make_het(K=K, mu=20.0, sigma2=20.0 ** 2 / 6, seed=3):
    return HetSpec.uniform_random(K, mu, sigma2, RNG(seed))


def mean_close(a, b, trials, k=6.0, floor=2e-3):
    """|mean_a - mean_b| within k combined standard errors (+ a small
    relative floor for float32 fluid pipelines)."""
    se = np.hypot(a.t_comp_std, b.t_comp_std) / np.sqrt(trials)
    assert abs(a.t_comp - b.t_comp) < max(k * se, floor * b.t_comp), \
        (a.t_comp, b.t_comp, se)


@pytest.mark.parametrize("backend", BACKENDS)
class TestConservation:
    def test_every_scheme_conserves_work(self, backend, monkeypatch):
        """With the backend selected globally, each registered scheme's
        exact single-trial path still completes exactly N units (the
        ``redundant`` schemes ship more by design and are checked for
        >= N)."""
        monkeypatch.setenv(ENV_VAR, backend)
        het = make_het()
        n = 2_000
        for name in list_schemes():
            scheme = get_scheme(name)
            stats = scheme.simulate(het, n, RNG(1))
            total = int(round(float(stats.n_done.sum())))
            if scheme.redundant:
                assert total >= n, f"{name} lost work: {total} < {n}"
            else:
                stats.check_work_conserved(n)

    def test_we_time_between_oracle_and_bound(self, backend):
        """No backend may 'complete' faster than the merged-process lower
        bound (that would mean losing units), nor sit far above it."""
        het = make_het(seed=11)
        oracle = N / het.lambda_sum
        for name in WE_SCHEMES:
            rep = get_scheme(name).mc(het, N, TRIALS, RNG(2),
                                      backend=backend)
            assert rep.extra["backend"] == backend
            assert oracle * 0.999 <= rep.t_comp < 1.10 * oracle, \
                (name, rep.t_comp, oracle)

    def test_report_shape_contract(self, backend):
        rep = get_scheme("work_exchange").mc(make_het(), N, TRIALS, RNG(3),
                                             keep_trials=True,
                                             backend=backend)
        assert rep.trials == TRIALS
        for arr in (rep.t_comp_trials, rep.iterations_trials,
                    rep.n_comm_trials):
            assert arr is not None and arr.shape == (TRIALS,)
            assert np.isfinite(arr).all()
        assert (rep.iterations_trials >= 1).all()
        assert (rep.n_comm_trials >= 0).all()


@pytest.mark.parametrize("backend", BACKENDS)
class TestStatisticalEquivalence:
    @pytest.mark.parametrize("name", WE_SCHEMES)
    def test_mean_and_variance_match_numpy(self, backend, name):
        het = make_het(seed=12)
        ref = get_scheme(name).mc(het, N, TRIALS, RNG(5), backend="numpy")
        rep = get_scheme(name).mc(het, N, TRIALS, RNG(6), backend=backend)
        mean_close(rep, ref, TRIALS)
        # variance: the fluid relaxation may only perturb the spread a
        # little (chi^2 ratio bounds at ~6 sigma for 512 samples)
        ratio = rep.t_comp_std / max(ref.t_comp_std, 1e-12)
        assert 0.6 < ratio < 1.6, (rep.t_comp_std, ref.t_comp_std)

    def test_mds_sweep_matches_numpy(self, backend):
        het = make_het(seed=13)
        ref = get_scheme("mds").mc(het, N, 400, RNG(7), backend="numpy")
        rep = get_scheme("mds").mc(het, N, 400, RNG(8), backend=backend)
        assert rep.extra["backend"] == backend
        # transform backends run the coupled (common-random-numbers)
        # sweep; near the optimum adjacent L means are statistically
        # tied, so allow the choice to land on a neighbour
        assert abs(rep.extra["L"] - ref.extra["L"]) <= 2
        mean_close(rep, ref, 400)


@pytest.mark.parametrize("backend", BACKENDS)
class TestDeterminism:
    def test_same_seed_same_report(self, backend):
        het = make_het(seed=14)
        a = get_scheme("work_exchange").mc(het, N, TRIALS, RNG(9),
                                           keep_trials=True,
                                           backend=backend)
        b = get_scheme("work_exchange").mc(het, N, TRIALS, RNG(9),
                                           keep_trials=True,
                                           backend=backend)
        np.testing.assert_array_equal(a.t_comp_trials, b.t_comp_trials)
        np.testing.assert_array_equal(a.iterations_trials,
                                      b.iterations_trials)
        np.testing.assert_array_equal(a.n_comm_trials, b.n_comm_trials)

    def test_mds_same_seed_same_report(self, backend):
        het = make_het(seed=15)
        a = get_scheme("mds").mc(het, N, 128, RNG(10), keep_trials=True,
                                 backend=backend)
        b = get_scheme("mds").mc(het, N, 128, RNG(10), keep_trials=True,
                                 backend=backend)
        assert a.extra["L"] == b.extra["L"]
        np.testing.assert_array_equal(a.t_comp_trials, b.t_comp_trials)


def drift_grid(G=2, rounds=20, kind="ar1"):
    """A DriftingScenario grid sized into the shared B bucket."""
    from repro.scenarios import DriftingScenario
    fam = DriftingScenario(K=K, points=tuple((20.0 * (g + 1),
                                              (20.0 * (g + 1)) ** 2 / 6,
                                              30 + g) for g in range(G)),
                           kind=kind, rounds=rounds, drift_sigma=0.2,
                           regime_prob=0.15)
    return fam.specs(), fam.rate_schedules()


@pytest.mark.parametrize("backend", BACKENDS)
class TestDriftingConformance:
    """Acceptance: the drifting-rates contract holds on every backend --
    per-round schedules produce the same distribution as the exact numpy
    engine (which the scalar drift reference pins bitwise), run
    deterministically, and never lose work."""

    @pytest.mark.parametrize("name", WE_SCHEMES)
    @pytest.mark.parametrize("kind", ["ar1", "regime"])
    def test_mean_and_variance_match_numpy(self, backend, name, kind):
        specs, sched = drift_grid(kind=kind)
        trials = TRIALS // len(specs)       # stay in the shared B bucket
        scheme = get_scheme(name)
        ref = scheme.mc_grid(specs, N, trials, RNG(21), backend="numpy",
                             rate_schedule=sched)
        rep = scheme.mc_grid(specs, N, trials, RNG(22), backend=backend,
                             rate_schedule=sched)
        for r, m in zip(ref, rep):
            mean_close(m, r, trials)
            ratio = m.t_comp_std / max(r.t_comp_std, 1e-12)
            assert 0.6 < ratio < 1.6, (m.t_comp_std, r.t_comp_std)

    def test_same_seed_same_report(self, backend):
        specs, sched = drift_grid()
        trials = TRIALS // len(specs)
        runs = [get_scheme("work_exchange").mc_grid(
                    specs, N, trials, RNG(23), backend=backend,
                    rate_schedule=sched, keep_trials=True)
                for _ in range(2)]
        for a, b in zip(*runs):
            np.testing.assert_array_equal(a.t_comp_trials, b.t_comp_trials)
            np.testing.assert_array_equal(a.n_comm_trials, b.n_comm_trials)

    def test_drift_slower_than_nominal_never_below_bound(self, backend):
        """Down-drifting rates may only slow completion; no backend may
        beat the nominal-rate work-conservation bound (losing work)."""
        specs, _ = drift_grid(G=1)
        het = specs[0]
        thr = np.full((20, K), 0.5) * het.lambdas[None, :]
        thr[0] = het.lambdas                 # nominal round 0
        rep = get_scheme("work_exchange").mc_grid(
            [het], N, TRIALS, RNG(24), backend=backend,
            rate_schedule=thr[None])[0]
        oracle = N / het.lambda_sum
        assert rep.t_comp > oracle * 0.999
        # round 0 runs at nominal and a 2x slowdown bounds the rest
        assert rep.t_comp < 2.2 * oracle

    def test_scalar_reference_pins_numpy_drift(self, backend):
        """The exact scalar drift path == batched numpy at trials=1;
        other backends are covered by the statistical battery above
        (run once, under the numpy id, to keep the pin in this file)."""
        if backend != "numpy":
            pytest.skip("bitwise pin is numpy-only by design")
        from repro.core.schemes import simulate_work_exchange_scalar
        from repro.core.types import ExchangeConfig
        specs, sched = drift_grid(G=1)
        ref = simulate_work_exchange_scalar(specs[0], N,
                                            ExchangeConfig(), RNG(25),
                                            rate_schedule=sched[0])
        rep = get_scheme("work_exchange").mc(specs[0], N, 1, RNG(25),
                                             keep_trials=True,
                                             rate_schedule=sched[0])
        assert rep.t_comp_trials[0] == ref.t_comp


@pytest.mark.parametrize("backend", BACKENDS)
class TestGridAgreement:
    def test_we_grid_matches_looped_mc(self, backend):
        specs = [make_het(seed=s, mu=10.0 * (s + 1),
                          sigma2=(10.0 * (s + 1)) ** 2 / 6) for s in (0, 1)]
        trials = TRIALS // len(specs)       # stay in the shared B bucket
        scheme = get_scheme("work_exchange")
        grid = scheme.mc_grid(specs, N, trials, RNG(11), backend=backend)
        for het, g in zip(specs, grid):
            m = scheme.mc(het, N, trials, RNG(12), backend=backend)
            mean_close(g, m, trials)
        assert grid[1].t_comp < grid[0].t_comp      # spec axis aligned

    def test_mds_grid_matches_looped_mc(self, backend):
        specs = [make_het(seed=s + 20) for s in (0, 1)]
        scheme = get_scheme("mds")
        grid = scheme.mc_grid(specs, N, 300, RNG(13), backend=backend)
        rng = RNG(14)
        for het, g in zip(specs, grid):
            m = scheme.mc(het, N, 300, rng, backend=backend)
            assert abs(g.extra["L"] - m.extra["L"]) <= 2
            mean_close(g, m, 300)
