"""Regenerate every paper figure's data to CSV under results/figures/.

The figure modules resolve every policy through the scheme registry
(``repro.core.schemes``); a newly registered scheme shows up in the fig5
CSV automatically via ``benchmarks.common.FIG_SCHEMES``.

Run:  PYTHONPATH=src python examples/paper_figures.py [--quick]
"""
import argparse
import csv
import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import fig5, fig6, fig7


def dump(rows, path: Path):
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {path} ({len(rows)} rows)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/figures")
    args = ap.parse_args()
    out = Path(args.out)
    dump(fig5.run(quick=args.quick), out / "fig5_completion_time.csv")
    dump(fig6.run(quick=args.quick), out / "fig6_comm_and_iters.csv")
    dump(fig7.run(quick=args.quick), out / "fig7_threshold.csv")
    print("done")


if __name__ == "__main__":
    main()
