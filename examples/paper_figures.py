"""Regenerate every paper figure's data to CSV under results/figures/.

The figure modules are declarative ``ExperimentSpec``s resolved through
``repro.experiments`` (``benchmarks/fig5|6|7.py``); a newly registered
scheme shows up in the fig5 CSV automatically via
``benchmarks.common.FIG_SCHEMES``.  Results go through the
content-addressed store (``results/store/<spec-hash>.json``), so
regenerating with unchanged specs is served from cache -- pass --fresh
to force recomputation.

Run:  PYTHONPATH=src python examples/paper_figures.py [--quick] [--fresh]
"""
import argparse
import csv
import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import fig5, fig6, fig7
from repro.experiments import ResultsStore


def dump(rows, path: Path):
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {path} ({len(rows)} rows)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fresh", action="store_true",
                    help="recompute even when the store has the spec")
    ap.add_argument("--out", default="results/figures")
    ap.add_argument("--store", default="results/store")
    args = ap.parse_args()
    out = Path(args.out)
    store = ResultsStore(args.store)
    kw = dict(quick=args.quick, store=store, force=args.fresh)
    dump(fig5.run(**kw), out / "fig5_completion_time.csv")
    dump(fig6.run(**kw), out / "fig6_comm_and_iters.csv")
    dump(fig7.run(**kw), out / "fig7_threshold.csv")
    print("done")


if __name__ == "__main__":
    main()
