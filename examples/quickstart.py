"""Quickstart: the paper in 60 seconds.

1. Builds a heterogeneous 50-worker cluster (rates ~ Uniform).
2. Compares oracle bound / optimized-MDS / fixed / work-exchange times.
3. Runs a REAL tiny-transformer training step under the work-exchange
   scheduler (virtual clocks, real gradients).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import simulator
from repro.core.types import ExchangeConfig, HetSpec
from repro.data import UnitStore
from repro.distributed.hetsched import HetTrainer
from repro.models import build_model
from repro.optim import AdamW


def main():
    # --- 1. the paper's setting -------------------------------------------
    N, K = 100_000, 50
    rng = np.random.default_rng(0)
    het = HetSpec.uniform_random(K, mu=50.0, sigma2=50.0 ** 2 / 6, rng=rng)
    oracle = N / het.lambda_sum
    print(f"cluster: K={K}, lambda_sum={het.lambda_sum:.1f}")
    print(f"oracle lower bound (Thm 1):      {oracle:.3f} s")

    L, t_mds = simulator.mds_optimize(het, N, trials=50, rng=rng)
    print(f"optimized (K,L)-MDS  (L*={L:2d}):   {t_mds:.3f} s "
          f"(+{100 * (t_mds / oracle - 1):.1f}%)")
    t_fix = simulator.fixed_mean_time(het, N, 200, rng)
    print(f"het-aware fixed assignment:      {t_fix:.3f} s "
          f"(+{100 * (t_fix / oracle - 1):.1f}%)")
    for known in (True, False):
        mc = simulator.work_exchange_mc(
            het, N, ExchangeConfig(known_heterogeneity=known), 30, rng)
        lbl = "known" if known else "unknown"
        print(f"work exchange ({lbl:7s} rates):  {mc.t_comp:.3f} s "
              f"(+{100 * (mc.t_comp / oracle - 1):.1f}%), "
              f"I={mc.iterations:.1f}, N_comm/N={mc.n_comm / N:.4f}")

    # --- 2. real training under the scheduler ------------------------------
    print("\nwork-exchange training (real gradients, virtual clocks):")
    cfg = dataclasses.replace(smoke_config(get_config("phi3-mini-3.8b")),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    store = UnitStore(unit_batch=2, seq_len=32, vocab=cfg.vocab_size,
                      structured=True)
    trainer = HetTrainer(model, AdamW(lr=5e-3, weight_decay=0.0),
                         rates=[1.0, 4.0, 2.0, 8.0], store=store,
                         policy="work_exchange_online", units_per_step=8)
    _, _, hist = trainer.train(params, steps=8)
    for h in hist:
        print(f"  step {h.step}: loss={h.loss:.3f} "
              f"T_virtual={h.t_virtual:.3f}s I={h.iterations} "
              f"moved_units={h.n_comm_units}")


if __name__ == "__main__":
    main()
