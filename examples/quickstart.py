"""Quickstart: the paper in 60 seconds.

1. Builds a heterogeneous 50-worker cluster (rates ~ Uniform).
2. Compares every registered scheduling scheme through the unified
   registry API -- three lines per scheme:

       het = HetSpec.uniform_random(K, mu, sigma2, rng)
       report = get_scheme("work_exchange").mc(het, N, trials, rng)
       print(report.t_comp, report.iterations, report.n_comm)

3. Declares a whole (mu, sigma^2) scenario study as an ``ExperimentSpec``
   (``repro.experiments``) and resolves it through the single engine
   entry point: the sampler backend (exact numpy engine, fused jitted
   jax pipeline, pallas kernel) and the device sharding knob ride on the
   spec, results land in the content-addressed store, and re-running the
   unchanged spec is a cache hit.

4. Runs a REAL tiny-transformer training step under the work-exchange
   scheduler (virtual clocks, real gradients) -- the same registry
   resolves the training policy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import HetSpec, get_scheme, list_schemes, resolve_backend
from repro.data import UnitStore
from repro.distributed.hetsched import HetTrainer
from repro.experiments import (ExperimentSpec, ResultsStore, ScenarioGrid,
                               run_experiment, scheme_spec)
from repro.models import build_model
from repro.optim import AdamW


def main():
    # --- 1. the paper's setting, one registry call per scheme --------------
    N, K = 100_000, 50
    rng = np.random.default_rng(0)
    het = HetSpec.uniform_random(K, mu=50.0, sigma2=50.0 ** 2 / 6, rng=rng)
    oracle = N / het.lambda_sum
    print(f"cluster: K={K}, lambda_sum={het.lambda_sum:.1f}")
    print(f"registered schemes: {', '.join(list_schemes())}")
    print(f"oracle lower bound (Thm 1):       {oracle:.3f} s")

    panel = ("mds", "fixed", "work_exchange", "work_exchange_unknown",
             "het_mds", "hedged")
    for name in panel:
        rep = get_scheme(name).mc(het, N, trials=30, rng=rng)
        extra = "".join(f" {k}={v:g}" for k, v in rep.extra.items()
                        if isinstance(v, (int, float)))
        print(f"{name:22s} {rep.t_comp:9.3f} s "
              f"(+{100 * (rep.t_comp / oracle - 1):5.1f}%)  "
              f"I={rep.iterations:5.1f}  N_comm/N={rep.n_comm / N:.4f}"
              f"{extra}")

    # --- 2. a declarative experiment through the store ----------------------
    backend = resolve_backend()      # REPRO_SAMPLER_BACKEND or "numpy"
    mus = (10.0, 50.0, 100.0)
    spec = ExperimentSpec(
        name="quickstart",
        grid=ScenarioGrid(K=K, points=[(mu, mu * mu / 6, int(mu))
                                       for mu in mus]),
        schemes=(scheme_spec("work_exchange"),),
        N=N, trials=30, seed=7, backend=backend,
        devices="auto")              # shards trials x scenarios if >1 device
    store = ResultsStore(tempfile.mkdtemp(prefix="repro-store-"))
    result = run_experiment(spec, store=store)
    print(f"\nExperimentSpec {spec.name!r} through the '{backend}' backend "
          f"({result.spec.devices} device(s)); stored at "
          f"store/{result.spec_hash[:16]}....json:")
    for (mu, _, _), het_g, rep in zip(spec.grid.points, spec.grid.specs(),
                                      result.report("work_exchange")):
        print(f"  mu={mu:5.1f}  T_comp={rep.t_comp:8.3f} s "
              f"(oracle {N / het_g.lambda_sum:8.3f} s)  "
              f"I={rep.iterations:5.1f}")
    again = run_experiment(spec, store=store)
    print(f"  re-run with the unchanged spec: "
          f"{'cache HIT, served from the store' if again.cache_hit else 'recomputed'}")

    # --- 3. real training under the work exchange scheduler ----------------
    print("\nwork exchange training (real gradients, virtual clocks):")
    cfg = dataclasses.replace(smoke_config(get_config("phi3-mini-3.8b")),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    store = UnitStore(unit_batch=2, seq_len=32, vocab=cfg.vocab_size,
                      structured=True)
    trainer = HetTrainer(model, AdamW(lr=5e-3, weight_decay=0.0),
                         rates=[1.0, 4.0, 2.0, 8.0], store=store,
                         policy="work_exchange_online", units_per_step=8)
    _, _, hist = trainer.train(params, steps=8)
    for h in hist:
        print(f"  step {h.step}: loss={h.loss:.3f} "
              f"T_virtual={h.t_virtual:.3f}s I={h.iterations} "
              f"moved_units={h.n_comm_units}")


if __name__ == "__main__":
    main()
